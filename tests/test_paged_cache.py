"""Paged KV allocator: pure-Python tests, no jax import, millisecond-fast.

Covers the satellite checklist: alloc/free round-trips, exhaustion surfacing
as a controlled failure (admission rejection at the engine layer), and block
tables staying consistent across interleaved prefill/decode/retire."""
import pytest

from repro.serve.paged_cache import (NULL_BLOCK, BlockPool, BlockTable,
                                     PoolExhausted, blocks_for_tokens,
                                     dense_equiv_blocks, worst_case_blocks)


def test_block_math():
    assert blocks_for_tokens(1, 8) == 1
    assert blocks_for_tokens(8, 8) == 1
    assert blocks_for_tokens(9, 8) == 2
    assert worst_case_blocks(prompt_len=7, max_new=9, block_size=8) == 2
    assert worst_case_blocks(prompt_len=8, max_new=9, block_size=8) == 3
    assert dense_equiv_blocks(max_batch=4, max_len=60, block_size=8) == 4 * 8


def test_alloc_free_roundtrip():
    pool = BlockPool(num_blocks=9, block_size=4)
    assert pool.usable_blocks == 8
    got = [pool.alloc() for _ in range(8)]
    assert len(set(got)) == 8, "allocated block ids must be unique"
    assert NULL_BLOCK not in got, "the null block is never handed out"
    assert pool.num_free == 0 and pool.num_used == 8
    assert pool.peak_used == 8
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(got)
    assert pool.num_free == 8 and pool.num_used == 0
    # full round-trip: the same capacity is allocatable again
    again = [pool.alloc() for _ in range(8)]
    assert sorted(again) == sorted(got)
    assert pool.peak_used == 8  # peak survives the free/realloc cycle


def test_free_rejects_garbage():
    pool = BlockPool(num_blocks=5, block_size=4)
    blk = pool.alloc()
    pool.free([blk])
    with pytest.raises(ValueError):
        pool.free([blk])            # double free
    with pytest.raises(ValueError):
        pool.free([NULL_BLOCK])     # null block is not freeable
    with pytest.raises(ValueError):
        pool.free([99])             # out of range


def test_reservations_gate_allocation():
    pool = BlockPool(num_blocks=9, block_size=4)
    assert pool.can_reserve(8)
    assert not pool.can_reserve(9), "cannot reserve more than the usable pool"
    assert pool.reserve(6)
    assert pool.available() == 2
    assert not pool.reserve(3), "reservation beyond availability must fail"
    # unreserved allocation respects the reservation ledger
    a, b = pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()  # 6 free blocks remain, but all 6 are reserved
    # reserved allocation draws the ledger down
    c = pool.alloc(reserved=True)
    assert pool.num_reserved == 5
    pool.release(5)
    assert pool.num_reserved == 0
    assert pool.available() == pool.num_free == 5
    pool.free([a, b, c])
    with pytest.raises(ValueError):
        pool.release(1)  # nothing reserved anymore


def test_block_tables_stay_consistent_interleaved():
    """Two requests interleaving prefill growth, decode growth, and retire:
    tables never share a block, capacity covers every written position, and
    retiring returns exactly the held blocks."""
    pool = BlockPool(num_blocks=9, block_size=4)
    ta, tb = BlockTable(4), BlockTable(4)
    ta.ensure(6, pool, reserved=False)       # request A prefills 6 tokens
    tb.ensure(3, pool, reserved=False)       # B prefills 3 (interleaved)
    assert ta.capacity >= 6 and tb.capacity >= 3
    assert not set(ta.blocks) & set(tb.blocks), "tables must be disjoint"
    for step in range(7, 12):                # A decodes to 11 tokens
        ta.ensure(step, pool, reserved=False)
        tb.ensure(step - 3, pool, reserved=False)
    assert not set(ta.blocks) & set(tb.blocks)
    assert len(ta.blocks) == blocks_for_tokens(11, 4)
    held = len(ta.blocks) + len(tb.blocks)
    assert pool.num_used == held
    # padded device view: fixed width, null-padded, own blocks first
    padded = ta.padded(8)
    assert len(padded) == 8
    assert padded[:len(ta.blocks)] == ta.blocks
    assert all(p == NULL_BLOCK for p in padded[len(ta.blocks):])
    with pytest.raises(ValueError):
        ta.padded(1)  # table wider than the padded view is a bug
    a_blocks = list(ta.blocks)
    ta.release_to(pool)                      # A retires
    assert ta.blocks == [] and pool.num_used == len(tb.blocks)
    # B can immediately grow into A's returned blocks
    tb.ensure(30, pool, reserved=False)
    assert set(a_blocks) & set(tb.blocks), "freed blocks are reusable"
    tb.release_to(pool)
    assert pool.num_used == 0


def test_exhaustion_is_controlled_not_a_crash():
    """Growing past the pool raises PoolExhausted (which the engine converts
    into admission rejection / preemption) rather than corrupting state."""
    pool = BlockPool(num_blocks=3, block_size=4)
    t = BlockTable(4)
    t.ensure(8, pool, reserved=False)        # takes both usable blocks
    with pytest.raises(PoolExhausted):
        t.ensure(9, pool, reserved=False)
    # state is intact: the table still holds its 2 blocks, pool is just full
    assert len(t.blocks) == 2 and pool.num_free == 0
    t.release_to(pool)
    assert pool.num_free == 2


def test_pool_rejects_degenerate_shapes():
    with pytest.raises(ValueError):
        BlockPool(num_blocks=1, block_size=4)   # no room beside the null block
    with pytest.raises(ValueError):
        BlockPool(num_blocks=4, block_size=0)
