"""Paged KV physical allocator: pure-Python tests, no jax import.

Covers block math (including the exact prompt + max_new - 1 admission
bound), alloc/free round-trips, the reservation ledger, and exhaustion
surfacing as a controlled failure.  Refcounted handles, tables, CoW, and
tier movement are covered one level up in test_kv_store.py."""
import pytest

from repro.serve.paged_cache import (NULL_BLOCK, BlockPool, PoolExhausted,
                                     blocks_for_tokens, dense_equiv_blocks,
                                     worst_case_blocks)


def test_block_math():
    assert blocks_for_tokens(1, 8) == 1
    assert blocks_for_tokens(8, 8) == 1
    assert blocks_for_tokens(9, 8) == 2
    assert dense_equiv_blocks(max_batch=4, max_len=60, block_size=8) == 4 * 8


def test_worst_case_is_exact_prompt_plus_max_new_minus_one():
    """The last sampled token's KV is never written, so the bound is
    prompt + max_new - 1 positions — crossing a block edge with the old
    prompt + max_new bound used to over-reserve one block."""
    assert worst_case_blocks(prompt_len=7, max_new=9, block_size=8) == 2
    # 8 + 9 = 17 tokens would need 3 blocks, but only 16 are ever written
    assert worst_case_blocks(prompt_len=8, max_new=9, block_size=8) == 2
    assert worst_case_blocks(prompt_len=8, max_new=10, block_size=8) == 3
    # degenerate max_new values never go below the prompt's own footprint
    assert worst_case_blocks(prompt_len=8, max_new=1, block_size=8) == 1
    assert worst_case_blocks(prompt_len=8, max_new=0, block_size=8) == 1


def test_alloc_free_roundtrip():
    pool = BlockPool(num_blocks=9, block_size=4)
    assert pool.usable_blocks == 8
    got = [pool.alloc() for _ in range(8)]
    assert len(set(got)) == 8, "allocated block ids must be unique"
    assert NULL_BLOCK not in got, "the null block is never handed out"
    assert pool.num_free == 0 and pool.num_used == 8
    assert pool.peak_used == 8
    with pytest.raises(PoolExhausted):
        pool.alloc()
    pool.free(got)
    assert pool.num_free == 8 and pool.num_used == 0
    # full round-trip: the same capacity is allocatable again
    again = [pool.alloc() for _ in range(8)]
    assert sorted(again) == sorted(got)
    assert pool.peak_used == 8  # peak survives the free/realloc cycle


def test_free_rejects_garbage():
    pool = BlockPool(num_blocks=5, block_size=4)
    blk = pool.alloc()
    pool.free([blk])
    with pytest.raises(ValueError):
        pool.free([blk])            # double free
    with pytest.raises(ValueError):
        pool.free([NULL_BLOCK])     # null block is not freeable
    with pytest.raises(ValueError):
        pool.free([99])             # out of range


def test_reservations_gate_allocation():
    pool = BlockPool(num_blocks=9, block_size=4)
    assert pool.can_reserve(8)
    assert not pool.can_reserve(9), "cannot reserve more than the usable pool"
    assert pool.reserve(6)
    assert pool.available() == 2
    assert not pool.reserve(3), "reservation beyond availability must fail"
    # unreserved allocation respects the reservation ledger
    a, b = pool.alloc(), pool.alloc()
    with pytest.raises(PoolExhausted):
        pool.alloc()  # 6 free blocks remain, but all 6 are reserved
    # reserved allocation draws the ledger down
    c = pool.alloc(reserved=True)
    assert pool.num_reserved == 5
    pool.release(5)
    assert pool.num_reserved == 0
    assert pool.available() == pool.num_free == 5
    pool.free([a, b, c])
    with pytest.raises(ValueError):
        pool.release(1)  # nothing reserved anymore


def test_exhaustion_is_controlled_not_a_crash():
    """Draining the pool raises PoolExhausted (which the engine converts
    into eviction / preemption) rather than corrupting state."""
    pool = BlockPool(num_blocks=3, block_size=4)
    got = [pool.alloc(), pool.alloc()]
    with pytest.raises(PoolExhausted):
        pool.alloc()
    # state is intact: both blocks still allocated, pool is just full
    assert pool.num_used == 2 and pool.num_free == 0
    pool.free(got)
    assert pool.num_free == 2


def test_pool_rejects_degenerate_shapes():
    with pytest.raises(ValueError):
        BlockPool(num_blocks=1, block_size=4)   # no room beside the null block
    with pytest.raises(ValueError):
        BlockPool(num_blocks=4, block_size=0)
