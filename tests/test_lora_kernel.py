"""Segmented gather-BGMV LoRA kernels vs the dense-gather oracle: ragged
per-row adapter mixes, ragged ranks (0/8/16 in one slab), GQA-shaped
projections, bf16 slabs, and expand-tile variation (interpret mode executes
the kernel bodies on CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.lora import lora_plan_block_out, set_lora_plan

RNG = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32, scale=0.5):
    return jnp.asarray(RNG.normal(size=shape) * scale).astype(dtype)


def _slab_pair(s, d_in, d_out, r, dtype=jnp.float32):
    return (_arr((s, d_in, r), dtype), _arr((s, r, d_out), dtype))


IDX_MIXES = [
    [0, 1, 2, 0],           # ragged mix, repeats
    [-1, -1, -1, -1],       # all base rows
    [2, -1, 0, -1],         # interleaved base / adapter
    [1],                    # single row
]


@pytest.mark.parametrize("idx", IDX_MIXES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_shrink_expand_parity(idx, dtype):
    t, d_in, d_out, s, r = len(idx), 64, 48, 3, 16
    a_slab, b_slab = _slab_pair(s, d_in, d_out, r, dtype)
    x = _arr((t, d_in), dtype)
    ids = jnp.asarray(idx, jnp.int32)

    h = ops.lora_shrink(x, a_slab, ids)
    h_ref = ref.lora_shrink_ref(x, a_slab, ids)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               rtol=tol, atol=tol)

    y = ops.lora_expand(h, b_slab, ids)
    y_ref = ref.lora_expand_ref(h, b_slab, ids, out_dtype=dtype)
    assert y.dtype == b_slab.dtype
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=tol, atol=tol * r ** 0.5)


def test_base_rows_are_exact_zero():
    """idx < 0 masks to EXACT zero, not merely small — the structural half
    of the base-identity contract (an all-base batch never attaches the
    lora branch at all; a mixed batch's base rows get bitwise-zero delta)."""
    t, d_in, d_out, s, r = 4, 32, 32, 2, 8
    a_slab, b_slab = _slab_pair(s, d_in, d_out, r)
    x = _arr((t, d_in))
    ids = jnp.asarray([-1, 0, -1, 1], jnp.int32)
    h = np.asarray(ops.lora_shrink(x, a_slab, ids))
    y = np.asarray(ops.lora_expand(jnp.asarray(h), b_slab, ids))
    assert (h[0] == 0).all() and (h[2] == 0).all()
    assert (y[0] == 0).all() and (y[2] == 0).all()
    assert (h[1] != 0).any() and (y[3] != 0).any()


def test_ragged_ranks_share_one_slab():
    """A rank-8 adapter in a rank-16 slot contributes zero through its
    padding: computing at r=16 with padded factors equals computing at r=8
    with the unpadded ones.  A rank-0 slot (all padding) is exactly zero."""
    t, d_in, d_out, s = 3, 48, 64, 3
    a8, b8 = _slab_pair(s, d_in, d_out, 8)
    a16 = jnp.pad(a8, ((0, 0), (0, 0), (0, 8)))
    b16 = jnp.pad(b8, ((0, 0), (0, 8), (0, 0)))
    # slot 2 is a rank-0 adapter: zero everything
    a16 = a16.at[2].set(0.0)
    b16 = b16.at[2].set(0.0)
    x = _arr((t, d_in))
    ids = jnp.asarray([0, 1, 2], jnp.int32)

    y16 = np.asarray(ops.lora_expand(ops.lora_shrink(x, a16, ids), b16, ids))
    y8 = np.asarray(ops.lora_expand(ops.lora_shrink(x, a8, ids), b8, ids))
    np.testing.assert_allclose(y16[:2], y8[:2], rtol=1e-5, atol=1e-5)
    assert (y16[2] == 0).all()      # rank 0 == exact base behavior


@pytest.mark.parametrize("d_in,d_out", [(64, 64),   # q/o-shaped
                                        (64, 16),   # GQA kv-shaped (narrow)
                                        (16, 64)])  # and its transpose
def test_gqa_projection_shapes(d_in, d_out):
    t, s, r = 5, 2, 8
    a_slab, b_slab = _slab_pair(s, d_in, d_out, r)
    x = _arr((t, d_in))
    ids = jnp.asarray([0, -1, 1, 1, 0], jnp.int32)
    h = ops.lora_shrink(x, a_slab, ids)
    y = ops.lora_expand(h, b_slab, ids)
    y_ref = ref.lora_expand_ref(ref.lora_shrink_ref(x, a_slab, ids),
                                b_slab, ids, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_out", [16, 33, 256])
def test_expand_tile_invariance(block_out):
    """Auto Schedule's block_out choice tiles the output features; every
    tile size (including one that does not divide d_out — the pad path)
    must produce the same result."""
    t, d_in, d_out, s, r = 4, 32, 80, 2, 8
    a_slab, b_slab = _slab_pair(s, d_in, d_out, r)
    x = _arr((t, d_in))
    ids = jnp.asarray([0, 1, -1, 0], jnp.int32)
    h = ops.lora_shrink(x, a_slab, ids)
    y = ops.lora_expand(h, b_slab, ids, block_out=block_out)
    want = ref.lora_expand_ref(h, b_slab, ids, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_set_lora_plan_roundtrip():
    before = lora_plan_block_out()
    try:
        set_lora_plan(128)
        assert lora_plan_block_out() == 128
        set_lora_plan(0)            # clamped, never a zero-size tile
        assert lora_plan_block_out() == 1
    finally:
        set_lora_plan(before)
