"""Cross-layer integration: trainer on an explicit mesh, non-dense-family
training, pipeline prefetch, and the dry-run cell runner on a local mesh."""

import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh
from repro.train.data import TokenPipeline
from repro.train.trainer import Trainer, TrainerConfig


def test_trainer_on_explicit_mesh():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    mesh = make_local_mesh()
    tcfg = TrainerConfig(seq_len=32, global_batch=2, steps=4, log_every=1)
    res = Trainer(cfg, tcfg, mesh=mesh).train()
    assert res["final_step"] == 4
    assert all(np.isfinite(e["loss"]) for e in res["log"])


@pytest.mark.parametrize("arch", ["falcon-mamba-7b", "zamba2-2.7b",
                                  "olmoe-1b-7b", "whisper-small"])
def test_trainer_nondense_families(arch):
    cfg = reduced_config(get_config(arch))
    tcfg = TrainerConfig(seq_len=16, global_batch=2, steps=3, log_every=1)
    res = Trainer(cfg, tcfg).train()
    assert res["final_step"] == 3
    assert np.isfinite(res["log"][-1]["loss"])


def test_pipeline_prefetch_thread():
    p = TokenPipeline(vocab=64, seq_len=8, global_batch=2, seed=3)
    p.start(start_step=5)
    it = iter(p)
    step, batch = next(it)
    assert step == 5
    np.testing.assert_array_equal(batch["tokens"], p.batch_at(5)["tokens"])
    step2, _ = next(it)
    assert step2 == 6
    p.stop()


def test_vlm_trainer_smoke():
    cfg = reduced_config(get_config("qwen2-vl-72b"))
    tcfg = TrainerConfig(seq_len=16, global_batch=2, steps=2, log_every=1)
    res = Trainer(cfg, tcfg).train()
    assert np.isfinite(res["log"][-1]["loss"])


def test_auto_distribution_agrees_with_policy_direction():
    """The SBP search's memory-capped answer (shard weights) points the same
    direction as the production FSDP policy for large models."""
    from repro.core.distribution import auto_distribute, build_distributed_egraph
    from repro.core.sbp import Placement, S
    from repro.core.tensor_ir import inp, matmul, unary
    pl = Placement(("data", "model"), (2, 2))
    x = inp("x", (64, 1024))
    w1, w2 = inp("w1", (1024, 4096)), inp("w2", (4096, 1024))
    term = matmul(unary(matmul(x, w1), kind="exp"), w2)
    free = auto_distribute(term, pl, use_sat=False)
    capped = auto_distribute(term, pl, mem_capacity=int(free.peak_memory * 0.8))
    dg = build_distributed_egraph(term, pl)
    free_sharded = sum(
        1 for tid, nd in free.assignments.items()
        if dg.terms[tid].attr("name") in ("w1", "w2")
        and any(isinstance(s, S) for s in nd))
    cap_sharded = sum(
        1 for tid, nd in capped.assignments.items()
        if dg.terms[tid].attr("name") in ("w1", "w2")
        and any(isinstance(s, S) for s in nd))
    assert cap_sharded > free_sharded  # the cap is what drives FSDP
