"""Async gateway over the serve engine: stream/oracle identity, mid-stream
cancellation (KV blocks freed), concurrent interleaving, HTTP/SSE wire
checks, stop sequences, and the aggregator's latency columns."""
import asyncio
import json

import jax
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.async_engine import AsyncServeEngine
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.gateway import (ByteTokenizer, Gateway, GatewayModel,
                                 Router, StopDetector)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("plan_kernels", False)
    return ServeEngine(cfg, params, **kw)


def _oracle(cfg, params, specs):
    """run_until_done on a fresh engine: the batch reference output."""
    eng = _engine(cfg, params)
    reqs = [Request(rid=i, prompt=list(p), max_new=n, sampling=sp)
            for i, (p, n, sp) in enumerate(specs)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_steps=500)
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


# ---------------------------------------------------------------------------
# async engine
# ---------------------------------------------------------------------------

def test_stream_identical_to_batch_oracle(setup):
    """Tokens streamed through the async engine are exactly what
    ``run_until_done`` produces for the same requests — greedy and seeded
    sampling alike (stateless (seed, index) sampling makes this hold
    regardless of batch composition or arrival order)."""
    cfg, fns, params = setup
    specs = [
        ([3, 5, 7, 11], 6, SamplingParams()),
        ([4, 6, 8], 5, SamplingParams(temperature=0.8, top_k=40, seed=7)),
        ([9, 2, 12, 13, 14], 4, SamplingParams(temperature=1.1, seed=3)),
    ]
    want = _oracle(cfg, params, specs)

    async def go():
        aeng = AsyncServeEngine(_engine(cfg, params))
        await aeng.start()
        try:
            streams = [aeng.submit(p, max_new=n, sampling=sp)
                       for p, n, sp in specs]
            outs = await asyncio.gather(*[s.drain() for s in streams])
            reasons = [s.finish_reason for s in streams]
        finally:
            await aeng.stop()
        return outs, reasons

    outs, reasons = asyncio.run(go())
    assert outs == want
    assert reasons == ["length"] * len(specs)


def test_cancel_mid_stream_frees_kv_blocks(setup):
    """Cancelling after the first token ends the stream with
    ``finish_reason="cancelled"`` and returns every KV block to the pool
    (prefix cache disabled so the accounting is exact)."""
    cfg, fns, params = setup

    async def go():
        eng = _engine(cfg, params, prefix_cache_blocks=0)
        aeng = AsyncServeEngine(eng)
        await aeng.start()
        try:
            stream = aeng.submit([3, 5, 7, 11], max_new=24)
            got = [await stream.__anext__()]   # wait for generation to start
            aeng.cancel(stream.rid)
            got += await stream.drain()
            # the cancel lands inside the stepper; give it a beat to retire
            for _ in range(200):
                if eng.pool.num_used == 0 and \
                        all(s is None for s in eng.slots):
                    break
                await asyncio.sleep(0.005)
            return (stream.finish_reason, len(got), eng.pool.num_used,
                    len(eng.queue))
        finally:
            await aeng.stop()

    reason, n_got, used, queued = asyncio.run(go())
    assert reason == "cancelled"
    assert 1 <= n_got < 24
    assert used == 0
    assert queued == 0


def test_cancel_queued_request(setup):
    """A request cancelled while still waiting in the admission queue never
    touches the pool and finishes as cancelled."""
    cfg, fns, params = setup
    eng = _engine(cfg, params, max_batch=1, prefix_cache_blocks=0)
    a = Request(rid=0, prompt=[3, 5, 7], max_new=8)
    b = Request(rid=1, prompt=[4, 6, 8], max_new=4)
    eng.submit(a)
    eng.submit(b)
    eng.step()                      # admits a (max_batch=1); b stays queued
    assert eng.cancel(1)
    assert b.cancelled and b.done and b.finish_reason == "cancelled"
    assert not eng.cancel(99)       # unknown rid is a no-op
    eng.run_until_done(max_steps=200)
    assert a.done and len(a.out) == 8
    assert eng.pool.num_used == 0


def test_concurrent_streams_interleave(setup):
    """Five submissions through max_batch=2 all finish, and their token
    events interleave (continuous batching, not one-request-at-a-time)."""
    cfg, fns, params = setup
    n_reqs, max_new = 5, 6

    async def go():
        aeng = AsyncServeEngine(_engine(cfg, params))
        await aeng.start()
        order = []

        async def consume(i, stream):
            async for _tok in stream:
                order.append(i)

        try:
            streams = [aeng.submit([3 + i, 5, 7], max_new=max_new)
                       for i in range(n_reqs)]
            await asyncio.gather(*[consume(i, s)
                                   for i, s in enumerate(streams)])
        finally:
            await aeng.stop()
        return order

    order = asyncio.run(go())
    assert len(order) == n_reqs * max_new
    switches = sum(1 for a, b in zip(order, order[1:]) if a != b)
    # perfectly serial service would switch exactly n_reqs - 1 times
    assert switches > n_reqs, f"no interleaving: {order}"


# ---------------------------------------------------------------------------
# HTTP layer
# ---------------------------------------------------------------------------

def _http_model(cfg, params, **kw):
    eng = _engine(cfg, params, **kw)
    return GatewayModel(model_id="m", async_engine=AsyncServeEngine(eng),
                        tokenizer=ByteTokenizer(cfg.vocab))


async def _raw(host, port, method, path, payload=None):
    """One HTTP exchange on a raw socket; returns (status, headers, body)."""
    body = b"" if payload is None else json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        head = f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
        if body:
            head += ("Content-Type: application/json\r\n"
                     f"Content-Length: {len(body)}\r\n")
        writer.write(head.encode() + b"\r\n" + body)
        await writer.drain()
        status = int((await reader.readline()).split()[1])
        headers = {}
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        data = await reader.read()
        return status, headers, data
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def _sse_chunks(data: bytes):
    """Parse an SSE body strictly: only data-lines, exactly one terminal
    [DONE]; returns the decoded JSON chunks."""
    events = [ln for ln in data.split(b"\n") if ln.strip()]
    assert all(e.startswith(b"data: ") for e in events), events
    payloads = [e[len(b"data: "):] for e in events]
    assert payloads[-1] == b"[DONE]" and payloads.count(b"[DONE]") == 1
    return [json.loads(p) for p in payloads[:-1]]


def test_http_stream_matches_oracle_and_sse_shape(setup):
    cfg, fns, params = setup
    prompt, max_new = [3, 5, 7, 11], 6
    sp = SamplingParams(temperature=0.7, top_k=20, seed=5)
    [want] = _oracle(cfg, params, [(prompt, max_new, sp)])

    async def go():
        async with Gateway(Router([_http_model(cfg, params)]), port=0) as gw:
            status, headers, data = await _raw(
                gw.host, gw.port, "POST", "/v1/completions",
                {"model": "m", "prompt": prompt, "max_tokens": max_new,
                 "stream": True, "temperature": sp.temperature,
                 "top_k": sp.top_k, "seed": sp.seed})
            st2, _, models = await _raw(gw.host, gw.port, "GET", "/v1/models")
            st404, _, _ = await _raw(gw.host, gw.port, "GET", "/nope")
            return status, headers, data, st2, models, st404

    status, headers, data, st2, models, st404 = asyncio.run(go())
    assert status == 200
    assert headers["content-type"].startswith("text/event-stream")
    assert "x-request-id" in headers
    chunks = _sse_chunks(data)
    assert all(c["object"] == "text_completion" for c in chunks)
    ids = [t for c in chunks for t in c["choices"][0].get("token_ids") or []]
    assert ids == want
    final = chunks[-1]
    assert final["choices"][0]["finish_reason"] == "length"
    assert final["usage"]["completion_tokens"] == max_new
    assert st2 == 200
    cards = json.loads(models)
    assert [m["id"] for m in cards["data"]] == ["m"]
    assert st404 == 404


def test_http_stop_sequence_truncates(setup):
    """A stop string taken from the unconstrained output truncates the
    stream before it and flips finish_reason to 'stop'."""
    cfg, fns, params = setup
    prompt, max_new = [3, 5, 7, 11], 8

    async def go():
        async with Gateway(Router([_http_model(cfg, params)]), port=0) as gw:
            async def completion(extra):
                _, _, data = await _raw(
                    gw.host, gw.port, "POST", "/v1/completions",
                    {"model": "m", "prompt": prompt, "max_tokens": max_new,
                     **extra})
                return json.loads(data)
            free = await completion({})
            text = free["choices"][0]["text"]
            stop = text[2:4]
            stopped = await completion({"stop": [stop]})
            return text, stop, stopped

    text, stop, stopped = asyncio.run(go())
    choice = stopped["choices"][0]
    assert choice["finish_reason"] == "stop"
    assert stop not in choice["text"]
    assert choice["text"] == text[:text.find(stop)]


def test_http_chat_stream_has_role_delta(setup):
    cfg, fns, params = setup

    async def go():
        async with Gateway(Router([_http_model(cfg, params)]), port=0) as gw:
            _, _, data = await _raw(
                gw.host, gw.port, "POST", "/v1/chat/completions",
                {"model": "m", "stream": True, "max_tokens": 4,
                 "messages": [{"role": "user", "content": "hi"}]})
            return _sse_chunks(data)

    chunks = asyncio.run(go())
    assert chunks[0]["object"] == "chat.completion.chunk"
    assert chunks[0]["choices"][0]["delta"]["role"] == "assistant"
    assert chunks[-1]["choices"][0]["finish_reason"] == "length"


def test_http_bad_requests(setup):
    cfg, fns, params = setup

    async def go():
        async with Gateway(Router([_http_model(cfg, params)]), port=0) as gw:
            bad_model = await _raw(gw.host, gw.port, "POST",
                                   "/v1/completions",
                                   {"model": "ghost", "prompt": "hi"})
            bad_prompt = await _raw(gw.host, gw.port, "POST",
                                    "/v1/completions",
                                    {"model": "m", "prompt": [99999]})
            return bad_model, bad_prompt

    (st1, _, b1), (st2, _, b2) = asyncio.run(go())
    assert st1 == 404 and b"ghost" in b1
    assert st2 == 400 and b"vocab" in b2


# ---------------------------------------------------------------------------
# fault tolerance surface: degraded health, load shedding, deadlines
# ---------------------------------------------------------------------------

def test_health_degraded_answers_503(setup):
    """Repeated step crashes flip the engine degraded; /health must turn
    non-200 so orchestrators can key restarts on it."""
    cfg, fns, params = setup
    model = _http_model(cfg, params)

    async def go():
        async with Gateway(Router([model]), port=0) as gw:
            ok = await _raw(gw.host, gw.port, "GET", "/health")
            model.engine.degraded = True     # what max consecutive crashes do
            bad = await _raw(gw.host, gw.port, "GET", "/health")
            model.engine.degraded = False
            return ok, bad

    (st_ok, _, body_ok), (st_bad, _, body_bad) = asyncio.run(go())
    assert st_ok == 200 and json.loads(body_ok)["status"] == "ok"
    assert st_bad == 503
    health = json.loads(body_bad)
    assert health["status"] == "degraded"
    assert health["models"][0]["degraded"] is True


def test_overloaded_gateway_sheds_with_429_and_retry_after(setup):
    cfg, fns, params = setup
    model = _http_model(cfg, params)

    async def go():
        async with Gateway(Router([model]), port=0) as gw:
            model.engine.overload_reason = lambda: "admission queue full"
            try:
                shed = await _raw(gw.host, gw.port, "POST",
                                  "/v1/completions",
                                  {"model": "m", "prompt": [3, 5, 7]})
            finally:
                del model.engine.overload_reason
            ok = await _raw(gw.host, gw.port, "POST", "/v1/completions",
                            {"model": "m", "prompt": [3, 5, 7],
                             "max_tokens": 2})
            return shed, ok

    (st, headers, body), (st_ok, _, _) = asyncio.run(go())
    assert st == 429
    assert headers.get("retry-after") == "1"
    err = json.loads(body)["error"]
    assert err["type"] == "overloaded_error"
    assert "queue full" in err["message"]
    assert model.engine.metrics().requests_shed == 1
    assert st_ok == 200, "shedding one request must not poison the next"


def test_request_timeout_field_expires_via_engine_reaper(setup):
    cfg, fns, params = setup

    async def go():
        async with Gateway(Router([_http_model(cfg, params)]), port=0) as gw:
            st, _, data = await _raw(
                gw.host, gw.port, "POST", "/v1/completions",
                {"model": "m", "prompt": [3, 5, 7], "max_tokens": 8,
                 "stream": True, "timeout": 1e-6})
            bad = await _raw(gw.host, gw.port, "POST", "/v1/completions",
                             {"model": "m", "prompt": [3, 5, 7],
                              "timeout": -1})
            return st, data, bad

    st, data, (st_bad, _, body_bad) = asyncio.run(go())
    assert st == 200
    chunks = _sse_chunks(data)
    assert chunks[-1]["choices"][0]["finish_reason"] == "expired"
    assert st_bad == 400 and b"timeout" in body_bad


def test_stream_of_quarantined_request_ends_with_error(setup):
    """A step crash mid-request must surface to the HTTP client as a
    terminal finish_reason="error" SSE event, not a hung stream."""
    from repro.serve.faults import FaultInjector

    cfg, fns, params = setup
    model = _http_model(cfg, params,
                        fault_injector=FaultInjector.parse("step:exc=1"))

    async def go():
        async with Gateway(Router([model]), port=0) as gw:
            return await asyncio.wait_for(
                _raw(gw.host, gw.port, "POST", "/v1/completions",
                     {"model": "m", "prompt": [3, 5, 7], "max_tokens": 4,
                      "stream": True}),
                timeout=30.0)

    st, _, data = asyncio.run(go())
    assert st == 200
    chunks = _sse_chunks(data)
    assert chunks[-1]["choices"][0]["finish_reason"] == "error"
    eng = model.engine
    assert eng.metrics().step_crashes == 1
    assert eng.check_invariants() == []


# ---------------------------------------------------------------------------
# pure helpers
# ---------------------------------------------------------------------------

def test_stop_detector_split_across_tokens():
    d = StopDetector(["END"])
    out = d.feed("aE") + d.feed("N") + d.feed("Db")
    assert out == "a"
    assert d.stopped


def test_stop_detector_no_match_flushes_all():
    d = StopDetector(["xyz"])
    out = d.feed("ab") + d.feed("cd") + d.flush()
    assert out == "abcd"
    assert not d.stopped


def test_byte_tokenizer_roundtrip():
    tok = ByteTokenizer(256 + 1)
    assert tok.decode(tok.encode("héllo")) == "héllo"
    small = ByteTokenizer(16)
    ids = small.encode("hello")
    assert all(0 < t < 16 for t in ids)      # clamped, never the pad id
