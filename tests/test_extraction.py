"""Extraction: greedy DP vs WPMaxSAT vs specialized B&B (§3.1.1)."""
import pytest

from repro.core.egraph import EGraph
from repro.core.extraction import (branch_bound_extract, greedy_extract,
                                   wpmaxsat_extract)
from repro.core.rewrite import TRANSPOSE_RULES
from repro.core.tensor_ir import binary, inp, transpose, unary


def _fig2_graph():
    A, B = inp("A", (32, 16)), inp("B", (16, 32))
    term = transpose(unary(binary(transpose(A, (1, 0)), B, kind="add"),
                           kind="exp"), (1, 0))
    eg = EGraph()
    root = eg.add_term(term)
    eg.saturate(TRANSPOSE_RULES, max_iters=8)
    return eg, root


def test_extractors_agree_on_cost():
    eg, root = _fig2_graph()
    c_greedy, _ = greedy_extract(eg, root)
    c_sat, _ = wpmaxsat_extract(eg, root)
    c_bb, _ = branch_bound_extract(eg, root)
    assert c_sat <= c_greedy + 1e-12
    assert abs(c_bb - c_sat) < 1e-12


def test_extraction_selects_one_node_per_class():
    eg, root = _fig2_graph()
    _, choice = wpmaxsat_extract(eg, root)
    for cid, node in choice.items():
        assert node in eg.nodes(cid)
        for ch in node.children:
            assert eg.find(ch) in choice  # children resolved


def test_memory_cap_infeasible_raises():
    eg, root = _fig2_graph()
    with pytest.raises(ValueError):
        branch_bound_extract(eg, root, mem_fn=lambda n: 100.0, cap=50.0)


def test_memory_cap_binding():
    eg, root = _fig2_graph()
    # every node costs 1 unit of memory: cap = #classes is feasible
    c_free, ch_free = branch_bound_extract(eg, root, mem_fn=lambda n: 1.0,
                                           cap=1000.0)
    used = len(ch_free)
    c_tight, ch_tight = branch_bound_extract(eg, root, mem_fn=lambda n: 1.0,
                                             cap=float(used))
    assert len(ch_tight) <= used
    assert c_tight >= c_free - 1e-15
