"""Paged serving engine over the tiered KVStore: continuous batching,
chunked prefill, per-request sampling, admission control, prefix sharing
(copy-on-write), preemption-by-swap, and the run_until_done regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.paged_cache import dense_equiv_blocks


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _solo_oracle(cfg, params, prompt, max_new):
    """One request alone in a fresh engine with sharing disabled: the
    unshared / never-preempted reference output."""
    eng = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                      plan_kernels=False, prefix_cache_blocks=0)
    r = Request(rid=0, prompt=list(prompt), max_new=max_new)
    eng.submit(r)
    eng.run_until_done()
    return r.out


def test_run_until_done_returns_finished(setup):
    """Regression: run_until_done used to declare ``finished`` and return it
    empty; it must return every completed request."""
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      plan_kernels=False)
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7], max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done(max_steps=200)
    assert len(finished) == 5
    assert {r.rid for r in finished} == {0, 1, 2, 3, 4}
    assert all(r.done for r in finished)
    assert all(len(r.out) == 4 for r in finished)


def test_engine_matches_single_request_decode(setup):
    """Paged engine output (chunked prefill + paged decode) for one greedy
    request == raw dense prefill+decode loop."""
    cfg, fns, params = setup
    prompt = [3, 5, 7, 11, 13, 17, 19]
    # chunk of 3 forces the prompt through 3 prefill chunks
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      prefill_chunk_tokens=3, plan_kernels=False)
    r = Request(rid=0, prompt=prompt, max_new=5)
    eng.submit(r)
    finished = eng.run_until_done(max_steps=100)
    assert [f.rid for f in finished] == [0]

    # dense oracle
    cache1, logits = fns.prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    def embed(small, big):
        if small.shape == big.shape:
            return small.astype(big.dtype)
        for ax in range(small.ndim):
            if small.shape[ax] != big.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), 0, axis=ax)
        return small
    cache = jax.tree.map(embed, cache1, fns.make_cache(1, 32))
    toks = [int(jnp.argmax(logits[0]))]
    cur = len(prompt)
    for _ in range(4):
        cache, lg = fns.decode_step(params, cache,
                                    {"token": jnp.asarray([[toks[-1]]], jnp.int32),
                                     "cur_len": jnp.int32(cur)})
        toks.append(int(jnp.argmax(lg[0])))
        cur += 1
    assert r.out == toks


def test_acceptance_12_requests_mixed(setup):
    """The PR's acceptance workload: 12 requests with mixed prompt/output
    lengths through max_batch=4 all complete, pool utilization stays below
    100%, and peak blocks beat the dense max_batch x max_len footprint."""
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, block_size=8,
                      plan_kernels=False)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(12):
        plen = int(rng.integers(3, 21))
        reqs.append(Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=plen).tolist(),
                            max_new=int(rng.integers(4, 15))))
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert len(finished) == 12
    assert {r.rid for r in finished} == set(range(12))
    m = eng.metrics()
    assert m.requests_finished == 12 and m.requests_rejected == 0
    assert m.tokens_per_sec > 0 and m.ttft_mean_s > 0
    assert m.peak_pool_utilization < 1.0
    dense = dense_equiv_blocks(4, 64, 8)
    assert m.dense_equiv_blocks == dense
    assert m.peak_blocks_used < dense, \
        "paged cache must beat the dense slot cache's KV footprint"
    # blocks all returned once the workload drains and the budgeted prefix
    # registry (the only legitimate post-drain holder) is dropped
    assert eng.pool.num_used <= eng.store.prefix_cache_blocks
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0


def test_admission_rejects_oversized(setup):
    """A request whose worst-case footprint can never fit is rejected (not
    crashed on); the rest of the workload is unaffected."""
    cfg, fns, params = setup
    # pool of 4 usable blocks x 4 tokens = 16 token capacity
    eng = ServeEngine(cfg, params, max_batch=2, max_len=64, block_size=4,
                      num_blocks=5, plan_kernels=False)
    big = Request(rid=0, prompt=[1] * 12, max_new=12)     # worst 6 > 4 blocks
    toolong = Request(rid=1, prompt=[1] * 60, max_new=8)  # 68 > max_len
    empty = Request(rid=3, prompt=[], max_new=4)
    nonew = Request(rid=4, prompt=[1, 2], max_new=0)
    ok = Request(rid=2, prompt=[2, 3, 4], max_new=4)      # worst 2 blocks
    for r in (big, toolong, empty, nonew, ok):
        eng.submit(r)
    finished = eng.run_until_done()
    assert [r.rid for r in finished] == [2]
    assert big.rejected and "pool capacity" in big.reject_reason
    assert toolong.rejected and "max_len" in toolong.reject_reason
    assert empty.rejected and "empty" in empty.reject_reason
    assert nonew.rejected and "max_new" in nonew.reject_reason
    assert {r.rid for r in eng.rejected} == {0, 1, 3, 4}
    assert eng.metrics().requests_rejected == 4


def test_sampling_seeded_reproducible(setup):
    """Same seeds -> identical outputs across independent engine runs."""
    cfg, fns, params = setup
    def run():
        eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                          plan_kernels=False)
        reqs = [Request(rid=i, prompt=[5, 7, 11 + i], max_new=6,
                        sampling=SamplingParams(temperature=1.0, top_k=20, seed=i))
                for i in range(3)]
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        return [tuple(r.out) for r in reqs]
    assert run() == run()


def test_sampling_unit_properties():
    """Sampler semantics on synthetic logits: greedy = argmax, temperature
    draws vary per step, are seed-keyed, and respect top-k support."""
    rng = np.random.default_rng(0)
    logits = rng.normal(size=128).astype(np.float32)
    greedy = ServeEngine._sample(logits, SamplingParams(), 0)
    assert greedy == int(np.argmax(logits))
    sp = SamplingParams(temperature=1.0, top_k=16, seed=3)
    draws = [ServeEngine._sample(logits, sp, i) for i in range(16)]
    assert draws == [ServeEngine._sample(logits, sp, i) for i in range(16)]
    assert len(set(draws)) > 1, "temperature sampling must vary across steps"
    other = [ServeEngine._sample(logits, SamplingParams(1.0, 16, 4), i)
             for i in range(16)]
    assert draws != other, "different seeds must give different streams"
    topk = set(np.argsort(logits)[-16:])
    assert set(draws) <= topk, "top-k sampling must stay in the top-k support"


def test_optimistic_admission_preempts_and_recovers(setup):
    """With optimistic admission and a pool too small for both requests'
    full generations, the engine preempts the youngest, restarts it, and
    still completes everything."""
    cfg, fns, params = setup
    # 6 usable blocks x 4 = 24 tokens; each request needs 4+16=20 tokens, so
    # both fit individually but not together
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic", plan_kernels=False)
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert {r.rid for r in finished} == {0, 1}
    assert all(len(r.out) == 16 for r in reqs)
    m = eng.metrics()
    assert m.preemptions >= 1, "this workload must overcommit and preempt"
    # preemption parked KV on the host tier and restored it (REPRO_KV_SWAP
    # defaults on): the victim's generated tokens survived, so no decode
    # work was re-delivered
    assert m.swap_out_blocks > 0 and m.swap_in_blocks == m.swap_out_blocks
    assert m.re_prefill_avoided > 0
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0
    # conservative admission on the same workload serializes instead
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                       num_blocks=7, admission="conservative",
                       plan_kernels=False)
    for i in range(2):
        eng2.submit(Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16))
    assert len(eng2.run_until_done()) == 2
    assert eng2.metrics().preemptions == 0


def test_prefix_sharing_prefills_shared_prefix_once(setup):
    """The PR's acceptance workload: N requests opening with the same prompt
    prefix prefill it exactly once — later requests fork the registered
    blocks (refcounted, copy-on-write) and skip straight to their suffix."""
    cfg, fns, params = setup
    prefix = [3, 5, 7, 11, 13, 17]                    # 6 tokens, bs=4
    eng = ServeEngine(cfg, params, max_batch=4, max_len=32, block_size=4,
                      plan_kernels=False)
    reqs = [Request(rid=i, prompt=prefix + [19 + i], max_new=4)
            for i in range(4)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert len(finished) == 4
    m = eng.metrics()
    # request 0 prefills all 7 tokens; requests 1..3 prefill only their
    # 1-token suffix: the 6-token prefix ran through the model exactly once
    assert m.prefill_tokens == 7 + 3 * 1
    assert m.re_prefill_avoided == 3 * 6
    assert m.shared_blocks == 3 * 2, "each sharer forks the prefix's 2 blocks"
    assert m.cow_copies >= 3, \
        "writing into the shared partial tail block must copy-on-write"
    # shared outputs match each request's unshared solo oracle
    for r in reqs:
        assert r.out == _solo_oracle(cfg, params, r.prompt, r.max_new), \
            f"rid {r.rid}: prefix sharing changed the output"


def test_preempted_request_restored_from_host_tier_matches_oracle(setup):
    """Preemption-by-swap equivalence: a request that was preempted, parked
    on the host tier, and restored must produce token-for-token the output
    of an uninterrupted run (greedy sampling)."""
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic", plan_kernels=False)
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    m = eng.metrics()
    assert m.preemptions >= 1 and m.swap_in_blocks > 0, \
        "this workload must preempt and restore through the host tier"
    for r in reqs:
        assert r.out == _solo_oracle(cfg, params, r.prompt, r.max_new), \
            f"rid {r.rid}: swap round-trip changed the output"


def test_kv_swap_knob_off_restores_legacy_restart(setup, monkeypatch):
    """REPRO_KV_SWAP=0: preempted requests drop their KV and restart from
    the prompt — everything still completes, nothing touches the host tier,
    and outputs still match the oracle (stateless seeded sampling replays)."""
    monkeypatch.setenv("REPRO_KV_SWAP", "0")
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic", plan_kernels=False)
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert {r.rid for r in finished} == {0, 1}
    assert all(len(r.out) == 16 for r in reqs)
    m = eng.metrics()
    assert m.preemptions >= 1
    assert m.swap_out_blocks == 0 and m.swap_in_blocks == 0
    monkeypatch.delenv("REPRO_KV_SWAP")
    for r in reqs:
        assert r.out == _solo_oracle(cfg, params, r.prompt, r.max_new)


def test_admission_relieves_pressure_by_swapping_stranded_parked_blocks(setup):
    """A parked request's device-resident blocks can strand the whole pool
    (they were shared at preemption, exclusive since).  Admission's relief
    ladder must push them to the host tier rather than halting with the
    queue head permanently blocked."""
    from repro.serve.engine import _Parked
    cfg, fns, params = setup
    # 4 usable blocks x 4 tokens; prefix sharing off so nothing else holds KV
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=5, admission="optimistic", plan_kernels=False,
                      prefix_cache_blocks=0)
    # a parked request whose 4 device-resident blocks fill the pool
    stranded = Request(rid=99, prompt=list(range(1, 14)), max_new=4, out=[7])
    eng._parked[99] = _Parked(blocks=[eng.store.alloc() for _ in range(4)],
                              next_prefill=13, pos=13)
    eng._submitted += 1
    fresh = Request(rid=0, prompt=[5, 6, 7], max_new=4)
    eng.submit(fresh)
    eng.queue.append(stranded)            # behind the fresh head
    finished = eng.run_until_done()
    assert {r.rid for r in finished} == {0, 99}, \
        "strand-blocked admission must not halt the engine"
    m = eng.metrics()
    # relief swaps only as much strand as admission actually needs
    assert m.swap_out_blocks >= 1, "relief must have parked strand on host"
    assert m.swap_in_blocks == m.swap_out_blocks, "and restored all of it"
    assert eng.pool.num_used == 0


def test_engine_plans_paged_kernels_through_pipeline(setup):
    """plan_kernels=True compiles the paged decode + prefill-chunk attention
    shapes through repro.pipeline and keeps the reports."""
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=8)
    assert set(eng.compile_reports) == {"decode", "prefill"}
    assert eng.kernel_plan is not None
    assert eng.compile_report.pass_times, "per-pass telemetry must be present"
    # cache hit on identical shapes: a second engine reuses the plan
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=8)
    assert eng2.compile_reports["decode"].cache_hit
