"""Serving engine: slot batching, prefill splice, decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def _setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def test_engine_completes_requests():
    cfg, fns, params = _setup()
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32)
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7], max_new=4) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_steps=100)
    assert all(r.done for r in reqs)
    assert all(len(r.out) >= 4 for r in reqs)


def test_engine_matches_single_request_decode():
    """Batched engine output for one request == raw prefill+decode loop."""
    cfg, fns, params = _setup()
    prompt = [3, 5, 7, 11]
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32)
    r = Request(rid=0, prompt=prompt, max_new=4)
    eng.submit(r)
    eng.run_until_done(max_steps=50)

    # manual greedy decode
    cache1, logits = fns.prefill(params, {"tokens": jnp.asarray([prompt], jnp.int32)})
    def embed(small, big):
        if small.shape == big.shape:
            return small.astype(big.dtype)
        for ax in range(small.ndim):
            if small.shape[ax] != big.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), 0, axis=ax)
        return small
    cache = jax.tree.map(embed, cache1, fns.make_cache(1, 32))
    toks = [int(jnp.argmax(logits[0]))]
    cur = len(prompt)
    for _ in range(3):
        cache, lg = fns.decode_step(params, cache,
                                    {"token": jnp.asarray([[toks[-1]]], jnp.int32),
                                     "cur_len": jnp.int32(cur)})
        toks.append(int(jnp.argmax(lg[0])))
        cur += 1
    assert r.out[:4] == toks
