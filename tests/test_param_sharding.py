"""Rule-driven tensor-parallel weight sharding (PR: true TP serving).

Four layers of coverage, mirroring tests/test_serve_sharded.py:

  * pure-Python rule machinery: the rule set emitted by Auto Distribution's
    SBP cost model is *total* (every param leaf in every transformer arch in
    the zoo matches a rule) and *precise* (norms/routers stay replicated,
    matmul weights carry cost-model-chosen layouts) — shapes only, via
    ``jax.eval_shape``, so the whole zoo runs in the single-device suite;
  * the SBP-choice regression: the search must keep emitting the canonical
    Megatron layout (column in-projections, row out-projections -> one
    collective per layer) and fall back to replicated when dims don't divide;
  * a 1-device-mesh TP engine in the ordinary suite (degenerate but real);
  * >= 4 devices (CI fake-pod lane): identity mode is BITWISE equal to the
    single-device oracle, reduce-scatter mode is fp32-close, and per-device
    param bytes land at ~1/4 of replicated.
"""
import dataclasses

import jax
import numpy as np
import pytest

from benchmarks.bench_serve import _workload
from repro.configs.base import get_config, reduced_config
from repro.distributed.param_sharding import (ShardRule, choose_tp_rules,
                                              set_serve_tp, tp_param_specs,
                                              validate_tp_divisibility)
from repro.launch.mesh import make_serve_mesh
from repro.models import build_model
from repro.serve.engine import ServeEngine

# every registered arch whose params are the stacked-transformer tree the
# rules target (ssm/hybrid/encdec families serve through different code)
ZOO_TRANSFORMERS = ["qwen3-0.6b", "nemotron-4-15b", "phi3-mini-3.8b",
                    "stablelm-3b", "olmoe-1b-7b",
                    "llama4-maverick-400b-a17b", "qwen2-vl-72b"]

REPLICATED_LEAVES = ("ln1", "ln2", "q_norm", "k_norm", "final_norm", "router")


# ---------------------------------------------------------------------------
# Rule totality and precision across the model zoo (shapes only, no mesh)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ZOO_TRANSFORMERS)
def test_rules_cover_every_transformer_config(arch):
    """No unmatched leaf, no over-match: every param in the arch's tree is
    claimed by exactly one rule, matmul weights by a cost-model-emitted
    (``sbp:*``) rule, norms/routers by a structural replicated rule."""
    cfg = reduced_config(get_config(arch))
    fns = build_model(cfg)
    abstract = jax.eval_shape(lambda: fns.init(jax.random.PRNGKey(0)))
    specs, report = tp_param_specs(cfg, abstract, 4)  # raises if non-total

    leaves = jax.tree_util.tree_leaves(abstract)
    assert len(report) == len(leaves)

    for path, rule in report.items():
        last = path.rsplit("/", 1)[-1]
        if last in REPLICATED_LEAVES:
            assert rule.trailing == (), \
                f"{path} over-matched a sharding rule ({rule.name})"
            assert rule.source.startswith("structural"), (path, rule)
        if "/attn/" in path and last in ("wq", "wk", "wv"):
            assert rule.name == "attn_qkv" and rule.source.startswith("sbp:")
        if "/attn/" in path and last == "wo":
            assert rule.name == "attn_out" and rule.source.startswith("sbp:")

    # a weight is sharded over at most ONE mesh axis entry
    for spec in jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)):
        assert sum(1 for e in spec if e == "model") <= 1, spec


def test_unmatched_leaf_raises():
    """A custom rule list without the catch-all must fail loudly on the
    first unclaimed param, not silently replicate it."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    abstract = jax.eval_shape(lambda: fns.init(jax.random.PRNGKey(0)))
    only_attn = [ShardRule("attn_qkv", ("attn", "w[qkv]"),
                           (None, "model"), "sbp:column")]
    with pytest.raises(ValueError, match="no sharding rule matched"):
        tp_param_specs(cfg, abstract, 4, rules=only_attn)


def test_rule_window_is_contiguous():
    """The redco-style matcher anchors on a contiguous key window: the
    shared-expert MLP under ``moe/shared`` must hit the mlp rules (via the
    ``mlp|shared`` alternation), never the expert-table rules."""
    cfg = reduced_config(get_config("llama4-maverick-400b-a17b"))
    fns = build_model(cfg)
    abstract = jax.eval_shape(lambda: fns.init(jax.random.PRNGKey(0)))
    _, report = tp_param_specs(cfg, abstract, 4)
    expert_in = [r.name for p, r in report.items()
                 if "/moe/" in p and "/shared/" not in p and "wi" in p]
    assert expert_in and set(expert_in) == {"moe_expert_in"}
    shared = [r.name for p, r in report.items() if "/shared/" in p]
    assert shared and all(n.startswith("mlp") for n in shared), shared
    routers = [r.name for p, r in report.items() if p.endswith("router")]
    assert routers and set(routers) == {"moe_router"}


def test_divisibility_validation():
    cfg = reduced_config(get_config("qwen3-0.6b"))   # GQA: kv=2
    with pytest.raises(ValueError, match="n_kv_heads"):
        validate_tp_divisibility(cfg, 4)
    validate_tp_divisibility(cfg, 1)                 # width 1 is always fine
    validate_tp_divisibility(
        dataclasses.replace(cfg, n_kv_heads=4), 4)   # widened: fine
    with pytest.raises(ValueError, match="d_ff"):
        validate_tp_divisibility(
            dataclasses.replace(cfg, n_kv_heads=4, d_ff=130), 4)


# ---------------------------------------------------------------------------
# The SBP cost-model choice itself (regression on the emitted layout)
# ---------------------------------------------------------------------------

def test_sbp_search_emits_megatron_layout():
    """Auto Distribution, given the per-block weight-memory cap and true
    input-traffic costs, must *discover* the canonical TP layout: column
    in-projections (no collective) + row out-projections (one partial-sum
    all-reduce per layer).  This is the PR's 'rules are emitted, not
    hard-coded' property — if the cost model regresses to a layout that
    needs a collective per matmul, this fails."""
    from repro.core.distribution import choose_tp_layout
    plan = choose_tp_layout(d_model=64, q_dim=64, d_ff=128, vocab=256,
                            n_model=4)
    kinds = {name: c.kind for name, c in plan.choices.items()}
    assert kinds == {"wq": "column", "wo": "row",
                     "wi": "column", "wdown": "row",
                     "wu": "column"}
    assert not plan.fallback
    assert plan.cost > 0
    # sum of per-device peaks over the three blocks: ~1/4 of the weights
    assert plan.peak_memory == 14336


def test_sbp_search_falls_back_when_indivisible():
    from repro.core.distribution import choose_tp_layout
    plan = choose_tp_layout(d_model=64, q_dim=64, d_ff=100, vocab=256,
                            n_model=3)
    assert set(plan.fallback) == {"attn", "mlp", "head"}
    assert all(c.kind == "replicated" for c in plan.choices.values())


def test_rules_carry_sbp_provenance():
    """choose_tp_rules translates the search result 1:1 — the matmul rules'
    sources and trailing specs are the cost model's kinds, and the tied
    embedding inherits the head choice transposed onto its (vocab, d)."""
    cfg = reduced_config(get_config("qwen3-0.6b"))
    assert cfg.tie_embeddings
    by_name = {r.name: r for r in choose_tp_rules(cfg, 4)}
    assert by_name["attn_qkv"].source == "sbp:column"
    assert by_name["attn_qkv"].trailing == (None, "model")
    assert by_name["attn_out"].source == "sbp:row"
    assert by_name["attn_out"].trailing == ("model", None)
    assert by_name["mlp_in"].trailing == (None, "model")
    assert by_name["mlp_out"].trailing == ("model", None)
    # head chose column on the logical (d, vocab) -> vocab-sharded table
    assert by_name["embed_tied"].source == "sbp:column"
    assert by_name["embed_tied"].trailing == ("model", None)
    assert by_name["replicated_rest"].patterns == (".*",)


# ---------------------------------------------------------------------------
# 1-device mesh: the TP engine in the ordinary single-device suite
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _run(cfg, params, mesh, n=12, **eng_kw):
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, block_size=8,
                      plan_kernels=False, mesh=mesh, **eng_kw)
    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert len(finished) == n
    return [tuple(r.out) for r in reqs], eng


def test_one_device_tp_engine_matches_plain(setup):
    """tp=True on a 1-device mesh runs the whole TP path (rule choice,
    device_put with specs, use-site constraints) degenerately — outputs
    must be identical and the per-device bytes equal the total."""
    cfg, fns, params = setup
    plain, _ = _run(cfg, params, mesh=False)
    tp, eng = _run(cfg, params, mesh=make_serve_mesh(1), tp=True)
    assert tp == plain
    assert eng.tp and eng.tp_report is not None
    assert eng.tp_report["layers/0/attn/wq"].name == "attn_qkv"
    m = eng.metrics()
    assert m.tp_devices == 1
    assert m.param_bytes_per_device == m.param_bytes_replicated > 0


def test_tp_off_by_default(setup):
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      plan_kernels=False, mesh=make_serve_mesh(1))
    assert not eng.tp and eng.tp_report is None
    assert eng.metrics().tp_devices == 1


def test_serve_tp_knob(setup, monkeypatch):
    """REPRO_SERVE_TP=1 turns a mesh-backed engine tensor-parallel without
    code changes; without a mesh the knob is inert."""
    cfg, fns, params = setup
    monkeypatch.setenv("REPRO_SERVE_TP", "1")
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      plan_kernels=False, mesh=make_serve_mesh(1))
    assert eng.tp and eng.tp_report is not None
    meshless = ServeEngine(cfg, params, max_batch=2, max_len=32,
                           block_size=4, plan_kernels=False, mesh=False)
    assert not meshless.tp


# ---------------------------------------------------------------------------
# >= 4 devices in-process (CI fake-pod lane)
# ---------------------------------------------------------------------------

needs_pod = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def pod_setup():
    # the qwen3 smoke config's GQA kv=2 can't split 4 ways; widen to MHA 4/4
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-0.6b")),
                              n_kv_heads=4)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


@needs_pod
def test_pod_tp_identity_and_memory(pod_setup):
    """Acceptance: identity mode on a fake 4-device pod is token-identical
    to the single-device oracle AND each device stores ~1/4 of the params
    (<= 30% — the norms stay replicated)."""
    cfg, fns, params = pod_setup
    plain, _ = _run(cfg, params, mesh=False)
    tp, eng = _run(cfg, params, mesh=make_serve_mesh(4), tp=True)
    assert tp == plain
    m = eng.metrics()
    assert m.tp_devices == 4 and m.mesh_devices == 4
    ratio = m.param_bytes_per_device / m.param_bytes_replicated
    assert 0.25 <= ratio <= 0.30, \
        f"per-device bytes {ratio:.1%} of replicated"
    # the weights really are mesh-placed column/row
    wq = eng.params["layers"][0]["attn"]["wq"]
    assert wq.sharding.spec[-1] == "model"
    wo = eng.params["layers"][0]["attn"]["wo"]
    assert wo.sharding.spec[-2] == "model"


@needs_pod
def test_pod_tp_rejects_indivisible_config(pod_setup):
    cfg, fns, params = pod_setup
    bad = dataclasses.replace(cfg, n_kv_heads=2)
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeEngine(bad, params, max_batch=2, max_len=32, block_size=4,
                    plan_kernels=False, mesh=make_serve_mesh(4), tp=True)


@needs_pod
def test_pod_reduce_scatter_mode_is_fp32_close(pod_setup):
    """REPRO_TP_REDUCE_SCATTER=1 computes through the stored column/row
    layout (partial sums -> one all-reduce per layer): prefill logits on
    rule-sharded params must match the replicated forward within fp32
    tolerance — the reduction is reordered, so bitwise is not expected."""
    from repro.distributed.sharding import to_named
    cfg, fns, params = pod_setup
    mesh = make_serve_mesh(4)
    specs, _ = tp_param_specs(cfg, params, 4)
    sharded = jax.device_put(params, to_named(specs, mesh))

    toks = jax.random.randint(jax.random.PRNGKey(7), (2, 8), 1, cfg.vocab)
    _, ref = fns.prefill(params, {"tokens": toks})
    set_serve_tp(mesh, reduce_scatter=True)
    try:
        _, got = fns.prefill(sharded, {"tokens": toks})
    finally:
        set_serve_tp(None)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=1e-4, atol=1e-5)

    # and identity mode through the same direct path is exactly equal
    set_serve_tp(mesh, reduce_scatter=False)
    try:
        _, exact = fns.prefill(sharded, {"tokens": toks})
    finally:
        set_serve_tp(None)
    assert np.array_equal(np.asarray(exact), np.asarray(ref))
