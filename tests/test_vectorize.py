"""Auto Vectorize (§3.1.2): MetaPackOperation + FoldNopPack + pass-through."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.codegen import compile_term
from repro.core.tensor_ir import binary, inp, matmul, unary
from repro.core.vectorize import auto_vectorize, count_ops


def test_fig3_pass_through_layout():
    Q, K, V = inp("Q", (1024, 128)), inp("K", (128, 1024)), inp("V", (1024, 128))
    term = matmul(unary(matmul(Q, K), kind="exp"), V)
    cost, packed, stats = auto_vectorize(term)
    # all three compute ops run packed; pack only at inputs, unpack at output
    assert count_ops(packed, "packed_matmul") == 2
    assert count_ops(packed, "packed_unary") == 1
    assert count_ops(packed, "matmul") == 0
    assert count_ops(packed, "pack") == 3
    assert count_ops(packed, "unpack") == 1
    assert cost < stats["baseline_cost"]


def test_packing_preserves_semantics():
    rng = np.random.default_rng(1)
    Q, K, V = inp("Q", (256, 128)), inp("K", (128, 256)), inp("V", (256, 128))
    term = matmul(unary(matmul(Q, K), kind="exp"), V)
    _, packed, _ = auto_vectorize(term)
    env = {"Q": jnp.array(rng.normal(size=(256, 128)) * 0.1, jnp.float32),
           "K": jnp.array(rng.normal(size=(128, 256)) * 0.1, jnp.float32),
           "V": jnp.array(rng.normal(size=(256, 128)) * 0.1, jnp.float32)}
    ref = compile_term(term)(**env)
    out = compile_term(packed)(**env)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_indivisible_shapes_stay_logical():
    # 100 is not divisible by any lane config: no packed variants exist
    x, y = inp("x", (100, 100)), inp("y", (100, 100))
    term = binary(x, y, kind="add")
    cost, packed, _ = auto_vectorize(term)
    assert count_ops(packed, "pack") == 0


@given(st.sampled_from([128, 256, 512]), st.sampled_from([128, 256]),
       st.sampled_from(["exp", "relu"]))
@settings(max_examples=8, deadline=None)
def test_vectorize_cost_never_worse(m, n, kind):
    x = inp("x", (m, n))
    w = inp("w", (n, m))
    term = matmul(unary(matmul(x, w), kind=kind), inp("v", (m, n)))
    cost, packed, stats = auto_vectorize(term, use_sat=False)
    assert cost <= stats["baseline_cost"] + 1e-15
