"""Mesh-sharded KV block pool: multi-device paged serving.

Three layers of coverage, because device counts are process-wide in jax:

  * in-process tests on a 1-device serve mesh — the shard_map machinery,
    NamedSharding slab, and knob plumbing run in the ordinary single-device
    suite (a model-axis of 1 is a degenerate but real mesh);
  * in-process tests that need a real multi-device view — skipped unless the
    process already sees >= 4 devices (CI's fake-pod lane sets
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` before pytest);
  * one slow subprocess test that forces the 4-device fake pod itself, so
    the full tier-1 suite verifies the multi-device oracle even when the
    parent process is single-device.

The oracle property throughout: a sharded engine's outputs are TOKEN-
IDENTICAL to an unsharded engine on the same params/workload.  This is by
construction, not tolerance — the pool is sharded per KV head and no
floating-point reduction crosses a shard (see repro.models.attention).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import pytest

from benchmarks.bench_serve import _workload
from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_serve_mesh
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine

ROOT = Path(__file__).resolve().parents[1]


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _run(cfg, params, mesh, n=12, **eng_kw):
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, block_size=8,
                      plan_kernels=False, mesh=mesh, **eng_kw)
    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert len(finished) == n
    return [tuple(r.out) for r in reqs], eng


# ---------------------------------------------------------------------------
# 1-device mesh: runs in the ordinary single-device suite
# ---------------------------------------------------------------------------

def test_one_device_mesh_matches_unsharded_oracle(setup):
    """The 12-request acceptance workload through a 1-device serve mesh
    (NamedSharding slab + shard_map attention) is token-identical to the
    plain engine."""
    cfg, fns, params = setup
    plain, _ = _run(cfg, params, mesh=False)   # knob-immune oracle
    sharded, eng = _run(cfg, params, mesh=make_serve_mesh(1))
    assert sharded == plain
    m = eng.metrics()
    assert m.mesh_devices == 1
    assert m.re_prefill_avoided > 0, "prefix sharing must survive sharding"
    # the slab really is mesh-placed
    spec = eng.cache["k"].sharding.spec
    assert spec[-2] == "model", f"kv-heads axis not sharded: {spec}"
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0


def test_preemption_by_swap_under_sharded_tier(setup):
    """Optimistic overcommit on a sharded pool: preemption parks per-shard
    block slices on the (replicated-on-host) host tier and restores them,
    resuming token-for-token."""
    cfg, fns, params = setup

    def solo(prompt, max_new):
        eng = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                          plan_kernels=False, prefix_cache_blocks=0,
                          mesh=False)
        r = Request(rid=0, prompt=list(prompt), max_new=max_new)
        eng.submit(r)
        eng.run_until_done()
        return r.out

    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic",
                      plan_kernels=False, mesh=make_serve_mesh(1))
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    m = eng.metrics()
    assert m.preemptions >= 1 and m.swap_out_blocks > 0
    assert m.swap_in_blocks == m.swap_out_blocks
    for r in reqs:
        assert r.out == solo(r.prompt, r.max_new), \
            f"rid {r.rid}: sharded swap round-trip changed the output"
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0


def test_serve_mesh_knob(setup, monkeypatch):
    """REPRO_SERVE_MESH=N shards over the first N devices without any code
    change ("0", the default, stays single-device)."""
    cfg, fns, params = setup
    monkeypatch.setenv("REPRO_SERVE_MESH", "1")
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      plan_kernels=False)
    assert eng.mesh is not None
    assert eng.metrics().mesh_devices == 1
    assert eng.cache["k"].sharding.spec[-2] == "model"
    monkeypatch.setenv("REPRO_SERVE_MESH", "0")
    eng2 = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                       plan_kernels=False)
    assert eng2.mesh is None


# ---------------------------------------------------------------------------
# >= 4 devices in-process (CI fake-pod lane)
# ---------------------------------------------------------------------------

needs_pod = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=4)")


@pytest.fixture(scope="module")
def pod_setup():
    # the qwen3 smoke config's GQA kv=2 can't split 4 ways; widen to MHA 4/4
    cfg = dataclasses.replace(reduced_config(get_config("qwen3-0.6b")),
                              n_kv_heads=4)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_indivisible_mesh_rejected(setup):
    """A mesh whose model axis doesn't divide the kv heads must fail loudly
    at construction, not silently mis-shard."""
    cfg, fns, params = setup
    bad = dataclasses.replace(cfg, n_kv_heads=3, n_heads=3)
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeEngine(bad, params, max_batch=2, max_len=32, block_size=4,
                    plan_kernels=False, mesh=make_serve_mesh(2))


@needs_pod
def test_fake_pod_sharded_pool_matches_unsharded_oracle(pod_setup):
    """Acceptance: the 12-request workload on a fake 4-device pod with the
    pool sharded on the heads axis is token-identical to the single-device
    run, and each device holds 1/4 of the kv-heads axis."""
    cfg, fns, params = pod_setup
    plain, _ = _run(cfg, params, mesh=False)   # knob-immune oracle
    sharded, eng = _run(cfg, params, mesh=make_serve_mesh(4))
    assert sharded == plain
    m = eng.metrics()
    assert m.mesh_devices == 4
    assert m.re_prefill_avoided > 0
    k = eng.cache["k"]
    assert len(k.sharding.device_set) == 4
    shard_shapes = {s.data.shape for s in k.addressable_shards}
    assert shard_shapes == {k.shape[:3] + (k.shape[3] // 4, k.shape[4])}
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0


@needs_pod
def test_fake_pod_preemption_by_swap(pod_setup):
    """Preemption-by-swap on the 4-device pod: host round-trips gather and
    re-split the per-shard slices bit-exactly."""
    cfg, fns, params = pod_setup

    def solo(prompt, max_new):
        eng = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                          plan_kernels=False, prefix_cache_blocks=0,
                          mesh=False)
        r = Request(rid=0, prompt=list(prompt), max_new=max_new)
        eng.submit(r)
        eng.run_until_done()
        return r.out

    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic",
                      plan_kernels=False, mesh=make_serve_mesh(4))
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    m = eng.metrics()
    assert m.preemptions >= 1 and m.swap_out_blocks > 0
    assert m.swap_in_blocks == m.swap_out_blocks
    for r in reqs:
        assert r.out == solo(r.prompt, r.max_new)


# ---------------------------------------------------------------------------
# Subprocess fake pod (full tier-1 suite, single-device parent)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_fake_pod_oracle_in_subprocess():
    """Force a 4-device CPU fake pod in a subprocess and run both oracles
    there: workload equivalence and preemption-by-swap equivalence.  This is
    what keeps the multi-device guarantee in the tier-1 suite, whose parent
    process deliberately keeps a single-device view."""
    code = textwrap.dedent("""
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
    os.environ['JAX_PLATFORMS'] = 'cpu'
    import dataclasses, json
    import jax
    from benchmarks.bench_serve import _workload
    from repro.configs.base import get_config, reduced_config
    from repro.launch.mesh import make_serve_mesh
    from repro.models import build_model
    from repro.serve.engine import Request, ServeEngine

    cfg = dataclasses.replace(reduced_config(get_config('qwen3-0.6b')),
                              n_kv_heads=4)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))

    def run(mesh):
        eng = ServeEngine(cfg, params, max_batch=4, max_len=64, block_size=8,
                          plan_kernels=False, mesh=mesh)
        reqs = _workload(cfg, 12)
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        return [list(r.out) for r in reqs], eng

    plain, _ = run(False)
    sharded, eng = run(make_serve_mesh(4))

    # preemption-by-swap under the sharded tier
    peng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                       num_blocks=7, admission='optimistic',
                       plan_kernels=False, mesh=make_serve_mesh(4))
    preqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
             for i in range(2)]
    for r in preqs:
        peng.submit(r)
    peng.run_until_done()
    pm = peng.metrics()

    def solo(prompt, max_new):
        e = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                        plan_kernels=False, prefix_cache_blocks=0, mesh=False)
        r = Request(rid=0, prompt=list(prompt), max_new=max_new)
        e.submit(r); e.run_until_done(); return list(r.out)

    print(json.dumps({
        'identical': sharded == plain,
        'mesh_devices': eng.metrics().mesh_devices,
        'prefix_reuse': eng.metrics().re_prefill_avoided,
        'preemptions': pm.preemptions,
        'swap_out': pm.swap_out_blocks, 'swap_in': pm.swap_in_blocks,
        'preempt_identical': all(list(r.out) == solo(r.prompt, r.max_new)
                                 for r in preqs),
    }))
    """)
    # repo root on PYTHONPATH too: the script reuses the bench workload
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": f"{ROOT / 'src'}:{ROOT}",
                            "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["identical"], "sharded pod output diverged from single-device"
    assert out["mesh_devices"] == 4
    assert out["prefix_reuse"] > 0
    assert out["preemptions"] >= 1 and out["swap_out"] > 0
    assert out["swap_in"] == out["swap_out"]
    assert out["preempt_identical"], "swap round-trip diverged on the pod"
