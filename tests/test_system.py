"""End-to-end system behaviour: the three passes composed + the jax bridge +
hypothesis invariants over the whole rewrite->extract->codegen path."""
import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.codegen import compile_term
from repro.core.distribution import auto_distribute, ndsbp_to_pspec, build_distributed_egraph
from repro.core.egraph import EGraph
from repro.core.extraction import greedy_extract, extract_term
from repro.core.rewrite import TRANSPOSE_RULES
from repro.core.sbp import Placement
from repro.core.tensor_ir import binary, inp, matmul, term_shape, transpose, unary
from repro.core.vectorize import VECTORIZE_RULES, auto_vectorize


def test_pipeline_vectorize_then_codegen_jit():
    """auto_vectorize -> compile_term -> jax.jit executes and matches."""
    rng = np.random.default_rng(0)
    Q, K, V = inp("Q", (256, 128)), inp("K", (128, 256)), inp("V", (256, 128))
    term = matmul(unary(matmul(Q, K), kind="exp"), V)
    _, packed, _ = auto_vectorize(term)
    f = jax.jit(compile_term(packed))
    env = {n: jnp.array(rng.normal(size=s) * 0.1, jnp.float32)
           for n, s in [("Q", (256, 128)), ("K", (128, 256)), ("V", (256, 128))]}
    out = f(**env)
    ref = compile_term(term)(**env)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_distribution_plan_drives_pjit():
    """The SBP plan's PartitionSpecs work as real in_shardings."""
    pl = Placement(("data", "model"), (1, 1))
    x = inp("x", (64, 32))
    w = inp("w", (32, 64))
    term = matmul(x, w)
    plan = auto_distribute(term, pl, use_sat=False)
    dg = build_distributed_egraph(term, pl)
    name_to_spec = {}
    for tid, nd in plan.assignments.items():
        t = dg.terms[tid]
        if t.op == "input":
            shape = term_shape(t)
            name_to_spec[t.attr("name")] = ndsbp_to_pspec(nd, pl, len(shape))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    xs = jnp.ones((64, 32))
    ws = jnp.ones((32, 64))
    f = jax.jit(lambda a, b: a @ b,
                in_shardings=(NamedSharding(mesh, name_to_spec["x"]),
                              NamedSharding(mesh, name_to_spec["w"])))
    with mesh:
        out = f(xs, ws)
    assert out.shape == (64, 64)


# -- hypothesis: random term DAGs survive saturation + extraction ------------

@st.composite
def random_term(draw):
    dim = draw(st.sampled_from([8, 16]))
    depth = draw(st.integers(1, 4))
    t = inp("A", (dim, dim))
    names = iter("BCDEFG")
    for _ in range(depth):
        op = draw(st.sampled_from(["transpose", "unary", "binary"]))
        if op == "transpose":
            t = transpose(t, (1, 0))
        elif op == "unary":
            t = unary(t, kind=draw(st.sampled_from(["exp", "relu", "neg"])))
        else:
            other = inp(next(names), term_shape(t))
            t = binary(t, other, kind=draw(st.sampled_from(["add", "mul"])))
    return t


@given(random_term())
@settings(max_examples=25, deadline=None)
def test_saturation_preserves_semantics(term):
    eg = EGraph()
    root = eg.add_term(term)
    base_cost, _ = greedy_extract(eg, root)
    eg.saturate(TRANSPOSE_RULES + VECTORIZE_RULES, max_iters=4,
                node_limit=1500)
    cost, choice = greedy_extract(eg, root)
    assert cost <= base_cost + 1e-15
    out_term = extract_term(eg, root, choice)
    assert term_shape(out_term) == term_shape(term)
    # numeric equivalence
    rng = np.random.default_rng(7)
    names = set()

    def collect(t):
        if t.op == "input":
            names.add((t.attr("name"), term_shape(t)))
        for c in t.children:
            collect(c)
    collect(term)
    env = {n: jnp.array(rng.normal(size=s) * 0.3, jnp.float32)
           for n, s in names}
    a = compile_term(term)(**env)
    b = compile_term(out_term)(**env)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-3, atol=1e-3)
