"""Multi-device behaviours that need >1 device: run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count so the main test process
keeps its single-device view (the dry-run rule from the assignment).

Each subprocess pays a cold jax import + 8-device compile (~8 min apiece on
the CI runner), so the whole module is marked slow — the full `test` job
still runs it; the fast lane (-m "not slow") skips it."""
import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow

ROOT = Path(__file__).resolve().parents[1]


def _run(script: str, n_devices: int = 8) -> dict:
    code = ("import os\n"
            f"os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={n_devices}'\n"
            + textwrap.dedent(script))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600,
                       env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_elastic_remesh_shrinks_data_axis():
    out = _run("""
    import json, jax
    from repro.distributed.fault_tolerance import elastic_remesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    m2 = elastic_remesh(mesh, lost_hosts=1)
    print(json.dumps({"shape": dict(m2.shape), "n": int(m2.devices.size)}))
    """)
    assert out["shape"] == {"data": 3, "model": 2}
    assert out["n"] == 6


def test_sharded_train_step_runs_on_8_devices():
    """One REAL sharded train step (not just lowering) on a 4x2 mesh."""
    out = _run("""
    import json, jax, jax.numpy as jnp
    from repro.configs.base import get_config, reduced_config
    from repro.distributed import sharding as shd
    from repro.launch.steps import make_train_step
    from repro.models import build_model

    cfg = reduced_config(get_config("qwen3-0.6b"))
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    fns = build_model(cfg)
    step, opt = make_train_step(cfg, remat=False)
    with mesh:
        params = fns.init(jax.random.PRNGKey(0))
        pspecs = shd.param_specs(cfg, params, mesh)
        opt_state = opt.init(params)
        ospecs = shd.opt_state_specs(pspecs, jax.eval_shape(lambda: opt_state), mesh)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0, cfg.vocab)
        batch = {"tokens": toks, "labels": toks}
        bspecs = shd.batch_specs(cfg, batch, mesh)
        f = jax.jit(step,
                    in_shardings=(shd.to_named(pspecs, mesh),
                                  shd.to_named(ospecs, mesh),
                                  shd.to_named(bspecs, mesh)))
        params = jax.device_put(params, shd.to_named(pspecs, mesh))
        opt_state = jax.device_put(opt_state, shd.to_named(ospecs, mesh))
        batch = jax.device_put(batch, shd.to_named(bspecs, mesh))
        p2, o2, metrics = f(params, opt_state, batch)
        loss = float(metrics["loss"])
    print(json.dumps({"loss": loss, "finite": bool(loss == loss)}))
    """)
    assert out["finite"]
    assert 0 < out["loss"] < 100


def test_dryrun_cell_runner_small_mesh():
    """The dry-run analysis pipeline end-to-end on a synthetic 8-dev mesh."""
    out = _run("""
    import json, jax, time
    import repro.launch.mesh as mesh_mod
    # shrink the production mesh for the test host
    mesh_mod.make_production_mesh = lambda multi_pod=False: jax.make_mesh(
        (2, 2, 2) if multi_pod else (4, 2),
        ("pod", "data", "model") if multi_pod else ("data", "model"))
    import repro.launch.dryrun as dr
    from pathlib import Path
    import tempfile
    import repro.configs.base as cb
    import dataclasses
    # tiny shape so the compile is fast
    cb.SHAPES["tiny_train"] = cb.ShapeSpec("tiny_train", 64, 8, "train")
    import repro.configs  # register archs
    cfg = cb.get_config("qwen3-0.6b")
    cb._REGISTRY["tiny-arch"] = lambda: dataclasses.replace(
        cb.reduced_config(cfg), name="tiny-arch")
    with tempfile.TemporaryDirectory() as d:
        res = dr.run_cell("tiny-arch", "tiny_train", "pod2",
                          Path(d) / "out.json")
    print(json.dumps({"status": res["status"],
                      "devices": res["devices"],
                      "flops": res["hlo_flops_per_device"],
                      "bottleneck": res["roofline"]["bottleneck"]}))
    """)
    assert out["status"] == "ok"
    assert out["devices"] == 8
    assert out["flops"] > 0
