"""Tiered KVStore bookkeeping: refcounts, copy-on-write, host swap, and the
prefix registry — pure Python against stub data planes, no jax import."""
import pytest

from repro.serve.kv_store import (DEVICE, HOST, BlockTable, DeviceTier,
                                  HostTier, KVStore)
from repro.serve.paged_cache import BlockPool, PoolExhausted


def make_store(num_blocks=9, block_size=4, host_blocks=8,
               prefix_cache_blocks=0):
    """A KVStore over a stub device tier: the 'cache' is a plain
    {idx: payload} dict threaded functionally, standing in for the jax slab."""
    def _copy(cache, src, dst):
        c = dict(cache)
        c[dst] = c.get(src)
        return c

    def _read(cache, idx):
        return cache.get(idx)

    def _write(cache, idx, data):
        c = dict(cache)
        c[idx] = data
        return c

    device = DeviceTier({}, BlockPool(num_blocks, block_size),
                        copy_block=_copy, read_block=_read, write_block=_write)
    return KVStore(device, HostTier(host_blocks),
                   prefix_cache_blocks=prefix_cache_blocks)


def put(store, block, payload):
    store.device.cache = {**store.device.cache, block.idx: payload}


def get(store, block):
    return store.device.cache.get(block.idx)


def test_refcount_lifecycle():
    store = make_store()
    b = store.alloc()
    assert b.tier == DEVICE and b.refcount == 1 and not b.shared
    used0 = store.device.pool.num_used
    (b2,) = store.fork([b])
    assert b2 is b and b.refcount == 2 and b.shared
    assert store.shared_blocks == 1
    store.decref(b)
    assert b.refcount == 1
    assert store.device.pool.num_used == used0, "shared decref must not free"
    store.decref(b)
    assert store.device.pool.num_used == used0 - 1, "last ref frees the block"
    with pytest.raises(ValueError):
        store.decref(b)
    with pytest.raises(ValueError):
        store.incref(b)


def test_cow_privatizes_shared_block():
    store = make_store()
    b = store.alloc()
    put(store, b, "prefix-kv")
    store.fork([b])                       # a second holder appears
    with pytest.raises(ValueError):
        # exclusive blocks are written in place, never CoW'd
        store.cow_into(store.alloc(), store.alloc())
    dst = store.alloc()
    mine = store.cow_into(b, dst)
    assert mine is dst and mine.refcount == 1
    assert b.refcount == 1, "CoW drops the writer's ref on the original"
    assert get(store, mine) == "prefix-kv", "copy carries the data"
    assert store.cow_copies == 1
    put(store, mine, "diverged")
    assert get(store, b) == "prefix-kv", "sharers never see the write"


def test_swap_round_trip_preserves_data():
    store = make_store()
    b = store.alloc()
    put(store, b, "cold-kv")
    used0, host0 = store.device.pool.num_used, store.host.num_used
    h = store.swap_out(b)
    assert h.tier == HOST
    assert store.device.pool.num_used == used0 - 1, "device slot came free"
    assert store.host.num_used == host0 + 1
    assert store.swapped_out == 1
    dst = store.alloc()
    back = store.swap_in(h, dst)
    assert back is dst and back.tier == DEVICE
    assert get(store, back) == "cold-kv", "swap round-trips the payload"
    assert store.host.num_used == host0, "host slot released on restore"
    assert store.swapped_in == 1


def test_swap_out_keeps_shared_blocks_resident():
    store = make_store()
    b = store.alloc()
    store.fork([b])                       # e.g. the prefix registry holds it
    same = store.swap_out(b)
    assert same is b and same.tier == DEVICE, \
        "a shared block is pinned on-device by its other holder"
    assert store.swapped_out == 0
    assert store.can_swap_out([b]), "shared blocks don't consume host space"


def test_parked_release_frees_host_blocks_keeps_registry_refs():
    """Cancelling/expiring a parked (preempted) request decrefs its block
    list — exactly what the engine's _drop_parked does.  Its exclusive
    host-tier blocks must return to the host pool; a block the prefix
    registry also holds survives with the registry's ref intact."""
    store = make_store(num_blocks=9, block_size=4, prefix_cache_blocks=4)
    shared = store.alloc()                # prompt block, registry-held too
    put(store, shared, "prefix-kv")
    assert store.register_prefix(list(range(100, 104)), [shared])
    tail = store.alloc()                  # exclusive generation tail
    put(store, tail, "tail-kv")
    parked = [shared, store.swap_out(tail)]
    assert parked[0].tier == DEVICE, "shared block pinned resident"
    assert parked[1].tier == HOST and store.host.num_used == 1
    # the parked holder goes away (cancel / deadline expiry)
    for b in parked:
        store.decref(b)
    assert store.host.num_used == 0, "parked host blocks must be freed"
    assert shared.refcount == 1, "registry's reference survives"
    n, got = store.match_prefix(list(range(100, 104)))
    assert n == 4 and got[0] is shared, "prefix stays servable"


def test_injected_swap_faults_fire_at_entry_leaving_ledgers_clean():
    """Fault hooks sit at operation entry, before any bookkeeping mutates:
    a fired swap fault must leave device/host ledgers exactly as they were
    (that's what makes the KV-leak invariants enforceable under chaos).
    The shared-block swap_out early-return doesn't even reach the hook."""
    from repro.serve.faults import FaultInjector, InjectedFault

    store = make_store()
    store.fault_injector = FaultInjector.parse("swap_out:exc=1,swap_in:exc=1")
    b = store.alloc()
    put(store, b, "kv")
    used0, host0 = store.device.pool.num_used, store.host.num_used
    with pytest.raises(InjectedFault):
        store.swap_out(b)
    assert b.tier == DEVICE and b.refcount == 1
    assert store.device.pool.num_used == used0
    assert store.host.num_used == host0
    h = store.swap_out(b)                 # rule exhausted: works now
    dst = store.alloc()
    with pytest.raises(InjectedFault):
        store.swap_in(h, dst)
    assert h.tier == HOST and store.host.num_used == host0 + 1
    assert store.swap_in(h, dst) is dst
    assert get(store, dst) == "kv"
    # a shared block short-circuits before the injection point
    store.fault_injector = FaultInjector.parse("swap_out:p=1.0")
    s = store.alloc()
    store.fork([s])
    assert store.swap_out(s) is s, "early-return must not consume a check"


def test_injected_alloc_fault_leaves_pool_ledger_clean():
    from repro.serve.faults import FaultInjector, InjectedFault

    pool = BlockPool(5, 4)
    pool.fault_injector = FaultInjector.parse("alloc:after=1")
    blk = pool.alloc()
    free0, reserved0 = pool.num_free, pool.num_reserved
    with pytest.raises(InjectedFault):
        pool.alloc()
    assert pool.num_free == free0 and pool.num_reserved == reserved0
    pool.free([blk, pool.alloc()])        # both allocs accounted, no leak
    assert pool.num_used == 0


def test_host_tier_exhaustion_and_double_free():
    store = make_store(host_blocks=1)
    a, b = store.alloc(), store.alloc()
    store.swap_out(a)
    with pytest.raises(PoolExhausted):
        store.swap_out(b)
    assert not store.can_swap_out([b])
    with pytest.raises(ValueError):
        store.host.free(99)


def test_prefix_registry_match_and_budget():
    store = make_store(num_blocks=17, block_size=4, prefix_cache_blocks=3)
    blocks = [store.alloc() for _ in range(3)]
    tokens = list(range(100, 110))        # 10 tokens over 3 blocks (bs=4)
    assert store.register_prefix(tokens, blocks)
    assert not store.register_prefix(tokens, blocks), \
        "an already-covered prefix is not re-registered"
    # full match
    n, got = store.match_prefix(tokens)
    assert n == 10 and [g.idx for g in got] == [b.idx for b in blocks]
    # partial match stops at the first diverging token
    n, got = store.match_prefix(tokens[:6] + [999, 999])
    assert n == 6 and len(got) == 2
    # no match
    assert store.match_prefix([1, 2, 3]) == (0, [])
    # the registry holds its own refs: callers fork, registry survives decref
    mine = store.fork(got)
    for b in mine:
        store.decref(b)
    assert store.match_prefix(tokens)[0] == 10


def test_prefix_registry_truncates_to_budget_and_evicts_lru():
    store = make_store(num_blocks=33, block_size=4, prefix_cache_blocks=4)
    a_blocks = [store.alloc() for _ in range(3)]
    store.register_prefix(list(range(12)), a_blocks)
    # a 6-block prompt is truncated to the 4-block budget (evicting A first)
    b_blocks = [store.alloc() for _ in range(6)]
    store.register_prefix(list(range(50, 74)), b_blocks)
    assert store.num_prefixes == 1
    n, got = store.match_prefix(list(range(50, 74)))
    assert n == 16, "truncated entry still shares its first budget*bs tokens"
    assert len(got) == 4
    # entry A's blocks were released back to exclusivity
    assert all(b.refcount == 1 for b in a_blocks)


def test_evict_prefixes_frees_only_unheld_blocks():
    store = make_store(num_blocks=9, block_size=4, prefix_cache_blocks=8)
    held = store.alloc()                  # also lives in a request's table
    loose = store.alloc()
    store.register_prefix([1, 2, 3, 4, 5], [held, loose])
    store.decref(loose)                   # its request retired; registry remains
    used0 = store.device.pool.num_used
    freed = store.evict_prefixes(2)
    assert freed == 1, "the table-held block stays allocated"
    assert store.device.pool.num_used == used0 - 1
    assert held.refcount == 1
    assert store.num_prefixes == 0
    assert store.evict_prefixes(1) == 0, "empty registry can't help"


def test_drop_prefixes_drains_everything():
    store = make_store(num_blocks=17, block_size=4, prefix_cache_blocks=8)
    for base in (0, 100):
        blocks = [store.alloc(), store.alloc()]
        store.register_prefix([base + i for i in range(8)], blocks)
        for b in blocks:                  # the registering request retires
            store.decref(b)
    assert store.num_prefixes == 2
    store.drop_prefixes()
    assert store.num_prefixes == 0 and store.device.pool.num_used == 0


def test_block_table_padded_and_release():
    store = make_store()
    t = BlockTable(block_size=4)
    t.blocks = [store.alloc() for _ in range(2)]
    assert t.capacity == 8
    ids = t.block_ids()
    assert t.padded(4) == ids + [0, 0]
    with pytest.raises(ValueError):
        t.padded(1)
    # a host-tier handle must never reach device-side batching
    t.blocks.append(store.swap_out(store.alloc()))
    with pytest.raises(AssertionError):
        t.padded(4)
    t.blocks.pop()
    t.release_to(store)
    assert t.blocks == [] and store.device.pool.num_used == 0


def test_tables_stay_disjoint_and_fork_aliases():
    """Two requests growing interleaved never collide physically; a forked
    table aliases the same physical blocks until CoW diverges them."""
    store = make_store(num_blocks=17, block_size=4)
    ta, tb = BlockTable(4), BlockTable(4)
    for n in range(1, 12):
        while ta.capacity < n:
            ta.blocks.append(store.alloc())
        while tb.capacity < max(n - 3, 0):
            tb.blocks.append(store.alloc())
    assert not set(ta.block_ids()) & set(tb.block_ids())
    shared = BlockTable(4, blocks=store.fork(ta.blocks[:2]))
    assert shared.block_ids() == ta.block_ids()[:2], "fork aliases physically"
    dst = store.alloc()
    shared.blocks[1] = store.cow_into(shared.blocks[1], dst)
    assert shared.block_ids()[1] != ta.block_ids()[1], "CoW diverges"
    assert all(b.refcount == 2 for b in ta.blocks[:1])
    shared.release_to(store)
    ta.release_to(store)
    tb.release_to(store)
    assert store.device.pool.num_used == 0
