"""Sharding policy: PartitionSpec rules, divisibility guards, constrain()."""
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.distributed import sharding as shd
from repro.models.model_zoo import abstract_params


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_param_specs_shapes_match():
    cfg = get_config("qwen3-0.6b")
    params = abstract_params(cfg)
    specs = shd.param_specs(cfg, params, _mesh11())
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for p, s in zip(flat_p, flat_s):
        assert isinstance(s, P)
        assert len(s) <= len(p.shape)


def test_divisibility_guard_drops_axis():
    """whisper's vocab (51865) is not divisible by 16: the 'model' entry on
    the embed table must be dropped on a 16-wide mesh."""
    import numpy as np
    cfg = get_config("whisper-small")
    params = abstract_params(cfg)
    devs = np.array(jax.devices() * 16)[:16].reshape(4, 4) if len(jax.devices()) < 16 \
        else np.array(jax.devices()[:16]).reshape(4, 4)
    # use a fake 4x4 mesh built by repeating the single CPU device: Mesh only
    # validates uniqueness at use, not construction — good enough for specs.
    try:
        mesh = jax.sharding.Mesh(devs, ("data", "model"))
    except ValueError:
        import pytest
        pytest.skip("cannot build 4x4 mesh on this host")
    specs = shd.param_specs(cfg, params, mesh)
    embed_spec = specs["embed"]["embed"]
    assert embed_spec[0] is None  # 51865 % 4 != 0 -> dropped


def test_batch_specs():
    cfg = get_config("qwen3-0.6b")
    mesh = _mesh11()
    batch = {"tokens": jax.ShapeDtypeStruct((8, 128), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
    specs = shd.batch_specs(cfg, batch, mesh)
    assert specs["tokens"] == P("data", None)


def test_cache_specs_gqa_sequence_parallel():
    """KV heads (8) < model axis (16): cache must shard the SEQ dim."""
    import numpy as np
    import pytest
    cfg = get_config("qwen3-0.6b")   # kv=8
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    devs = np.array([jax.devices()[0]] * 16).reshape(1, 16)
    try:
        mesh = jax.sharding.Mesh(devs, ("data", "model"))
    except ValueError:
        pytest.skip("cannot build mesh")
    cache = {"k": jax.ShapeDtypeStruct((28, 4, 512, 8, 128), jnp.bfloat16),
             "v": jax.ShapeDtypeStruct((28, 4, 512, 8, 128), jnp.bfloat16)}
    specs = shd.cache_specs(cfg, cache, mesh)
    assert specs["k"][2] is not None      # seq sharded
    assert specs["k"][3] is None          # kv heads NOT sharded (8 % 16 != 0)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    assert y is x


def test_constrain_applies_in_mesh():
    mesh = _mesh11()
    with mesh:
        def f(x):
            return shd.constrain(x, "batch", "ff")
        out = jax.jit(f)(jnp.ones((4, 8)))
        assert out.shape == (4, 8)


def test_opt_state_specs_mirror_params():
    from repro.train.optimizer import AdamW, AdamWConfig
    cfg = get_config("qwen3-0.6b")
    params = abstract_params(cfg)
    mesh = _mesh11()
    pspecs = shd.param_specs(cfg, params, mesh)
    opt = AdamW(AdamWConfig())
    opt_abs = jax.eval_shape(opt.init, params)
    ospecs = shd.opt_state_specs(pspecs, opt_abs, mesh)
    assert ospecs["step"] == P()
    assert jax.tree.leaves(ospecs["m"], is_leaf=lambda x: isinstance(x, P))
