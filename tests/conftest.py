import os

# Tests run on the single real CPU device (the 512-device force-host flag is
# set ONLY inside repro.launch.dryrun, never globally).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
