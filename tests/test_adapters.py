"""AdapterStore: refcounted LRU slots over a two-tier slab — eviction
order, pin/refcount protection, AdapterStoreFull, host-tier reloads, byte
accounting, and rank validation."""
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.serve.adapters import (AdapterStore, AdapterStoreFull,
                                  adapted_projections, make_lora_params,
                                  seed_for)


@pytest.fixture(scope="module")
def cfg():
    return reduced_config(get_config("qwen3-0.6b"))


def _store(cfg, max_adapters=2, **kw):
    return AdapterStore(cfg, max_adapters=max_adapters, **kw)


def test_load_is_idempotent_and_counts(cfg):
    st = _store(cfg)
    slot = st.load("a")
    assert st.load("a") == slot          # LRU touch, not a second load
    assert st.loads == 1
    assert st.is_loaded("a") and st.known("a")
    assert st.loaded() == ["a"]
    m = st.metrics()
    assert m["adapters_loaded"] == 1 and m["adapter_loads"] == 1


def test_lru_eviction_order(cfg):
    st = _store(cfg, max_adapters=2)
    st.load("a")
    st.load("b")
    st.load("a")                         # touch: b is now least recent
    st.load("c")                         # evicts b, not a
    assert sorted(st.loaded()) == ["a", "c"]
    assert st.evictions == 1
    assert not st.is_loaded("b") and st.known("b")   # host tier keeps it


def test_refcount_blocks_eviction(cfg):
    st = _store(cfg, max_adapters=2)
    st.load("a")
    st.acquire("a")                      # in flight
    st.load("b")
    st.load("c")                         # must evict idle b, never held a
    assert st.is_loaded("a") and st.is_loaded("c")
    st.acquire("c")
    with pytest.raises(AdapterStoreFull):
        st.load("d")                     # every slot in flight
    st.release("a")
    st.load("d")                         # a is idle again -> evictable
    assert sorted(st.loaded()) == ["c", "d"]


def test_pin_blocks_eviction(cfg):
    st = _store(cfg, max_adapters=2)
    st.load("a")
    st.pin("a")
    st.load("b")
    st.load("c")                         # evicts b (a pinned, refcount 0)
    assert st.is_loaded("a")
    st.pin("c")
    with pytest.raises(AdapterStoreFull):
        st.load("d")
    st.unpin("a")
    st.load("d")
    assert sorted(st.loaded()) == ["c", "d"]


def test_host_tier_reload_skips_materialization(cfg):
    st = _store(cfg, max_adapters=1, rank_cap=8)
    st.load("a", rank=4)
    st.load("b")                         # evicts a to the host tier
    assert st.host_reloads == 0
    st.load("a")                         # back from host, same padded bytes
    assert st.host_reloads == 1
    assert st.rank_of("a") == 4          # rank survives the round trip
    # host tier holds BOTH adapters even though only one is resident
    assert st.metrics()["adapters_loaded"] == 1
    assert st.known("b") and not st.is_loaded("b")


def test_byte_accounting(cfg):
    st = _store(cfg, max_adapters=3)
    assert st.device_bytes() == 0        # slab is lazy: no tenants, no slab
    st.load("a")
    dev = st.device_bytes()
    assert dev == st.per_adapter_bytes() * st.max_adapters
    host1 = st.host_bytes()
    assert host1 > 0
    st.load("b")
    assert st.device_bytes() == dev      # slab preallocated all slots
    assert st.host_bytes() == 2 * host1  # write-through copy per adapter
    st.unload("b")
    assert st.host_bytes() == host1      # unload drops BOTH tiers


def test_rank_cap_validation(cfg):
    st = _store(cfg, rank_cap=8)
    assert st.rank_cap == 8
    with pytest.raises(ValueError, match="rank cap"):
        st.load("big", rank=9)
    # sublane padding: odd caps round up to a multiple of 8
    assert _store(cfg, rank_cap=9).rank_cap == 16


def test_weight_shape_validation(cfg):
    st = _store(cfg, rank_cap=8)
    w = make_lora_params(cfg, rank=4, seed=seed_for("x"))
    proj = next(iter(adapted_projections(cfg)))
    a, b = w[proj]
    w[proj] = (a[:, :, :2], b)           # rank mismatch on one projection
    with pytest.raises(ValueError, match=proj):
        st.load("x", weights=w, rank=4)


def test_unload_refuses_in_flight(cfg):
    st = _store(cfg)
    st.load("a")
    st.acquire("a")
    with pytest.raises(RuntimeError, match="in flight"):
        st.unload("a")
    st.release("a")
    st.unload("a")
    assert not st.known("a")             # gone from both tiers
    assert st.refcount("a") == 0         # and refcount of a stranger is 0


def test_rank_zero_adapter_is_all_padding(cfg):
    st = _store(cfg, rank_cap=8)
    slot = st.load("null", rank=0)
    slabs = st.slabs()
    for sl in slabs.values():
        assert (np.asarray(sl["a"][:, slot]) == 0).all()
        assert (np.asarray(sl["b"][:, slot]) == 0).all()


def test_synthetic_factors_are_name_deterministic(cfg):
    w1 = make_lora_params(cfg, rank=4, seed=seed_for("tenant-a"))
    w2 = make_lora_params(cfg, rank=4, seed=seed_for("tenant-a"))
    w3 = make_lora_params(cfg, rank=4, seed=seed_for("tenant-b"))
    proj = next(iter(w1))
    assert (w1[proj][0] == w2[proj][0]).all()
    assert (w1[proj][0] != w3[proj][0]).any()
