"""The serve-bench trajectory aggregator: trend table, floor suggestion,
ratchet-only-upward semantics."""
import json

import pytest

from benchmarks.aggregate_serve import (load_points, ratchet, suggest_floor,
                                        trend_table)


def _point(path, t, tps, **kw):
    p = {"bench": "serve", "unix_time": t, "tokens_per_sec": tps,
         "ttft_mean_s": kw.get("ttft", 0.04),
         "peak_pool_utilization": kw.get("pool", 0.4),
         "preemptions": kw.get("preempt", 0)}
    if "mesh_devices" in kw:
        p["mesh_devices"] = kw["mesh_devices"]
    if "tp_devices" in kw:
        p["tp_devices"] = kw["tp_devices"]
    path.write_text(json.dumps(p))
    return str(path)


def test_load_sorts_by_time_and_rejects_foreign_json(tmp_path):
    a = _point(tmp_path / "a.json", 200.0, 500.0)
    b = _point(tmp_path / "b.json", 100.0, 400.0)
    pts = load_points([a, b])
    assert [p["tokens_per_sec"] for p in pts] == [400.0, 500.0]
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"bench": "other"}))
    with pytest.raises(ValueError):
        load_points([str(bad)])


def test_load_tolerates_missing_and_empty_history(tmp_path):
    """A failed CI run leaves a missing or empty BENCH_serve.json; the
    aggregator must skip it with a note, not traceback."""
    good = _point(tmp_path / "good.json", 10.0, 150.0)
    empty = tmp_path / "empty.json"
    empty.write_text("")
    skipped = []
    pts = load_points([str(tmp_path / "nope.json"), str(empty), good],
                      skipped=skipped)
    assert [p["tokens_per_sec"] for p in pts] == [150.0]
    assert len(skipped) == 2
    assert any("missing" in s for s in skipped)
    assert any("unparseable" in s for s in skipped)


def test_empty_history_renders_explanatory_row():
    table = trend_table([])
    assert len(table.splitlines()) == 3  # header + separator + explainer
    assert "no trajectory points yet" in table


def test_cli_with_no_usable_points_exits_clean(tmp_path, capsys):
    """End to end: every input missing/empty -> explanatory row, baseline
    untouched, exit 0 (an empty history is a normal first-push state)."""
    from benchmarks.aggregate_serve import cli
    import sys
    base = tmp_path / "serve.json"
    base.write_text(json.dumps({"bench": "serve", "tokens_per_sec": 140.0,
                                "_comment": "floor"}))
    empty = tmp_path / "empty.json"
    empty.write_text("")
    argv, sys.argv = sys.argv, ["aggregate_serve", str(tmp_path / "gone.json"),
                                str(empty), "--baseline", str(base),
                                "--ratchet"]
    try:
        assert cli() == 0
    finally:
        sys.argv = argv
    out = capsys.readouterr().out
    assert "no trajectory points yet" in out
    assert "nothing to aggregate" in out
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0


def test_trend_table_one_row_per_point(tmp_path):
    paths = [_point(tmp_path / f"{i}.json", float(i), 100.0 + i)
             for i in range(3)]
    table = trend_table(load_points(paths))
    assert len(table.splitlines()) == 2 + 3  # header + separator + rows
    assert "102.0" in table


def test_suggest_floor_is_discounted_trailing_median(tmp_path):
    paths = [_point(tmp_path / f"{i}.json", float(i), tps)
             for i, tps in enumerate([100.0, 500.0, 520.0, 540.0])]
    pts = load_points(paths)
    assert suggest_floor(pts) == pytest.approx(0.8 * 510.0)


def test_cli_refuses_to_ratchet_from_too_few_points(tmp_path, capsys):
    from benchmarks.aggregate_serve import cli
    import sys
    base = tmp_path / "serve.json"
    base.write_text(json.dumps({"bench": "serve", "tokens_per_sec": 140.0,
                                "_comment": "floor"}))
    lucky = _point(tmp_path / "lucky.json", 1.0, 2000.0)
    argv, sys.argv = sys.argv, ["aggregate_serve", lucky,
                                "--baseline", str(base), "--ratchet"]
    try:
        assert cli() == 0
    finally:
        sys.argv = argv
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0
    assert "--ratchet ignored" in capsys.readouterr().out


def test_sharded_points_labelled_and_excluded_from_ratchet(tmp_path):
    """Mesh-sharded points appear in the trend table with their mesh width
    but never enter the single-device ratchet series — a fast sharded run
    must not tighten the single-device floor (nor a slow one hold it down)."""
    from benchmarks.aggregate_serve import point_mesh, single_device_points
    singles = [_point(tmp_path / f"s{i}.json", float(i), 500.0)
               for i in range(3)]
    sharded = _point(tmp_path / "m.json", 10.0, 9000.0, mesh_devices=4)
    legacy = _point(tmp_path / "old.json", 0.5, 500.0)  # pre-mesh history
    pts = load_points(singles + [sharded, legacy])
    assert [point_mesh(p) for p in pts] == [1, 1, 1, 1, 4]
    table = trend_table(pts)
    assert "kv x4" in table and table.count("single") == 4
    series = single_device_points(pts)
    assert len(series) == 4
    assert suggest_floor(series) == pytest.approx(0.8 * 500.0)


def test_tp_points_labelled_and_excluded_from_ratchet(tmp_path):
    """Tensor-parallel points (weights sharded, bench_serve --tp N) get
    their own 'tp xN' label — distinct from KV-pool-only 'kv xN' — and,
    like all sharded points, never enter the single-device ratchet."""
    from benchmarks.aggregate_serve import (point_sharded, point_tp,
                                            single_device_points)
    singles = [_point(tmp_path / f"s{i}.json", float(i), 500.0)
               for i in range(3)]
    kv_only = _point(tmp_path / "kv.json", 10.0, 800.0, mesh_devices=4)
    tp = _point(tmp_path / "tp.json", 11.0, 900.0, mesh_devices=4,
                tp_devices=4)
    pts = load_points(singles + [kv_only, tp])
    assert [point_tp(p) for p in pts] == [1, 1, 1, 1, 4]
    assert point_sharded(pts[-1])
    table = trend_table(pts)
    assert "tp x4" in table and "kv x4" in table
    series = single_device_points(pts)
    assert len(series) == 3
    assert suggest_floor(series) == pytest.approx(0.8 * 500.0)


def test_cli_with_only_sharded_points_leaves_floor_untouched(tmp_path, capsys):
    from benchmarks.aggregate_serve import cli
    import sys
    base = tmp_path / "serve.json"
    base.write_text(json.dumps({"bench": "serve", "tokens_per_sec": 140.0,
                                "_comment": "floor"}))
    pts = [_point(tmp_path / f"m{i}.json", float(i), 5000.0, mesh_devices=4)
           for i in range(4)]
    argv, sys.argv = sys.argv, ["aggregate_serve", *pts,
                                "--baseline", str(base), "--ratchet"]
    try:
        assert cli() == 0
    finally:
        sys.argv = argv
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0
    assert "single-device only" in capsys.readouterr().out


def test_ratchet_only_moves_up(tmp_path):
    base = tmp_path / "serve.json"
    base.write_text(json.dumps({"bench": "serve", "tokens_per_sec": 140.0,
                                "_comment": "floor"}))
    # suggestion below the floor: untouched even with apply
    msg = ratchet(str(base), 100.0, apply=True)
    assert "stays" in msg
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0
    # above the floor but apply=False: report only
    msg = ratchet(str(base), 200.0, apply=False)
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0
    assert "--ratchet" in msg
    # above the floor with apply: rewritten, comment annotated
    ratchet(str(base), 200.0, apply=True)
    new = json.loads(base.read_text())
    assert new["tokens_per_sec"] == 200.0
    assert "ratcheted" in new["_comment"]


def _latency_point(path, t, **kw):
    p = {"bench": "serve_latency", "open_loop": True, "unix_time": t,
         "qps": kw.get("qps", 8.0), "requests": 16, "completed": 16,
         "tokens_per_sec": kw.get("tps", 75.0),
         "ttft_p50_ms": kw.get("ttft50", 4.5),
         "ttft_p99_ms": kw.get("ttft99", 12.0),
         "itl_p50_ms": kw.get("itl50", 1.4),
         "itl_p99_ms": kw.get("itl99", 3.6)}
    path.write_text(json.dumps(p))
    return str(path)


def test_latency_points_load_and_render_percentile_cells(tmp_path):
    """BENCH_latency.json points mix into the table with their own mode
    label and p50/p99 cells; closed-loop history predating the percentile
    fields falls back to ~mean / blank instead of crashing."""
    old = _point(tmp_path / "old.json", 1.0, 500.0)        # pre-latency point
    lat = _latency_point(tmp_path / "lat.json", 2.0)
    pts = load_points([old, lat])
    table = trend_table(pts)
    assert "open @8qps" in table and "closed" in table
    assert "4.5/12.0" in table and "1.4/3.6" in table      # p50/p99 cells
    assert "~40.0" in table                                # mean fallback ms
    bare = tmp_path / "bare.json"                          # no latency at all
    bare.write_text(json.dumps({"bench": "serve", "unix_time": 3.0,
                                "tokens_per_sec": 100.0}))
    table = trend_table(load_points([str(bare)]))
    assert "| – | – |" in table                            # blank lat cells


def test_open_loop_points_excluded_from_ratchet(tmp_path):
    """Open-loop delivery rate is paced by the Poisson schedule, not engine
    capacity: a slow open-loop run must not drag the throughput floor."""
    from benchmarks.aggregate_serve import point_open_loop, single_device_points
    singles = [_point(tmp_path / f"s{i}.json", float(i), 500.0)
               for i in range(3)]
    lat = _latency_point(tmp_path / "lat.json", 10.0, tps=75.0)
    pts = load_points(singles + [lat])
    assert [point_open_loop(p) for p in pts] == [False, False, False, True]
    series = single_device_points(pts)
    assert len(series) == 3
    assert suggest_floor(series) == pytest.approx(0.8 * 500.0)


def test_cli_with_only_open_loop_points_leaves_floor_untouched(tmp_path,
                                                               capsys):
    from benchmarks.aggregate_serve import cli
    import sys
    base = tmp_path / "serve.json"
    base.write_text(json.dumps({"bench": "serve", "tokens_per_sec": 140.0,
                                "_comment": "floor"}))
    pts = [_latency_point(tmp_path / f"l{i}.json", float(i), tps=9000.0)
           for i in range(4)]
    argv, sys.argv = sys.argv, ["aggregate_serve", *pts,
                                "--baseline", str(base), "--ratchet"]
    try:
        assert cli() == 0
    finally:
        sys.argv = argv
    assert json.loads(base.read_text())["tokens_per_sec"] == 140.0
    out = capsys.readouterr().out
    assert "excluded from the throughput ratchet" in out
    assert "closed-loop single-device only" in out
