"""Per-arch smoke tests: reduced same-family config, one forward/train step
on CPU, output shapes + no NaNs; decode-vs-prefill logit consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config, list_archs, reduced_config
from repro.models import build_model

ALL_ARCHS = list_archs()


def _batch_for(cfg, b, s, rng):
    toks = jax.random.randint(rng, (b, s), 1, cfg.vocab)
    labels = jnp.roll(toks, -1, axis=1)
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(rng, (b, s, cfg.d_model)) * 0.02,
                "positions": jnp.broadcast_to(
                    jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32),
                "labels": labels}
    if cfg.family == "audio":
        return {"frames": jax.random.normal(rng, (b, s, cfg.d_model)) * 0.02,
                "tokens": toks, "labels": labels}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = reduced_config(get_config(arch))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 16, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(
        lambda p: fns.loss(p, batch, remat=False))(params)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(gn) and gn > 0, f"{arch}: bad grad norm"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg = reduced_config(get_config(arch))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = _batch_for(cfg, b, s, jax.random.PRNGKey(1))
    batch.pop("labels")
    cache, logits = fns.prefill(params, batch)
    assert logits.shape == (b, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def _embed_cache(cache_small, cache_big):
    def place(small, big):
        if small.shape == big.shape:
            return small
        for ax in range(small.ndim):
            if small.shape[ax] != big.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), 0, axis=ax)
        return small
    return jax.tree.map(place, cache_small, cache_big)


@pytest.mark.parametrize("arch", ["qwen3-0.6b", "nemotron-4-15b",
                                  "falcon-mamba-7b", "zamba2-2.7b",
                                  "whisper-small", "olmoe-1b-7b",
                                  "llama4-maverick-400b-a17b"])
def test_decode_matches_prefill(arch):
    cfg = reduced_config(get_config(arch))
    if cfg.moe is not None:  # avoid capacity drops in the comparison
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 1, cfg.vocab)
    if cfg.family == "audio":
        frames = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model)) * 0.1
        b1 = {"frames": frames, "tokens": toks[:, :S]}
        b2 = {"frames": frames, "tokens": toks[:, :S + 1]}
    else:
        b1, b2 = {"tokens": toks[:, :S]}, {"tokens": toks[:, :S + 1]}
    cache1, _ = fns.prefill(params, b1)
    _, logits2 = fns.prefill(params, b2)
    if cfg.family == "ssm":
        cache, dbatch = cache1, {"token": toks[:, S:S + 1]}
    else:
        cache = _embed_cache(cache1, fns.make_cache(B, S + 4))
        dbatch = {"token": toks[:, S:S + 1], "cur_len": jnp.int32(S)}
    _, logits_dec = fns.decode_step(params, cache, dbatch)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits2, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The registered full configs carry the exact assigned hyperparams."""
    expect = {
        "stablelm-3b": (32, 2560, 32, 32, 6912, 50304),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "falcon-mamba-7b": (64, 4096, 1, 1, 0, 65024),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch


def test_param_counts_in_band():
    """Analytic param counts should be in the ballpark of the arch names."""
    bands = {"qwen3-0.6b": (0.4e9, 0.8e9),
             "falcon-mamba-7b": (6e9, 9e9),
             "qwen2-vl-72b": (60e9, 80e9),
             "llama4-maverick-400b-a17b": (330e9, 460e9),
             "olmoe-1b-7b": (6e9, 8.5e9),
             "nemotron-4-15b": (12e9, 18e9)}
    for arch, (lo, hi) in bands.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n:.3e} outside [{lo:.0e},{hi:.0e}]"
    # MoE active params
    a = get_config("llama4-maverick-400b-a17b").active_param_count()
    assert 12e9 <= a <= 25e9
    a = get_config("olmoe-1b-7b").active_param_count()
    assert 0.8e9 <= a <= 2e9


def test_long500k_skip_rules():
    from repro.configs.base import cell_is_runnable
    assert not cell_is_runnable(get_config("qwen3-0.6b"), SHAPES["long_500k"])[0]
    assert cell_is_runnable(get_config("falcon-mamba-7b"), SHAPES["long_500k"])[0]
    assert cell_is_runnable(get_config("zamba2-2.7b"), SHAPES["long_500k"])[0]
