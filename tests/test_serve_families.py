"""Cross-family serving oracle matrix: every model family in the zoo
(transformer / ssm / hybrid) through the SAME paged ``ServeEngine``, each
run token-identical to the family's dense ``prefill`` + ``decode_step``
reference — over greedy and sampled decoding, with chunked prefill on and
off, and across a forced preemption-by-swap that parks recurrent state in
the StateSlab's host tier mid-generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, SamplingParams, ServeEngine
from repro.serve.faults import check_kv_invariants

FAMILY_ARCHS = {
    "transformer": "qwen3-0.6b",
    "ssm": "falcon-mamba-7b",
    "hybrid": "zamba2-2.7b",
}
MAX_LEN = 48
BLOCK_SIZE = 8


@pytest.fixture(scope="module")
def zoo():
    """One (cfg, fns, params) per family, built once for the module."""
    out = {}
    for family, arch in FAMILY_ARCHS.items():
        cfg = reduced_config(get_config(arch))
        assert cfg.family == ("dense" if family == "transformer" else family)
        fns = build_model(cfg)
        out[family] = (cfg, fns, fns.init(jax.random.PRNGKey(0)))
    return out


def _embed(small, big):
    """Grow a prompt-sized cache plane to the decode-sized one (write at 0
    on the first differing axis).  Without this, ``decode_step``'s write at
    ``cur_len`` clamps against a prompt-length cache and corrupts the last
    KV entry — the oracle, not the engine, would be wrong."""
    if small.shape == big.shape:
        return small.astype(big.dtype)
    for ax in range(small.ndim):
        if small.shape[ax] != big.shape[ax]:
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), 0, axis=ax)
    return small


def _oracle(cfg, fns, params, req):
    """Dense single-request reference: whole-prompt prefill, one contiguous
    cache, per-token decode, the engine's own stateless sampler."""
    cache, logits = fns.prefill(
        params, {"tokens": jnp.asarray([req.prompt], jnp.int32)})
    if cfg.family != "ssm":
        cache = jax.tree.map(_embed, cache, fns.make_cache(1, MAX_LEN))
    out = [ServeEngine._sample(np.asarray(logits[0]), req.sampling, 0)]
    cur = len(req.prompt)
    for _ in range(req.max_new - 1):
        batch = {"token": jnp.asarray([[out[-1]]], jnp.int32)}
        if cfg.family != "ssm":
            batch["cur_len"] = jnp.int32(cur)
        cache, lg = fns.decode_step(params, cache, batch)
        out.append(ServeEngine._sample(np.asarray(lg[0]), req.sampling,
                                       len(out)))
        cur += 1
    return out


def _requests(cfg, sampled: bool):
    """Three requests with mixed prompt lengths: one short (single chunk),
    one crossing a block boundary, one long enough to need several prefill
    chunks even at the engine's ssm-rounded chunk size."""
    rng = np.random.default_rng(7)
    reqs = []
    for i, plen in enumerate([3, 9, 17]):
        sp = SamplingParams(temperature=0.8, top_k=40, seed=100 + i) \
            if sampled else SamplingParams()
        prompt = rng.integers(1, cfg.vocab, size=plen).tolist()
        reqs.append(Request(rid=i, prompt=prompt, max_new=5, sampling=sp))
    return reqs


@pytest.mark.parametrize("family", sorted(FAMILY_ARCHS))
@pytest.mark.parametrize("sampled", [False, True], ids=["greedy", "sampled"])
@pytest.mark.parametrize("chunked", [False, True],
                         ids=["whole-prompt", "chunked-prefill"])
def test_family_matches_dense_oracle(zoo, family, sampled, chunked):
    """The matrix: (family x sampling x prefill chunking) — continuous
    batching through the paged engine must be token-identical to the dense
    oracle in every cell.  Chunked prefill uses a deliberately awkward
    request (17 tokens) so scan carry-state crosses chunk boundaries; the
    engine rounds the chunk up to the scan granule for stateful families."""
    cfg, fns, params = zoo[family]
    eng = ServeEngine(cfg, params, max_batch=2, max_len=MAX_LEN,
                      block_size=BLOCK_SIZE, plan_kernels=False,
                      prefill_chunk_tokens=4 if chunked else MAX_LEN)
    reqs = _requests(cfg, sampled)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    assert len(finished) == len(reqs)
    for r in reqs:
        assert r.out == _oracle(cfg, fns, params, r), \
            f"{family} rid={r.rid} diverged from its dense oracle"
    check_kv_invariants(eng)
    # stateful families keep recurrent state in the slab, not the pool:
    # a drained engine holds zero slab slots and (for pure ssm) never
    # allocated a single KV block
    if family == "transformer":
        assert eng.state_store is None
    else:
        assert eng.state_store.device.pool.num_used == 0
        assert eng.state_store.device.pool.peak_used >= 1
        if family == "ssm":
            assert eng.pool.peak_used == 0


@pytest.mark.parametrize("family", ["ssm", "hybrid"])
def test_preemption_by_swap_resumes_slab_state(zoo, family):
    """Mid-generation preemption parks the victim's recurrent state in the
    StateSlab's HOST tier (plus any KV blocks for hybrids) and the resumed
    request finishes token-identically — generated tokens and carry-state
    both survive the round trip."""
    cfg, fns, params = zoo[family]
    eng = ServeEngine(cfg, params, max_batch=3, max_len=MAX_LEN,
                      block_size=BLOCK_SIZE, plan_kernels=False)
    assert eng.swap_enabled, "REPRO_KV_SWAP must default on for this test"
    reqs = _requests(cfg, sampled=True)
    for r in reqs:
        eng.submit(r)
    forced_rid = None
    while eng.step():
        if forced_rid is not None:
            continue
        mid = [s for s in eng.slots
               if s is not None and len(s.req.out) >= 2]
        if mid:
            victim = max(mid, key=lambda s: len(s.req.out))
            n_before = len(victim.req.out)
            eng._requeue(victim)
            forced_rid = victim.req.rid
            parked = eng._parked[forced_rid]
            assert parked.state is not None
            assert parked.state.tier == "host"
            check_kv_invariants(eng)
            assert len(eng.finished) == 0 or all(
                f.rid != forced_rid for f in eng.finished)
            assert n_before >= 2
    assert forced_rid is not None, "no request was ever mid-generation"
    eng.run_until_done()
    m = eng.metrics()
    assert m.preemptions >= 1
    assert m.swap_out_blocks >= 1 and m.swap_in_blocks >= 1
    for r in reqs:
        assert r.out == _oracle(cfg, fns, params, r), \
            f"{family} rid={r.rid} changed tokens across preemption-by-swap"
    check_kv_invariants(eng)
    assert eng.state_store.device.pool.num_used == 0
