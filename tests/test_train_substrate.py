"""Optimizer, data pipeline, checkpointing, trainer recovery."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.train.checkpoint import (list_checkpoints, restore_latest,
                                    save_checkpoint)
from repro.train.data import TokenPipeline
from repro.train.optimizer import AdamW, AdamWConfig, dequantize, quantize
from repro.train.trainer import Trainer, TrainerConfig


def test_adamw_reduces_quadratic_loss():
    opt = AdamW(AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                            weight_decay=0.0))
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0


def test_adamw_grad_clip():
    opt = AdamW(AdamWConfig(lr=1e-3, grad_clip=1.0))
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    _, _, metrics = opt.update({"w": jnp.full(4, 1e6)}, state, params)
    assert float(metrics["grad_norm"]) > 1.0  # reported pre-clip


def test_int8_quant_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 3,
                    jnp.float32)
    q = quantize(x, 256)
    err = float(jnp.max(jnp.abs(dequantize(q) - x)))
    assert err < float(jnp.max(jnp.abs(x))) / 100


def test_int8_optimizer_state_runs():
    opt = AdamW(AdamWConfig(lr=0.05, state_dtype="int8", warmup_steps=1))
    params = {"w": jnp.array([4.0, -4.0])}
    state = opt.init(params)
    for _ in range(40):
        params, state, _ = opt.update({"w": 2 * params["w"]}, state, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 2.0


def test_data_deterministic_by_step():
    p = TokenPipeline(vocab=100, seq_len=32, global_batch=2, seed=7)
    b1, b2 = p.batch_at(5), p.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = p.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token targets
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_checkpoint_atomic_roundtrip():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 10, tree)
        save_checkpoint(d, 20, jax.tree.map(lambda x: x * 2, tree))
        assert [s for s, _ in list_checkpoints(d)] == [10, 20]
        restored, mf = restore_latest(d, tree)
        assert mf["step"] == 20
        np.testing.assert_allclose(np.asarray(restored["a"], np.float32),
                                   np.asarray(tree["a"]) * 2)


def test_checkpoint_gc():
    tree = {"a": jnp.zeros(2)}
    with tempfile.TemporaryDirectory() as d:
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(d, s, tree, keep=2)
        assert [s for s, _ in list_checkpoints(d)] == [4, 5]


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, {"a": jnp.zeros((2, 2))})
        with pytest.raises(ValueError):
            restore_latest(d, {"a": jnp.zeros((3, 3))})


def test_trainer_recovers_from_failure():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainerConfig(seq_len=32, global_batch=2, steps=12,
                             checkpoint_every=4, log_every=50, workdir=d)
        t = Trainer(cfg, tcfg)
        res = t.train(fail_at=9)
        assert res["final_step"] == 12
        # deterministic replay: a clean run gives the same final loss
        t2 = Trainer(cfg, TrainerConfig(seq_len=32, global_batch=2, steps=12,
                                        checkpoint_every=100, log_every=50))
        res2 = t2.train()
        l1 = [e for e in res["log"] if e["step"] == 11 or e["step"] == res["final_step"] - 1]
        l2 = [e for e in res2["log"] if e["step"] == 11 or e["step"] == res2["final_step"] - 1]
        assert abs(l1[-1]["loss"] - l2[-1]["loss"]) < 5e-3


def test_trainer_loss_decreases():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    tcfg = TrainerConfig(seq_len=64, global_batch=4, steps=15, log_every=1)
    res = Trainer(cfg, tcfg).train()
    losses = [e["loss"] for e in res["log"]]
    assert losses[-1] < losses[0]
