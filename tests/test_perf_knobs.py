"""Perf-knob plumbing: env vars reach the model/sharding code paths and
knob'd variants stay numerically equivalent."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model


@pytest.fixture
def clean_env():
    keys = [k for k in os.environ if k.startswith("REPRO_")]
    saved = {k: os.environ.pop(k) for k in keys}
    yield
    for k in list(os.environ):
        if k.startswith("REPRO_"):
            del os.environ[k]
    os.environ.update(saved)


def test_knob_snapshot_roundtrip(clean_env):
    from repro.perf import knob_snapshot, perf
    os.environ["REPRO_REMAT_POLICY"] = "nothing"
    os.environ["REPRO_SEQ_PARALLEL"] = "1"
    os.environ["REPRO_WEIGHT_AG"] = "1"
    p = perf()
    assert p.remat_policy == "nothing"
    assert p.seq_parallel is True
    assert p.weight_ag is True
    snap = knob_snapshot()
    assert snap["moe_decode"] == "gather"


def test_moe_decode_dispatch_matches_gather(clean_env):
    """Both decode MoE paths compute the same result (capacity permitting)."""
    import dataclasses
    cfg = reduced_config(get_config("olmoe-1b-7b"))
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=64.0))
    from repro.models.moe import apply_moe_decode, apply_moe_decode_dispatch, init_moe
    p = init_moe(cfg, jax.random.PRNGKey(0), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, cfg.d_model)) * 0.1
    a = apply_moe_decode(cfg, p, x)
    b = apply_moe_decode_dispatch(cfg, p, x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_remat_policies_equal_loss(clean_env):
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    os.environ["REPRO_REMAT_POLICY"] = "dots"
    l1 = float(fns.loss(params, batch, remat=True))
    os.environ["REPRO_REMAT_POLICY"] = "nothing"
    l2 = float(fns.loss(params, batch, remat=True))
    assert abs(l1 - l2) < 1e-4


def test_norm_bf16_knob_changes_dtype_path(clean_env):
    from repro.models.layers import rms_norm
    x = jnp.ones((4, 8), jnp.bfloat16)
    w = jnp.ones((8,), jnp.bfloat16)
    os.environ["REPRO_NORM_F32"] = "0"
    a = rms_norm(x, w)
    os.environ["REPRO_NORM_F32"] = "1"
    b = rms_norm(x, w)
    assert a.dtype == b.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=2e-2)
