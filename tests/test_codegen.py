"""Codegen: packed-layout array transforms + term compilation properties."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.codegen import _pack_array, _unpack_array, compile_term, kernel_plan
from repro.core.schedule.minlp import Schedule
from repro.core.tensor_ir import T, binary, inp, transpose, unary


@given(st.sampled_from([(8, 128), (128, 128)]),
       st.sampled_from([(128, 256), (256, 128), (256, 256)]))
@settings(max_examples=12, deadline=None)
def test_pack_unpack_roundtrip(lanes, shape):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), jnp.float32)
    packed = _pack_array(x, lanes, (0, 1))
    assert packed.shape == (shape[0] // lanes[0], shape[1] // lanes[1],
                            lanes[0], lanes[1])
    back = _unpack_array(packed, lanes, (0, 1), 2)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(x))


def test_pack_is_blocked_layout():
    x = jnp.arange(16).reshape(4, 4)
    p = _pack_array(x, (2, 2), (0, 1))
    # block (0,0) is the top-left 2x2 tile
    np.testing.assert_array_equal(np.asarray(p[0, 0]), [[0, 1], [4, 5]])


def test_compile_term_all_ops():
    rng = np.random.default_rng(1)
    a = inp("a", (8, 8))
    t = binary(unary(transpose(a, (1, 0)), kind="exp"),
               inp("b", (8, 8)), kind="mul")
    f = compile_term(t)
    env = {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
           "b": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
    want = jnp.exp(env["a"].T) * env["b"]
    np.testing.assert_allclose(np.asarray(f(**env)), np.asarray(want),
                               rtol=1e-6)


def test_kernel_plan_defaults_on_empty_schedule():
    plan = kernel_plan(Schedule({}, 0.0, 0.0, 0.0, 0))
    assert plan.block_m >= 128 and plan.block_k >= 128
