"""HLO analyzer: dot FLOPs, while trip counts, collective byte parsing."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo, collective_time_s, roofline_terms


def _compile_text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_dot_flops_simple():
    a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    text = _compile_text(lambda x, y: x @ y, a, b)
    res = analyze_hlo(text, 1)
    want = 2 * 128 * 64 * 256
    assert abs(res["flops"] - want) / want < 0.01


def test_while_trip_count_multiplies_flops():
    a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def scanned(x):
        def body(c, _):
            return c @ x, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    text = _compile_text(scanned, a)
    res = analyze_hlo(text, 1)
    one = 2 * 64 * 64 * 64
    # 7 iterations of one matmul (allow slack for fusion rewrites)
    assert res["flops"] >= 6 * one
    assert res["flops"] <= 9 * one


def test_collective_model_factors():
    coll = {"all-reduce": {"bytes": 1e9, "count": 1, "max_group": 4}}
    t_ar = collective_time_s(coll)
    coll2 = {"all-gather": {"bytes": 1e9, "count": 1, "max_group": 4}}
    t_ag = collective_time_s(coll2)
    assert abs(t_ar / t_ag - 2.0) < 0.01  # ring all-reduce moves 2x


def test_roofline_bottleneck_identification():
    r = roofline_terms({"flops": 1e15, "bytes_traffic": 1e9,
                        "collectives": {}})
    assert r["bottleneck"] == "compute"
    r = roofline_terms({"flops": 1e9, "bytes_traffic": 1e13,
                        "collectives": {}})
    assert r["bottleneck"] == "memory"
