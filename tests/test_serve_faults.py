"""Fault-tolerant serving: seeded fault injection, crash-isolated stepping
(quarantine + degraded health), request deadlines, bounded-queue load
shedding, and the KV-leak invariant checker."""
import time

import jax
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine
from repro.serve.faults import FaultInjector, InjectedFault, \
    check_kv_invariants


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _engine(cfg, params, faults=False, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("plan_kernels", False)
    return ServeEngine(cfg, params, fault_injector=faults, **kw)


def _run_guarded(eng, max_steps=500):
    """Drive step_guarded until the engine drains (what the async stepper
    thread does, minus the thread)."""
    for _ in range(max_steps):
        busy = eng.queue or eng._parked or \
            any(s is not None for s in eng.slots)
        if not busy:
            return
        eng.step_guarded()
    raise AssertionError("engine did not drain")


def _drained(eng):
    eng.release_prefix_cache()
    assert eng.pool.num_used == 0
    assert eng.pool.num_reserved == 0
    assert eng.store.host.num_used == 0
    assert eng.check_invariants() == []
    assert eng.invariant_violations == []


# ---------------------------------------------------------------------------
# FaultInjector semantics (pure Python, no engine)
# ---------------------------------------------------------------------------

def test_fault_injector_parse_rejects_bad_specs():
    for bad in ("alloc", "alloc:p", "nosite:p=0.5", "alloc:bogus=1"):
        with pytest.raises(ValueError):
            FaultInjector.parse(bad)
    fi = FaultInjector.parse("alloc:p=0.5, step:exc=2 ,")
    assert [(r.site, r.mode, r.value) for r in fi.rules] == \
        [("alloc", "p", 0.5), ("step", "exc", 2.0)]


def test_fault_injector_modes_fire_deterministically():
    fi = FaultInjector.parse("alloc:p=1.0")
    with pytest.raises(InjectedFault) as ei:
        fi.check("alloc")
    assert ei.value.site == "alloc"
    fi.check("step")                       # other sites unaffected

    never = FaultInjector.parse("alloc:p=0.0")
    for _ in range(50):
        never.check("alloc")

    after = FaultInjector.parse("swap_out:after=2")
    after.check("swap_out")
    after.check("swap_out")
    with pytest.raises(InjectedFault):
        after.check("swap_out")            # the (N+1)-th check
    after.check("swap_out")                # exactly once

    exc = FaultInjector.parse("step:exc=2")
    for _ in range(2):
        with pytest.raises(InjectedFault):
            exc.check("step")
    exc.check("step")                      # first N only
    assert exc.counts() == {"step": {"checks": 3, "fired": 2}}


def test_fault_injector_from_env(monkeypatch):
    monkeypatch.delenv("REPRO_FAULT", raising=False)
    assert FaultInjector.from_env() is None
    monkeypatch.setenv("REPRO_FAULT", "alloc:after=1")
    monkeypatch.setenv("REPRO_FAULT_SEED", "7")
    fi = FaultInjector.from_env()
    assert fi is not None and fi.seed == 7
    assert fi.rules[0].site == "alloc"


def test_injected_fault_is_not_pool_exhausted():
    """An injected alloc fault models a device/allocator error — the
    eviction/preemption ladder (which catches PoolExhausted) must NOT
    absorb it, or chaos runs would never reach the recovery paths."""
    from repro.serve.paged_cache import PoolExhausted
    assert not issubclass(InjectedFault, PoolExhausted)


# ---------------------------------------------------------------------------
# Crash isolation: quarantine, degraded health
# ---------------------------------------------------------------------------

def test_step_crash_quarantines_poison_request_others_complete(setup):
    """The tentpole regression: a step-loop exception fails the poisoning
    request with finish_reason="error" and frees its blocks; everyone else
    completes; one crash does not degrade the engine."""
    cfg, fns, params = setup
    eng = _engine(cfg, params,
                  faults=FaultInjector.parse("step:exc=1"))
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7], max_new=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    _run_guarded(eng)
    errored = [r for r in reqs if r.errored]
    assert len(errored) == 1
    assert errored[0].finish_reason == "error"
    assert "injected step fault" in errored[0].error
    survivors = [r for r in reqs if not r.errored]
    assert all(r.done and len(r.out) == 4 for r in survivors)
    m = eng.metrics()
    assert m.step_crashes == 1 and m.requests_errored == 1
    assert not eng.degraded and not m.degraded
    _drained(eng)


def test_repeated_crashes_degrade_engine_and_idle_does_not_clear(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params, faults=FaultInjector.parse("step:exc=100"))
    for i in range(3):
        eng.submit(Request(rid=i, prompt=[3 + i, 5, 7], max_new=4))
    _run_guarded(eng)
    assert eng._step_crashes >= eng.max_consecutive_crashes
    assert eng.degraded and eng.metrics().degraded
    # an idle step is not evidence of health: degraded must stick until a
    # step actually serves something cleanly
    assert eng.step_guarded() is False
    assert eng.degraded
    _drained(eng)


def test_clean_step_clears_degraded(setup, monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_MAX_CRASHES", "1")
    cfg, fns, params = setup
    eng = _engine(cfg, params, faults=FaultInjector.parse("step:exc=1"))
    assert eng.max_consecutive_crashes == 1
    for i in range(2):
        eng.submit(Request(rid=i, prompt=[3 + i, 5, 7], max_new=4))
    eng.step_guarded()                       # crash -> degraded at threshold 1
    assert eng.degraded
    _run_guarded(eng)                        # survivor serves cleanly
    assert not eng.degraded
    _drained(eng)


def test_alloc_fault_mid_flight_quarantines_without_leaks(setup):
    """An allocator fault during prefill/decode growth is attributed to the
    request being grown; every request still reaches a terminal state and
    both tiers account for every block."""
    cfg, fns, params = setup
    eng = _engine(cfg, params, faults=FaultInjector.parse("alloc:after=6"))
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7, 11, 13], max_new=8)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    _run_guarded(eng)
    assert all(r.done for r in reqs)
    assert sum(1 for r in reqs if r.errored) >= 1
    assert all(len(r.out) == 8 for r in reqs if not r.errored)
    assert eng.metrics().step_crashes >= 1
    _drained(eng)


def test_invariant_checker_detects_manufactured_leak(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    assert eng.check_invariants() == []
    leaked = eng.store.alloc()               # allocated, reachable nowhere
    errs = check_kv_invariants(eng)
    assert any("leaked" in e for e in errs)
    eng.store.decref(leaked)
    assert eng.check_invariants() == []


# ---------------------------------------------------------------------------
# Deadlines and load shedding
# ---------------------------------------------------------------------------

def test_deadline_expires_queued_request_before_any_work(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    req = Request(rid=0, prompt=[3, 5, 7], max_new=4, deadline_ms=1.0)
    eng.submit(req)
    time.sleep(0.01)
    eng.step()
    assert req.expired and req.done and req.finish_reason == "expired"
    assert req.out == []
    assert eng.metrics().requests_expired == 1
    _drained(eng)


def test_deadline_expires_active_request_and_frees_blocks(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    victim = Request(rid=0, prompt=[3, 5, 7], max_new=16)
    other = Request(rid=1, prompt=[4, 6, 8], max_new=4)
    eng.submit(victim)
    eng.submit(other)
    for _ in range(4):                       # admit + a few decode steps
        eng.step()
    assert not victim.done
    victim._deadline_at = time.monotonic() - 1.0
    _run_guarded(eng)
    assert victim.expired and victim.finish_reason == "expired"
    assert 0 < len(victim.out) < 16, "expired mid-generation"
    assert other.done and not other.expired and len(other.out) == 4
    _drained(eng)


def test_default_deadline_env_applies_to_all_requests(setup, monkeypatch):
    monkeypatch.setenv("REPRO_SERVE_DEADLINE_MS", "1")
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    assert eng.default_deadline_ms == 1
    req = Request(rid=0, prompt=[3, 5, 7], max_new=4)
    eng.submit(req)
    assert req._deadline_at > 0
    time.sleep(0.01)
    eng.step()
    assert req.expired


def test_bounded_queue_sheds_at_submit(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params, max_queue=2)
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7], max_new=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    assert reqs[2].shed and reqs[2].done
    assert reqs[2].finish_reason == "shed"
    assert len(eng.queue) == 2
    _run_guarded(eng)
    assert all(r.done and len(r.out) == 4 for r in reqs[:2])
    m = eng.metrics()
    assert m.requests_shed == 1 and m.requests_finished == 2
    _drained(eng)


def test_overload_reason_reports_queue_and_pressure(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params, max_queue=1)
    assert eng.overload_reason() == ""
    eng.submit(Request(rid=0, prompt=[3, 5, 7], max_new=4))
    assert "queue full" in eng.overload_reason()
    eng.note_gateway_shed()
    assert eng.metrics().requests_shed == 1
    _run_guarded(eng)
    _drained(eng)


# ---------------------------------------------------------------------------
# Parked (preempted) requests: cancel / expiry must release the host tier
# ---------------------------------------------------------------------------

def _park_one(cfg, params):
    """The preemption workload from test_serve: pool too small for both
    generations, so the youngest gets parked on the host tier.  Steps until
    the park actually happens and returns (engine, parked victim)."""
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic",
                      plan_kernels=False, fault_injector=False)
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    for _ in range(200):
        if eng._parked:
            break
        eng.step()
    assert eng._parked, "workload must preempt"
    rid = next(iter(eng._parked))
    victim = next(r for r in reqs if r.rid == rid)
    other = next(r for r in reqs if r.rid != rid)
    return eng, victim, other


def test_cancel_parked_request_releases_host_blocks(setup):
    cfg, fns, params = setup
    eng, victim, other = _park_one(cfg, params)
    assert eng.store.host.num_used > 0, "victim parked on the host tier"
    assert eng.cancel(victim.rid)
    assert victim.cancelled and victim.rid not in eng._parked
    assert eng.store.host.num_used == 0, \
        "cancelling a parked request must free its host-tier blocks"
    assert eng.check_invariants() == []
    _run_guarded(eng)
    assert other.done and len(other.out) == 16
    _drained(eng)


def test_expire_parked_request_releases_host_blocks(setup):
    cfg, fns, params = setup
    eng, victim, other = _park_one(cfg, params)
    assert eng.store.host.num_used > 0
    victim._deadline_at = time.monotonic() - 1.0
    _run_guarded(eng)
    assert victim.expired and victim.finish_reason == "expired"
    assert victim.rid not in eng._parked
    assert other.done and len(other.out) == 16
    _drained(eng)


def test_swap_out_fault_downgrades_preemption_to_legacy_restart(setup):
    """A swap_out fault during preemption must not kill the victim: the
    engine falls back to drop-and-restart (stateless seeded sampling keeps
    the output identical), counts a swap_failure, and leaks nothing."""
    cfg, fns, params = setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      num_blocks=7, admission="optimistic",
                      plan_kernels=False,
                      fault_injector=FaultInjector.parse("swap_out:p=1.0"))
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=16)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    _run_guarded(eng)
    assert all(r.done and not r.errored and len(r.out) == 16 for r in reqs)
    m = eng.metrics()
    assert m.preemptions >= 1, "workload must overcommit and preempt"
    assert m.swap_failures >= 1, "the injected swap fault must have fired"
    assert m.swap_out_blocks == 0, "no swap completed under p=1.0 faults"
    # legacy restart replays the same tokens (stateless (seed,idx) sampling)
    ref = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                      plan_kernels=False, prefix_cache_blocks=0,
                      fault_injector=False)
    for r in reqs:
        ref_req = Request(rid=r.rid, prompt=list(r.prompt), max_new=16)
        ref.submit(ref_req)
        ref.run_until_done()
        assert r.out == ref_req.out, \
            f"rid {r.rid}: swap-fault downgrade changed the output"


# ---------------------------------------------------------------------------
# Stateful families (ssm / hybrid): the slab fault sites.  REPRO_FAULT can
# target recurrent-state traffic independently of block traffic —
# slab_alloc / slab_swap_out / slab_swap_in — and the same recovery ladder
# must hold: quarantine frees slab state, a swap fault downgrades the park
# to a token-identical restart.
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module", params=["falcon-mamba-7b", "zamba2-2.7b"],
                ids=["ssm", "hybrid"])
def stateful_setup(request):
    cfg = reduced_config(get_config(request.param))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def test_slab_alloc_fault_quarantines_request_and_frees_state(stateful_setup):
    """A state-slot allocator fault at admission is blamed on the admitting
    request: it errors out (its KV reservation released, no slab slot
    leaked), everyone else completes, and both allocators drain to zero."""
    cfg, fns, params = stateful_setup
    eng = _engine(cfg, params,
                  faults=FaultInjector.parse("slab_alloc:after=1"))
    reqs = [Request(rid=i, prompt=[3 + i, 5, 7], max_new=4)
            for i in range(3)]
    for r in reqs:
        eng.submit(r)
    _run_guarded(eng)
    errored = [r for r in reqs if r.errored]
    assert len(errored) == 1, "exactly the faulted admission must error"
    assert errored[0].finish_reason == "error"
    survivors = [r for r in reqs if not r.errored]
    assert all(r.done and len(r.out) == 4 for r in survivors)
    assert eng.metrics().step_crashes >= 1
    assert eng.state_store.device.pool.num_used == 0, \
        "quarantine must free the slab state"
    assert eng.state_store.host.num_used == 0
    _drained(eng)


def test_slab_swap_fault_downgrades_preemption_token_identically(
        stateful_setup):
    """A slab_swap_out fault during preemption must not kill the victim:
    the park downgrades to the legacy drop-and-restart (state decref'd, no
    host slot consumed) and stateless seeded sampling replays the exact
    same tokens on re-admission."""
    cfg, fns, params = stateful_setup
    eng = ServeEngine(cfg, params, max_batch=2, max_len=32, block_size=4,
                      plan_kernels=False,
                      fault_injector=FaultInjector.parse(
                          "slab_swap_out:p=1.0"))
    assert eng.swap_enabled, "REPRO_KV_SWAP must default on"
    reqs = [Request(rid=i, prompt=[3, 5, 7, 11 + i], max_new=8)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    # pure-ssm requests never outgrow the pool (O(1) state), so force the
    # preemption the way pressure would: requeue a mid-generation victim
    forced = False
    while eng.step():
        if forced:
            continue
        mid = [s for s in eng.slots
               if s is not None and len(s.req.out) >= 2]
        if mid:
            eng._requeue(max(mid, key=lambda s: len(s.req.out)))
            forced = True
            assert not eng._parked, \
                "the faulted swap must downgrade to a drop, not park"
            assert check_kv_invariants(eng) == []
    assert forced, "no request was ever mid-generation"
    eng.run_until_done()
    m = eng.metrics()
    assert m.preemptions >= 1
    assert m.swap_failures >= 1, "the injected slab swap fault must fire"
    assert m.swap_out_blocks == 0, \
        "nothing may cross the swap tier under p=1.0 slab faults"
    assert all(r.done and not r.errored and len(r.out) == 8 for r in reqs)
    # the restarted victim must replay the exact same tokens
    ref_eng = ServeEngine(cfg, params, max_batch=1, max_len=32, block_size=4,
                          plan_kernels=False, fault_injector=False)
    for r in reqs:
        rr = Request(rid=r.rid, prompt=list(r.prompt), max_new=8)
        ref_eng.submit(rr)
        ref_eng.run_until_done()
        assert r.out == rr.out, \
            f"rid {r.rid}: slab swap-fault downgrade changed the output"
    assert eng.state_store.device.pool.num_used == 0
    assert eng.state_store.host.num_used == 0
    _drained(eng)


# ---------------------------------------------------------------------------
# Async engine: submit after stop must not hang
# ---------------------------------------------------------------------------

def test_submit_after_stop_terminates_stream_immediately(setup):
    import asyncio

    from repro.serve.async_engine import AsyncServeEngine

    cfg, fns, params = setup
    eng = _engine(cfg, params)

    async def scenario():
        aeng = AsyncServeEngine(eng, model_id="m")
        await aeng.start()
        out = await aeng.generate([3, 5, 7], max_new=4)
        assert len(out) == 4
        await aeng.stop()
        stream = aeng.submit([3, 5, 7], max_new=4)
        toks = await asyncio.wait_for(stream.drain(), timeout=5.0)
        assert toks == [] and stream.finish_reason == "shutdown"

    asyncio.run(scenario())


# ---------------------------------------------------------------------------
# Chaos lane (marked `chaos`: excluded from the fast lane, run by the
# chaos-smoke CI job and the full tier-1 suite)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
def test_chaos_lane_holds_fault_tolerance_contract():
    """tools.chaos_smoke in-process: the open-loop gateway workload under a
    deterministic alloc+step fault mix must end with every stream terminal,
    zero leaked blocks on either tier, and survivors oracle-identical."""
    from tools.chaos_smoke import run_chaos
    from tools.gateway_smoke import Deadline

    report, failures = run_chaos("alloc:p=0.1,step:exc=2", seed=1,
                                 n_requests=6, qps=30.0,
                                 deadline=Deadline(240.0))
    assert failures == [], failures
    assert sum(report["outcomes"].values()) == 6, \
        "every request must reach a terminal outcome"
    assert report["step_crashes"] >= 1, "the step faults must have fired"
    assert sum(c["fired"] for c in report["fault_counts"].values()) >= 1
