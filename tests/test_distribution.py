"""Auto Distribution (§3.1.3): BuildEGraph + memory-constrained extraction."""
import pytest
from jax.sharding import PartitionSpec

from repro.core.distribution import (auto_distribute, build_distributed_egraph,
                                     ndsbp_to_pspec)
from repro.core.sbp import B, Placement, S
from repro.core.tensor_ir import inp, matmul, unary

PL = Placement(("data", "model"), (2, 2))


def _mlp(t=512, d=256, f=1024):
    x = inp("x", (t, d))
    w1, w2 = inp("w1", (d, f)), inp("w2", (f, d))
    return matmul(unary(matmul(x, w1), kind="exp"), w2), (x, w1, w2)


def test_ecluster_structure():
    term, _ = _mlp()
    dg = build_distributed_egraph(term, PL)
    # each logical node has an e-cluster keyed by SBP with distinct e-classes
    for tid, cluster in dg.eclusters.items():
        assert len(cluster) >= 1
        ids = [dg.eg.find(c) for c in cluster.values()]
        assert len(set(ids)) == len(ids), "same-SBP classes must be unioned"


def test_unconstrained_prefers_data_parallel():
    term, _ = _mlp()
    plan = auto_distribute(term, PL, use_sat=False)
    # weights replicated, activations row-split: zero boxing until unshard
    by_name = {}
    dg = build_distributed_egraph(term, PL)
    for tid, nd in plan.assignments.items():
        name = dg.terms[tid].attr("name")
        if name:
            by_name[name] = nd
    assert by_name["w1"] == (B, B)
    assert all(isinstance(s, S) and s.axis == 0 for s in by_name["x"])


def test_memory_cap_forces_weight_sharding():
    # weight-dominated block: replication is cheap on comm but heavy on HBM
    term, _ = _mlp(t=64, d=1024, f=4096)
    free = auto_distribute(term, PL, use_sat=False)
    cap = int(free.peak_memory * 0.8)
    plan = auto_distribute(term, PL, mem_capacity=cap)
    assert plan.peak_memory <= cap
    assert plan.cost >= free.cost - 1e-15  # memory savings cost communication
    # at least one weight is no longer fully replicated
    dg = build_distributed_egraph(term, PL)
    sharded_weights = 0
    for tid, nd in plan.assignments.items():
        name = dg.terms[tid].attr("name")
        if name in ("w1", "w2") and any(isinstance(s, S) for s in nd):
            sharded_weights += 1
    assert sharded_weights >= 1


def test_infeasible_cap():
    term, _ = _mlp()
    with pytest.raises(ValueError):
        auto_distribute(term, PL, mem_capacity=10)


def test_ndsbp_to_pspec():
    pl3 = Placement(("pod", "data", "model"), (2, 4, 4))
    spec = ndsbp_to_pspec((S(0), S(0), S(1)), pl3, 2)
    assert spec == PartitionSpec(("pod", "data"), "model")
    assert ndsbp_to_pspec((B, B, B), pl3, 2) == PartitionSpec(None, None)


@pytest.mark.slow  # WPMaxSAT + branch-and-bound cross-check takes ~1 min
def test_sat_and_bb_agree_small():
    term, _ = _mlp(t=64, d=64, f=64)
    sat_plan = auto_distribute(term, PL, use_sat=True)
    bb_plan = auto_distribute(term, PL, mem_capacity=1 << 40)
    assert abs(sat_plan.cost - bb_plan.cost) < 1e-12
