"""E-graph core: union-find, congruence closure, saturation (§3.1.1)."""
import pytest

from repro.core.egraph import EGraph, ENode
from repro.core.rewrite import TRANSPOSE_RULES
from repro.core.tensor_ir import binary, inp, matmul, term_shape, transpose, unary


def test_hashcons_dedup():
    eg = EGraph()
    a = eg.add_term(inp("A", (4, 4)))
    b = eg.add_term(inp("A", (4, 4)))
    assert a == b
    assert eg.size() == 1


def test_union_merges_classes():
    eg = EGraph()
    a = eg.add_term(inp("A", (4, 4)))
    b = eg.add_term(inp("B", (4, 4)))
    r = eg.union(a, b)
    assert eg.find(a) == eg.find(b) == r
    assert len(eg.nodes(r)) == 2


def test_union_shape_mismatch_raises():
    eg = EGraph()
    a = eg.add_term(inp("A", (4, 4)))
    b = eg.add_term(inp("B", (4, 8)))
    with pytest.raises(ValueError):
        eg.union(a, b)


def test_congruence_closure():
    # f(a), f(b): after union(a, b), congruence must merge f(a) and f(b)
    eg = EGraph()
    a = eg.add_term(inp("A", (4, 4)))
    b = eg.add_term(inp("B", (4, 4)))
    fa = eg.add(ENode("unary", (a,), (("kind", "exp"),)))
    fb = eg.add(ENode("unary", (b,), (("kind", "exp"),)))
    assert eg.find(fa) != eg.find(fb)
    eg.union(a, b)
    eg.rebuild()
    assert eg.find(fa) == eg.find(fb)


def test_analysis_shape_inference():
    eg = EGraph()
    t = matmul(inp("A", (8, 16)), inp("B", (16, 32)))
    cid = eg.add_term(t)
    assert eg.shape(cid) == (8, 32)
    assert eg.shape(cid) == term_shape(t)


def test_saturation_reaches_fixpoint():
    eg = EGraph()
    A = inp("A", (8, 8))
    t = transpose(transpose(A, (1, 0)), (1, 0))
    root = eg.add_term(t)
    stats = eg.saturate(TRANSPOSE_RULES, max_iters=10)
    assert stats["iters"] <= 10
    # double transpose folded: root class contains the input node itself
    ops = {n.op for n in eg.nodes(root)}
    assert "input" in ops


def test_saturation_node_limit():
    eg = EGraph()
    x = inp("x", (8, 8))
    t = binary(transpose(x, (1, 0)), transpose(x, (1, 0)), kind="add")
    eg.add_term(t)
    stats = eg.saturate(TRANSPOSE_RULES, max_iters=50, node_limit=12)
    assert eg.size() <= 12 + 10  # one iteration of slack
