"""Property-based stress of the tiered stores: random alloc / fork / CoW /
swap / free interleavings against a stub-plane KVStore + StateSlab pair (the
block pool and the recurrent-state slab an ssm/hybrid engine holds side by
side), with the engine's own ledger auditor — ``check_kv_invariants`` — run
after EVERY single operation through an engine-shaped view of the stores.
No refcount may leak, no ledger may drift, at any interleaving.

Runs under hypothesis when installed (``pip install .[test]``); a
deterministic seeded driver exercises the same interpreter regardless, so
the invariants are enforced in every environment."""
import types

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.serve.faults import check_kv_invariants
from repro.serve.kv_store import (DEVICE, HOST, BlockTable, DeviceTier,
                                  HostTier, KVStore, SlabDeviceView,
                                  StateSlab)
from repro.serve.paged_cache import BlockPool

BLOCK_SIZE = 4
N_BLOCKS = 9       # usable device blocks + null
N_SLOTS = 6        # state slab slots + null
N_HOST = 5         # deliberately tight: swap guards must actually bite
N_OPS = 8


def _stub_stores():
    """A KVStore over a stub block plane and a StateSlab over a stub slot
    plane of the SAME base tier — the production shape (one shared cache
    pytree, two allocators over different axes), minus jax."""
    def _copy(cache, src, dst):
        c = dict(cache)
        c[dst] = c.get(src)
        return c

    def _read(cache, idx):
        return cache.get(idx, f"uninit{idx}")

    def _write(cache, idx, data):
        c = dict(cache)
        c[idx] = data
        return c

    base = DeviceTier({}, BlockPool(N_BLOCKS, BLOCK_SIZE),
                      copy_block=_copy, read_block=_read, write_block=_write)
    store = KVStore(base, HostTier(N_HOST))
    # the slab view indexes slots of the same cache dict: offset the ids so
    # block writes and slot writes can never collide in the stub plane
    off = 1000

    def _scopy(cache, src, dst):
        return _copy(cache, off + src, off + dst)

    def _sread(cache, idx):
        return _read(cache, off + idx)

    def _swrite(cache, idx, data):
        return _write(cache, off + idx, data)

    slab = StateSlab(SlabDeviceView(base, BlockPool(N_SLOTS, 1),
                                    _scopy, _sread, _swrite),
                     HostTier(N_HOST))
    return store, slab


class _Seq:
    """One request's holdings: a block list + at most one state slot."""

    def __init__(self):
        self.blocks = []
        self.state = None
        self.parked = False


def _engine_view(store, slab, seqs):
    """Engine-shaped namespace over the model, so the REAL auditor walks our
    stub world: live seqs are slots, parked seqs are ``_parked`` entries."""
    slots, parked = [], {}
    for rid, s in seqs.items():
        if s.parked:
            parked[rid] = types.SimpleNamespace(blocks=list(s.blocks),
                                                state=s.state)
        else:
            slots.append(types.SimpleNamespace(
                table=BlockTable(BLOCK_SIZE, blocks=list(s.blocks)),
                reserved_left=0, state=s.state))
    return types.SimpleNamespace(slots=slots, _parked=parked, store=store,
                                 pool=store.device.pool, state_store=slab)


def _drive(ops):
    """Interpret (op, a, b) triples against the model; inapplicable ops are
    no-ops (the audit still runs).  Returns the final (store, slab)."""
    store, slab = _stub_stores()
    seqs, next_rid = {}, 0

    def pick(candidates, a):
        return candidates[a % len(candidates)] if candidates else None

    for op, a, b in ops:
        op %= N_OPS
        live = [s for s in seqs.values() if not s.parked]
        if op == 0:                                   # grow a block table
            s = pick(live, a)
            if s is None:
                s = seqs[next_rid] = _Seq()
                next_rid += 1
            if store.device.pool.num_free > 0:
                s.blocks.append(store.alloc())
                store.device.cache = {**store.device.cache,
                                      s.blocks[-1].idx: f"blk{a}.{b}"}
        elif op == 1:                                 # claim a state slot
            s = pick([s for s in live if s.state is None], a)
            if s is not None and slab.device.pool.num_free > 0:
                s.state = slab.alloc()
                slab.device.write(s.state.idx, f"st{a}.{b}")
        elif op == 2:                                 # fork a prefix (+state)
            src = pick([s for s in live if s.blocks], a)
            if src is not None:
                child = _Seq()
                k = 1 + b % len(src.blocks)
                child.blocks = list(store.fork(src.blocks[:k]))
                if src.state is not None and b % 2:
                    child.state = slab.fork([src.state])[0]
                seqs[next_rid] = child
                next_rid += 1
        elif op == 3:                                 # CoW a shared block
            cands = [(s, i) for s in live for i, blk in enumerate(s.blocks)
                     if blk.shared and blk.tier == DEVICE]
            hit = pick(cands, a)
            if hit is not None and store.device.pool.num_free > 0:
                s, i = hit
                s.blocks[i] = store.cow_into(s.blocks[i], store.alloc())
        elif op == 4:                                 # CoW shared state
            cands = [s for s in live
                     if s.state is not None and s.state.shared]
            s = pick(cands, a)
            if s is not None and slab.device.pool.num_free > 0:
                s.state = slab.cow_into(s.state, slab.alloc())
        elif op == 5:                                 # park (preempt-by-swap)
            s = pick([s for s in live if s.blocks or s.state is not None], a)
            ok = s is not None and store.can_swap_out(s.blocks)
            if ok and s.state is not None:
                ok = slab.can_swap_out([s.state])
            if ok:
                if s.state is not None:
                    s.state = slab.swap_out(s.state)
                s.blocks = [store.swap_out(blk) for blk in s.blocks]
                s.parked = True
        elif op == 6:                                 # restore a parked seq
            s = pick([s for s in seqs.values() if s.parked], a)
            if s is not None:
                n_host = sum(1 for blk in s.blocks if blk.tier == HOST)
                ok = store.device.pool.num_free >= n_host
                if ok and s.state is not None and s.state.tier == HOST:
                    ok = slab.device.pool.num_free > 0
                if ok:
                    if s.state is not None and s.state.tier == HOST:
                        s.state = slab.swap_in(s.state, slab.alloc())
                    s.blocks = [store.swap_in(blk, store.alloc())
                                if blk.tier == HOST else blk
                                for blk in s.blocks]
                    s.parked = False
        elif op == 7:                                 # retire / cancel
            rid = pick(sorted(seqs), a)
            if rid is not None:
                s = seqs.pop(rid)
                for blk in s.blocks:
                    store.decref(blk)
                if s.state is not None:
                    slab.decref(s.state)
        errs = check_kv_invariants(_engine_view(store, slab, seqs))
        assert not errs, f"after op {(op, a, b)}: {errs}"

    # drain: every holder gone -> every ledger empty, nothing leaked
    for s in seqs.values():
        for blk in s.blocks:
            store.decref(blk)
        if s.state is not None:
            slab.decref(s.state)
    assert store.device.pool.num_used == 0
    assert store.host.num_used == 0
    assert slab.device.pool.num_used == 0
    assert slab.host.num_used == 0
    return store, slab


@given(st.lists(st.tuples(st.integers(0, N_OPS - 1), st.integers(0, 31),
                          st.integers(0, 31)),
                min_size=1, max_size=80))
@settings(max_examples=80, deadline=None)
def test_random_interleavings_hold_invariants(ops):
    """Any interleaving of alloc/fork/CoW/park/restore/free over both tiers
    keeps every refcount equal to its holder count and every pool ledger
    consistent — checked after every operation, then drained to zero."""
    _drive(ops)


@pytest.mark.parametrize("seed", range(12))
def test_seeded_interleavings_hold_invariants(seed):
    """The same interpreter under a deterministic PRNG schedule: runs in
    every environment, hypothesis installed or not."""
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(0, N_OPS)), int(rng.integers(0, 32)),
            int(rng.integers(0, 32)))
           for _ in range(120)]
    _drive(ops)


def test_slab_swap_round_trips_state_payload():
    """StateSlab parks carry the actual state bytes: slot payloads survive
    the host round trip and CoW copies diverge without back-propagating."""
    _, slab = _stub_stores()
    a = slab.alloc()
    slab.device.write(a.idx, "h0")
    (a2,) = slab.fork([a])
    assert a2 is a and a.shared
    mine = slab.cow_into(a, slab.alloc())
    assert slab.device.read(mine.idx) == "h0"
    slab.device.write(mine.idx, "h1")
    assert slab.device.read(a.idx) == "h0", "CoW must not leak back"
    h = slab.swap_out(mine)
    assert h.tier == HOST and slab.swapped_out == 1
    back = slab.swap_in(h, slab.alloc())
    assert str(slab.device.read(back.idx)) == "h1"
    for blk in (a, back):
        slab.decref(blk)
    assert slab.device.pool.num_used == 0 and slab.host.num_used == 0
