"""SBP abstraction (§3.1.3): shard shapes, boxing costs, signatures."""
from _hypothesis_compat import given, settings, st

from repro.core.sbp import (B, P, Placement, S, boxing_cost, boxing_ops,
                            memory_bytes, shard_shape, valid_ndsbps)

PL = Placement(("data", "model"), (4, 2))


def test_shard_shape():
    assert shard_shape((8, 16), (S(0), S(1)), PL) == (2, 8)
    assert shard_shape((8, 16), (B, B), PL) == (8, 16)
    assert shard_shape((6, 16), (S(0), B), PL) is None  # 6 % 4 != 0


def test_memory_bytes():
    assert memory_bytes((8, 16), (S(0), S(1)), PL, 2) == 2 * 2 * 8
    assert memory_bytes((8, 16), (B, B), PL, 2) == 2 * 8 * 16


def test_boxing_kinds():
    shape = (8, 16)
    ops = boxing_ops((S(0), B), (B, B), shape, PL)
    assert ops == [("all-gather", 2 * 8 * 16 // 4 * 4, 4)]
    ops = boxing_ops((P, B), (B, B), shape, PL)
    assert ops[0][0] == "all-reduce"
    ops = boxing_ops((P, B), (S(0), B), shape, PL)
    assert ops[0][0] == "reduce-scatter"
    ops = boxing_ops((S(0), B), (S(1), B), shape, PL)
    assert ops[0][0] == "all-to-all"
    assert boxing_ops((B, B), (S(0), B), shape, PL) == [("slice", 0, 4)]


def test_all_reduce_twice_all_gather():
    shape = (64, 64)
    ar = boxing_cost((P, B), (B, B), shape, PL)
    ag = boxing_cost((S(0), B), (B, B), shape, PL)
    assert ar > ag  # 2x the ring traffic


def test_valid_ndsbps_divisibility():
    nds = valid_ndsbps((8, 6), PL)
    # model axis (size 2): S(1) valid on dim of size 6; data axis (4): not
    assert (S(0), S(1)) in nds
    assert all(shard_shape((8, 6), nd, PL) is not None for nd in nds)


@given(st.tuples(st.sampled_from([4, 8, 16, 64]), st.sampled_from([4, 8, 32])))
@settings(max_examples=20, deadline=None)
def test_boxing_cost_nonnegative(shape):
    for src in valid_ndsbps(shape, PL, allow_partial=True):
        for dst in valid_ndsbps(shape, PL):
            c = boxing_cost(src, dst, shape, PL)
            assert c is None or c >= 0.0
