"""Graceful degradation when the optional ``hypothesis`` [test] extra is
absent: property-based tests skip instead of failing the whole module's
collection, and the deterministic tests alongside them still run.

Usage in test modules:

    from _hypothesis_compat import given, settings, st
"""
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _InertStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute access /
        call / decoration returns another inert object, so module-level
        strategy definitions evaluate without hypothesis installed."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _InertStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg on purpose: pytest must not read the wrapped
            # function's parameters as fixture requests
            def skipper():
                pytest.skip("hypothesis not installed (pip install .[test])")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco
