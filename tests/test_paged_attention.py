"""Parity suite for the paged flash-attention Pallas kernel.

The kernel (``repro.kernels.paged_attention``) runs in interpret mode on CPU
and must match the dense-gather oracle (``ref.paged_attention_ref`` /
``ref.paged_attention_chunk_ref``) to <= 1e-4 across ragged ``seq_lens``,
null-block table padding, single-block requests, non-divisible block sizes,
GQA ratios, and every ``pages_per_fetch`` the cost model can pick.  The last
tests exercise the model-level dispatch flag (REPRO_PAGED_ATTN) end to end.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOL = 1e-4  # the PR's acceptance bound


def _pool(b, m, bs, kv, hd, seed=0, n_extra=2, dtype=jnp.float32):
    """Random pool + per-row tables of m distinct non-null blocks."""
    rng = np.random.default_rng(seed)
    n = b * m + 1 + n_extra
    k_pages = jnp.asarray(rng.normal(size=(n, bs, kv, hd)) * 0.4, dtype)
    v_pages = jnp.asarray(rng.normal(size=(n, bs, kv, hd)) * 0.4, dtype)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, n))[:b * m].reshape(b, m), jnp.int32)
    return k_pages, v_pages, tables, rng


def _assert_decode_parity(q, k_pages, v_pages, tables, lens, pages_per_fetch,
                          tol=TOL):
    out = ops.paged_attention(q, k_pages, v_pages, tables, lens,
                              pages_per_fetch=pages_per_fetch)
    want = ref.paged_attention_ref(q, k_pages, v_pages, tables, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("pages_per_fetch", [1, 2, 3, 4])
def test_decode_parity_ragged_lens(pages_per_fetch):
    """Every row at its own depth, including length-1 and full-span rows."""
    b, m, bs, h, kv, hd = 4, 4, 8, 8, 2, 32
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.float32)
    lens = jnp.asarray([1, 7, 16, 29], jnp.int32)
    _assert_decode_parity(q, k_pages, v_pages, tables, lens, pages_per_fetch)


def test_decode_null_block_padding():
    """Table tails padded with the null block (entry 0) past each row's
    length must contribute nothing — the engine always pads this way."""
    b, m, bs, h, kv, hd = 3, 4, 8, 4, 2, 32
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=1)
    # rows use 1 / 2 / 3 blocks; zero the rest of each table
    used = [1, 2, 3]
    tbl = np.asarray(tables).copy()
    for i, u in enumerate(used):
        tbl[i, u:] = 0
    tables = jnp.asarray(tbl)
    lens = jnp.asarray([u * bs - 3 for u in used], jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.float32)
    for p in (1, 2, 4):
        _assert_decode_parity(q, k_pages, v_pages, tables, lens, p)


def test_decode_single_block_requests():
    """M == 1 tables: one page per request, pages_per_fetch clamps to 1."""
    b, m, bs, h, kv, hd = 2, 1, 8, 4, 4, 16
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=2)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.float32)
    lens = jnp.asarray([1, 5], jnp.int32)
    for p in (1, 4):  # 4 > M exercises the clamp
        _assert_decode_parity(q, k_pages, v_pages, tables, lens, p)


@pytest.mark.parametrize("bs", [3, 5, 7])
def test_decode_non_divisible_block_sizes(bs):
    """Block sizes that divide neither the lens nor pages_per_fetch*m."""
    b, m, h, kv, hd = 2, 5, 4, 2, 16
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=3)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.float32)
    lens = jnp.asarray([bs + 1, 3 * bs - 2], jnp.int32)
    for p in (1, 2, 3):  # 2 and 3 don't divide m=5 -> wrapper pads the table
        _assert_decode_parity(q, k_pages, v_pages, tables, lens, p)


@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
def test_decode_gqa_ratios(h, kv):
    b, m, bs, hd = 2, 3, 4, 16
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=4)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.float32)
    lens = jnp.asarray([5, 12], jnp.int32)
    _assert_decode_parity(q, k_pages, v_pages, tables, lens, 2)


def test_decode_bf16_pages():
    """bf16 pool, f32 accumulation: looser tolerance, same structure."""
    b, m, bs, h, kv, hd = 2, 3, 8, 4, 2, 32
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=5,
                                          dtype=jnp.bfloat16)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.4, jnp.bfloat16)
    lens = jnp.asarray([9, 20], jnp.int32)
    out = ops.paged_attention(q, k_pages, v_pages, tables, lens,
                              pages_per_fetch=2)
    want = ref.paged_attention_ref(q, k_pages, v_pages, tables, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("start", [0, 8, 11])
def test_chunk_parity(start):
    """Prefill chunks at several offsets, crossing page boundaries."""
    b, m, bs, h, kv, hd, c = 1, 4, 8, 4, 2, 32, 8
    k_pages, v_pages, tables, rng = _pool(b, m, bs, kv, hd, seed=6)
    q = jnp.asarray(rng.normal(size=(b, c, h, hd)) * 0.4, jnp.float32)
    chunk_pos = jnp.arange(start, start + c, dtype=jnp.int32)
    kv_lens = jnp.asarray([start + c], jnp.int32)
    for p in (1, 2, 3):
        out = ops.paged_attention_chunk(q, k_pages, v_pages, tables,
                                        chunk_pos, kv_lens,
                                        pages_per_fetch=p)
        want = ref.paged_attention_chunk_ref(q, k_pages, v_pages, tables,
                                             chunk_pos, kv_lens)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=TOL, atol=TOL)


# ---------------------------------------------------------------------------
# Model-level dispatch (REPRO_PAGED_ATTN flag)
# ---------------------------------------------------------------------------

def _attn_setup(seed=0):
    from repro.configs.base import get_config, reduced_config
    from repro.models import attention as attn
    cfg = reduced_config(get_config("qwen3-0.6b"))
    p = attn.init_attention(cfg, jax.random.PRNGKey(seed), jnp.float32)
    return cfg, p


def test_dispatch_decode_kernel_matches_gather(monkeypatch):
    """attention_decode_block_paged under REPRO_PAGED_ATTN=kernel must
    reproduce the gather path bit-for-tolerance, caches included."""
    from repro.models import attention as attn
    cfg, p = _attn_setup()
    b, m, bs, hd = 3, 4, 8, cfg.resolved_head_dim
    n = b * m + 1
    rng = np.random.default_rng(8)
    k_pages = jnp.asarray(rng.normal(size=(n, bs, cfg.n_kv_heads, hd)) * 0.3,
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n, bs, cfg.n_kv_heads, hd)) * 0.3,
                          jnp.float32)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, n)).reshape(b, m), jnp.int32)
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)) * 0.3, jnp.float32)
    lens = jnp.asarray([0, 9, 26], jnp.int32)   # includes a fresh row

    monkeypatch.setenv("REPRO_PAGED_ATTN", "kernel")
    ok, kk, vk = attn.attention_decode_block_paged(
        cfg, p, x, k_pages, v_pages, tables, lens)
    monkeypatch.setenv("REPRO_PAGED_ATTN", "gather")
    og, kg, vg = attn.attention_decode_block_paged(
        cfg, p, x, k_pages, v_pages, tables, lens)
    np.testing.assert_allclose(np.asarray(ok), np.asarray(og),
                               rtol=TOL, atol=TOL)
    np.testing.assert_array_equal(np.asarray(kk), np.asarray(kg))
    np.testing.assert_array_equal(np.asarray(vk), np.asarray(vg))


def test_dispatch_prefill_kernel_and_m_used_match_full_gather(monkeypatch):
    """The prefill chunk path: (a) restricting to m_used blocks changes
    nothing (the satellite fix is mask-invariant), (b) the kernel path
    matches the gather path under the same restriction."""
    from repro.models import attention as attn
    cfg, p = _attn_setup(seed=1)
    m, bs, hd, c = 4, 8, cfg.resolved_head_dim, 8
    n = m + 3
    rng = np.random.default_rng(9)
    k_pages = jnp.asarray(rng.normal(size=(n, bs, cfg.n_kv_heads, hd)) * 0.3,
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n, bs, cfg.n_kv_heads, hd)) * 0.3,
                          jnp.float32)
    table = jnp.asarray([[2, 5, 1, 0]], jnp.int32)
    start, prompt_len = 8, 13          # chunk runs past the prompt (padding)
    x = jnp.asarray(rng.normal(size=(1, c, cfg.d_model)) * 0.3, jnp.float32)
    chunk_pos = jnp.arange(start, start + c, dtype=jnp.int32)
    m_used = -(-(start + c) // bs)

    monkeypatch.setenv("REPRO_PAGED_ATTN", "gather")
    o_full, kf, vf = attn.attention_prefill_chunk_block(
        cfg, p, x, k_pages, v_pages, table, chunk_pos,
        jnp.int32(prompt_len))
    o_used, ku, vu = attn.attention_prefill_chunk_block(
        cfg, p, x, k_pages, v_pages, table, chunk_pos,
        jnp.int32(prompt_len), m_used=m_used)
    monkeypatch.setenv("REPRO_PAGED_ATTN", "kernel")
    o_kern, kk, vk = attn.attention_prefill_chunk_block(
        cfg, p, x, k_pages, v_pages, table, chunk_pos,
        jnp.int32(prompt_len), m_used=m_used)

    # only the first prompt_len - start rows are real; the engine discards
    # the padding rows' outputs, so parity is asserted on the real ones
    real = prompt_len - start
    np.testing.assert_allclose(np.asarray(o_used)[:, :real],
                               np.asarray(o_full)[:, :real],
                               rtol=TOL, atol=TOL)
    np.testing.assert_allclose(np.asarray(o_kern)[:, :real],
                               np.asarray(o_full)[:, :real],
                               rtol=TOL, atol=TOL)
    for got, want in ((ku, kf), (vu, vf), (kk, kf), (vk, vf)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_plan_routing():
    """The engine-side plumbing: KernelPlan's kv tile -> pages per fetch."""
    from repro.core.codegen import KernelPlan, paged_pages_per_fetch
    plan = KernelPlan(paged_block_kv=64)
    assert paged_pages_per_fetch(plan, block_size=8, max_blocks_per_seq=16) == 8
    assert paged_pages_per_fetch(plan, block_size=8, max_blocks_per_seq=4) == 4
    assert paged_pages_per_fetch(plan, block_size=256, max_blocks_per_seq=8) == 1

    from repro.models import attention as attn
    attn.set_paged_plan(3)
    assert attn.paged_plan()["pages_per_fetch"] == 3
    attn.set_paged_plan(1)
