"""Multi-LoRA multiplexing: N tenants over one shared paged base.

Engine-level contracts — base-identity (adapter_id=None is bitwise the
pre-LoRA engine, structurally: no lora ops traced), rank-0 token identity,
adversarial prefix isolation (same prompt, different adapters), terminal
finishers decref'ing adapter slots — plus the gateway's ``base:adapter``
routing.  The live-HTTP end-to-end runs under ``-m multilora`` (the
multilora-smoke CI job); everything else is fast-lane."""
import asyncio
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    return cfg, fns, params


def _engine(cfg, params, **kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("block_size", 4)
    kw.setdefault("plan_kernels", False)
    kw.setdefault("mesh", False)
    return ServeEngine(cfg, params, **kw)


def _run(eng, *reqs):
    for r in reqs:
        eng.submit(r)
    eng.run_until_done(max_steps=800)
    assert all(r.done for r in reqs)
    return [list(r.out) for r in reqs]


PROMPT = [3, 5, 7, 11, 13, 17, 19, 23]


# ---------------------------------------------------------------------------
# identity contracts
# ---------------------------------------------------------------------------

def test_base_request_bitwise_identical_with_adapters_loaded(setup):
    """adapter_id=None must be the pre-LoRA engine, bit for bit — even on
    an engine that has tenants resident (their slab must not perturb base
    rows)."""
    cfg, fns, params = setup
    plain = _engine(cfg, params)
    [want] = _run(plain, Request(rid=0, prompt=list(PROMPT), max_new=6))

    eng = _engine(cfg, params)
    eng.load_adapter("tenant-a")
    eng.adapters.pin("tenant-a")
    [got] = _run(eng, Request(rid=0, prompt=list(PROMPT), max_new=6))
    assert got == want


def test_all_base_batch_traces_no_lora_ops(setup):
    """Structural half of the identity contract: a batch without adapter
    rows never attaches ``batch['lora']``, so the traced decode graph
    contains no lora ops at all — identity by absence, not by a zero-add."""
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    eng.load_adapter("tenant-a")        # slab exists; base batches ignore it
    m = eng.max_blocks_per_seq
    batch = {"token": jnp.zeros((2, 1), jnp.int32),
             "block_tables": jnp.zeros((2, m), jnp.int32),
             "seq_lens": jnp.ones((2,), jnp.int32)}
    base_jaxpr = str(jax.make_jaxpr(fns.decode_paged)(params, eng.cache,
                                                      batch))
    assert "lora" not in base_jaxpr

    batch["lora"] = {"ids": jnp.asarray([0, -1], jnp.int32),
                     "slabs": eng.adapters.slabs()}
    mixed_jaxpr = str(jax.make_jaxpr(fns.decode_paged)(params, eng.cache,
                                                       batch))
    assert "lora" in mixed_jaxpr

    # and the engine only attaches the descriptor when a row holds a slot
    assert eng._lora_descriptor(np.asarray([-1, -1], np.int32)) is None
    assert eng._lora_descriptor(np.asarray([-1, 0], np.int32)) is not None


def test_rank0_adapter_is_token_identical_to_base(setup):
    """A rank-0 adapter is all slab padding: its delta is exactly zero, so
    its stream equals the base stream token for token."""
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    eng.load_adapter("null-tenant", rank=0)
    base, adapted = _run(
        eng,
        Request(rid=0, prompt=list(PROMPT), max_new=6),
        Request(rid=1, prompt=list(PROMPT), max_new=6,
                adapter_id="null-tenant"))
    assert adapted == base


def test_real_adapter_diverges_from_base(setup):
    """The converse guard: a nonzero adapter must actually change tokens,
    otherwise the identity tests above prove nothing."""
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    eng.load_adapter("tenant-a")
    base, adapted = _run(
        eng,
        Request(rid=0, prompt=list(PROMPT), max_new=8),
        Request(rid=1, prompt=list(PROMPT), max_new=8,
                adapter_id="tenant-a"))
    assert adapted != base


# ---------------------------------------------------------------------------
# prefix isolation
# ---------------------------------------------------------------------------

def test_same_prompt_different_adapters_never_cross_serve(setup):
    """The adversarial case: tenant B asks tenant A's exact prompt.  B must
    re-prefill from scratch (a prefix hit would replay A's activations) and
    still produce exactly what a fresh single-tenant engine produces."""
    cfg, fns, params = setup
    # headroom so admission reservations don't evict the prefix registry
    eng = _engine(cfg, params, max_batch=1, num_blocks=24,
                  prefix_cache_blocks=6)
    eng.load_adapter("tenant-a")
    eng.load_adapter("tenant-b")

    [out_a] = _run(eng, Request(rid=0, prompt=list(PROMPT), max_new=5,
                                adapter_id="tenant-a"))
    eng.reset_metrics()
    [out_b] = _run(eng, Request(rid=1, prompt=list(PROMPT), max_new=5,
                                adapter_id="tenant-b"))
    assert eng.metrics().re_prefill_avoided == 0   # no cross-tenant adoption

    ref = _engine(cfg, params, max_batch=1)
    ref.load_adapter("tenant-b")
    [want_b] = _run(ref, Request(rid=0, prompt=list(PROMPT), max_new=5,
                                 adapter_id="tenant-b"))
    assert out_b == want_b
    assert out_b != out_a

    # within-tenant reuse still works: A again adopts A's registered prefix
    eng.reset_metrics()
    [out_a2] = _run(eng, Request(rid=2, prompt=list(PROMPT), max_new=5,
                                 adapter_id="tenant-a"))
    assert eng.metrics().re_prefill_avoided > 0
    assert out_a2 == out_a                         # reuse changed no tokens


# ---------------------------------------------------------------------------
# terminal finishers decref
# ---------------------------------------------------------------------------

def test_cancel_decrefs_without_evicting_pinned_tenants(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    eng.load_adapter("system")
    eng.adapters.pin("system")
    eng.load_adapter("tenant-a")
    req = Request(rid=0, prompt=list(PROMPT), max_new=20,
                  adapter_id="tenant-a")
    eng.submit(req)
    assert eng.adapters.refcount("tenant-a") == 1
    for _ in range(3):
        eng.step()
    eng.cancel(req.rid)
    eng.step()
    assert req.finish_reason == "cancelled"
    assert eng.adapters.refcount("tenant-a") == 0
    assert eng.adapters.is_loaded("system")        # pinned neighbour intact
    eng.check_invariants()


def test_expired_request_decrefs_adapter(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    eng.load_adapter("tenant-a")
    req = Request(rid=0, prompt=list(PROMPT), max_new=20,
                  adapter_id="tenant-a", deadline_ms=0.01)
    eng.submit(req)
    eng.run_until_done(max_steps=200)
    assert req.done and req.finish_reason in ("expired", "shed")
    assert eng.adapters.refcount("tenant-a") == 0
    eng.check_invariants()


def test_unknown_adapter_is_rejected_not_crashed(setup):
    cfg, fns, params = setup
    eng = _engine(cfg, params)
    req = Request(rid=0, prompt=list(PROMPT), max_new=4, adapter_id="nope")
    eng.submit(req)
    assert req.rejected and "unknown adapter" in req.reject_reason
    eng.check_invariants()


@pytest.mark.multilora
@pytest.mark.skipif(len(jax.devices()) < 2, reason="needs >= 2 devices")
def test_sharded_engine_refuses_adapters(setup):
    """Multi-LoRA on a sharded serve mesh is explicitly unsupported: both
    the load path and the submit path must refuse loudly, never silently
    serve a replicated slab on a partitioned engine."""
    from repro.launch.mesh import make_serve_mesh

    cfg, fns, params = setup
    eng = _engine(cfg, params, mesh=make_serve_mesh(2))
    with pytest.raises(NotImplementedError, match="sharded serve mesh"):
        eng.load_adapter("tenant-a")
    with pytest.raises(NotImplementedError, match="sharded serve mesh"):
        eng.submit(Request(rid=0, prompt=list(PROMPT), max_new=4,
                           adapter_id="tenant-a"))


# ---------------------------------------------------------------------------
# gateway routing
# ---------------------------------------------------------------------------

async def _raw(host, port, method, path, payload=None):
    body = b"" if payload is None else json.dumps(payload).encode()
    reader, writer = await asyncio.open_connection(host, port)
    try:
        head = f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
        if body:
            head += ("Content-Type: application/json\r\n"
                     f"Content-Length: {len(body)}\r\n")
        writer.write(head.encode() + b"\r\n" + body)
        await writer.drain()
        status = int((await reader.readline()).split()[1])
        while (await reader.readline()) not in (b"\r\n", b"\n", b""):
            pass
        return status, await reader.read()
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def _stream_ids(data: bytes):
    ids, model = [], ""
    for ln in data.split(b"\n"):
        ln = ln.strip()
        if not ln.startswith(b"data: ") or ln == b"data: [DONE]":
            continue
        chunk = json.loads(ln[len(b"data: "):])
        model = chunk.get("model", model)
        ids += chunk["choices"][0].get("token_ids") or []
    return ids, model


@pytest.mark.multilora
def test_gateway_routes_adapters_end_to_end(setup):
    """Live HTTP: ``m:tenant`` resolves per request, ``/v1/models`` lists
    adapter cards under their parent, unknown adapters 404, and every
    stream echoes the tenant-qualified model tag."""
    from repro.serve.async_engine import AsyncServeEngine
    from repro.serve.gateway import (ByteTokenizer, Gateway, GatewayModel,
                                     Router)

    cfg, fns, params = setup
    eng = _engine(cfg, params)
    model = GatewayModel(model_id="m",
                         async_engine=AsyncServeEngine(eng, model_id="m"),
                         tokenizer=ByteTokenizer(cfg.vocab),
                         adapters=["tenant-a", "tenant-b"])

    async def go():
        async with Gateway(Router([model]), port=0) as gw:
            async def ask(mid):
                return await _raw(gw.host, gw.port, "POST",
                                  "/v1/completions",
                                  {"model": mid, "prompt": PROMPT,
                                   "max_tokens": 5, "stream": True})
            st_m, models = await _raw(gw.host, gw.port, "GET", "/v1/models")
            st_a, data_a = await ask("m:tenant-a")
            st_b, data_b = await ask("m:tenant-b")
            st_base, data_base = await ask("m")
            st_sole, data_sole = await ask(":tenant-a")  # sole-model form
            st_404, _ = await ask("m:nope")
            return (st_m, models, st_a, data_a, st_b, data_b, st_base,
                    data_base, st_sole, data_sole, st_404)

    (st_m, models, st_a, data_a, st_b, data_b, st_base, data_base,
     st_sole, data_sole, st_404) = asyncio.run(go())

    assert st_m == 200
    cards = {c["id"]: c for c in json.loads(models)["data"]}
    assert "m" in cards and not cards["m"].get("parent")
    assert cards["m:tenant-a"]["parent"] == "m"
    assert cards["m:tenant-a"]["adapter"] == "tenant-a"

    assert st_a == st_b == st_base == st_sole == 200
    ids_a, tag_a = _stream_ids(data_a)
    ids_b, tag_b = _stream_ids(data_b)
    ids_base, tag_base = _stream_ids(data_base)
    ids_sole, _ = _stream_ids(data_sole)
    assert (tag_a, tag_b, tag_base) == ("m:tenant-a", "m:tenant-b", "m")
    assert len({tuple(ids_a), tuple(ids_b), tuple(ids_base)}) == 3
    assert ids_sole == ids_a            # ":tenant-a" == "m:tenant-a"
    assert st_404 == 404

    # all refs returned once the streams drained
    assert eng.adapters.refcount("tenant-a") == 0
    assert eng.adapters.refcount("tenant-b") == 0
