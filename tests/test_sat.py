"""DPLL SAT + weighted partial MaxSAT (property-tested vs brute force)."""
import itertools

from _hypothesis_compat import given, settings, st

from repro.core.sat import sat_solve, wpmaxsat


def test_sat_simple():
    # (x1 | x2) & (!x1 | x2) & (!x2 | x3)
    m = sat_solve(3, [[1, 2], [-1, 2], [-2, 3]])
    assert m is not None
    assert m.get(2) is True and m.get(3) is True


def test_unsat():
    assert sat_solve(1, [[1], [-1]]) is None


def test_wpmaxsat_prefers_cheap():
    # must pick x1 or x2; x1 costs 5, x2 costs 1
    r = wpmaxsat(2, [[1, 2]], [(-1, 5.0), (-2, 1.0)])
    assert r is not None
    assert r.assignment.get(2) is True or r.cost <= 1.0
    assert abs(r.cost - 1.0) < 1e-9


def _brute_force(n, hard, soft):
    best = None
    for bits in itertools.product([False, True], repeat=n):
        assign = {i + 1: bits[i] for i in range(n)}
        if not all(any(assign[abs(l)] == (l > 0) for l in cl) for cl in hard):
            continue
        cost = sum(w for lit, w in soft if assign[abs(lit)] != (lit > 0))
        if best is None or cost < best:
            best = cost
    return best


@st.composite
def maxsat_instance(draw):
    n = draw(st.integers(2, 6))
    n_clauses = draw(st.integers(1, 8))
    hard = []
    for _ in range(n_clauses):
        k = draw(st.integers(1, 3))
        cl = [draw(st.integers(1, n)) * draw(st.sampled_from([1, -1]))
              for _ in range(k)]
        hard.append(cl)
    soft = [(-(i + 1), float(draw(st.integers(1, 9))))
            for i in range(n) if draw(st.booleans())]
    return n, hard, soft


@given(maxsat_instance())
@settings(max_examples=60, deadline=None)
def test_wpmaxsat_matches_brute_force(inst):
    n, hard, soft = inst
    expected = _brute_force(n, hard, soft)
    r = wpmaxsat(n, hard, soft)
    if expected is None:
        assert r is None
    else:
        assert r is not None
        assert abs(r.cost - expected) < 1e-9
