"""Fault tolerance: straggler detection, retry-from-checkpoint, elasticity."""
import jax
import pytest

from repro.distributed.fault_tolerance import (FaultTolerantLoop,
                                               StragglerDetector,
                                               elastic_remesh)


def test_straggler_detector():
    d = StragglerDetector(threshold=3.0)
    for i in range(10):
        assert not d.record(i, 1.0)
    assert d.record(10, 10.0)
    assert len(d.events) == 1


def test_loop_recovers_from_transient_failure():
    saves = {}
    crashes = [5]

    def step_fn(state, step, batch):
        if step in crashes:
            crashes.remove(step)
            raise RuntimeError("node lost")
        return state + 1

    def save_fn(state, step):
        saves["latest"] = (state, step)

    def restore_fn(_state):
        return saves.get("latest")

    loop = FaultTolerantLoop(step_fn, save_fn, restore_fn)
    state, step = loop.run(0, 0, 10, checkpoint_every=2)
    assert step == 10
    assert state == 10  # replayed steps land on the same state
    assert loop.failures == 1 and loop.restores == 1


def test_loop_gives_up_after_max_retries():
    def step_fn(state, step, batch):
        raise RuntimeError("permanent")

    loop = FaultTolerantLoop(step_fn, lambda s, t: None, lambda s: None,
                             max_retries=2)
    with pytest.raises(RuntimeError):
        loop.run(0, 0, 5)


def test_elastic_remesh_single_device():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # losing a host from a 1-wide data axis leaves nothing -> error
    with pytest.raises(RuntimeError):
        elastic_remesh(mesh, lost_hosts=1)
