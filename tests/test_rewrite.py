"""Table 1 transpose rules + the Fig. 2 phase-ordering example."""
import jax.numpy as jnp
import numpy as np

from repro.core.codegen import compile_term
from repro.core.egraph import EGraph
from repro.core.extraction import greedy_extract, extract_term
from repro.core.rewrite import TRANSPOSE_RULES
from repro.core.tensor_ir import (binary, compose_perms, inp, invert_perm,
                                  transpose, unary)
from repro.core.vectorize import count_ops


def _optimize(term):
    eg = EGraph()
    root = eg.add_term(term)
    eg.saturate(TRANSPOSE_RULES, max_iters=10)
    _, choice = greedy_extract(eg, root)
    return extract_term(eg, root, choice)


def test_perm_utils():
    p = (2, 0, 1)
    assert invert_perm(p) == (1, 2, 0)
    assert compose_perms(p, invert_perm(p)) == (0, 1, 2)


def test_fold_two_trans():
    A = inp("A", (4, 8))
    t = transpose(transpose(A, (1, 0)), (1, 0))
    out = _optimize(t)
    assert count_ops(out, "transpose") == 0


def test_fig2_phase_ordering():
    """Out = T(Unary(Binary(T(A), B))): greedy local rewriting can strand a
    transpose; saturation finds the 1-transpose form."""
    A, B = inp("A", (64, 128)), inp("B", (128, 64))
    term = transpose(unary(binary(transpose(A, (1, 0)), B, kind="add"),
                           kind="exp"), (1, 0))
    assert count_ops(term, "transpose") == 2
    out = _optimize(term)
    assert count_ops(out, "transpose") <= 1


def test_rewrites_preserve_semantics():
    rng = np.random.default_rng(0)
    A, B = inp("A", (16, 8)), inp("B", (8, 16))
    term = transpose(unary(binary(transpose(A, (1, 0)), B, kind="add"),
                           kind="exp"), (1, 0))
    out = _optimize(term)
    env = {"A": jnp.array(rng.normal(size=(16, 8)), jnp.float32),
           "B": jnp.array(rng.normal(size=(8, 16)), jnp.float32)}
    ref = compile_term(term)(**env)
    opt = compile_term(out)(**env)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(opt), rtol=1e-5)
