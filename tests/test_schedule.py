"""Auto Schedule (§3.2): MINLP capacity/coverage + MCTS improvement."""
from repro.core.schedule import (attention_tile_graph, auto_schedule,
                                 matmul_tile_graph)
from repro.core.schedule.mcts import MCTS, enumerate_actions
from repro.core.schedule.minlp import MINLPSolver, VMEM_BYTES
from repro.core.codegen import kernel_plan


def test_minlp_capacity_respected():
    tg = matmul_tile_graph(4096, 4096, 4096)
    sched = MINLPSolver().solve(tg)
    assert sched.feasible
    assert sched.vmem_peak <= VMEM_BYTES
    tiles = sched.tiles[0]
    for l in ("i", "j", "k"):
        assert tg.extent(l) % tiles[l] == 0  # domain coverage (Eq. 10)


def test_merge_action_legality():
    tg = attention_tile_graph(1024, 128)
    acts = enumerate_actions(tg)
    merges = [a for a in acts if a[0] == "merge"]
    # mm1 -> exp and exp -> mm2 are the only legal fusions
    assert ("merge", (0, 1)) in merges
    assert ("merge", (1, 2)) in merges
    assert ("merge", (0, 2)) not in merges


def test_fusion_reduces_memory_time():
    """exp is pure data movement: fusing it into mm1 must cut HBM traffic."""
    tg = attention_tile_graph(2048, 128)
    solver = MINLPSolver()
    unfused = solver.solve(tg)
    fused = solver.solve(tg.merge(0, 1))
    assert fused.t_mem < unfused.t_mem


def test_mcts_never_regresses():
    tg = attention_tile_graph(2048, 128)
    state, sched, baseline = auto_schedule(tg, iterations=20)
    assert sched.latency <= baseline.latency + 1e-15


def test_mcts_finds_fusion_when_memory_bound():
    # small head dim -> exp traffic dominates -> fusion should be chosen
    tg = attention_tile_graph(4096, 64)
    state, sched, baseline = auto_schedule(tg, iterations=30)
    fused_sizes = [len(g.ops) for g in state.groups]
    assert max(fused_sizes) >= 2


def test_kernel_plan_alignment():
    tg = matmul_tile_graph(2048, 2048, 2048)
    sched = MINLPSolver().solve(tg)
    plan = kernel_plan(sched)
    assert plan.block_m % 128 == 0
    assert plan.block_n % 128 == 0
    assert plan.block_k % 128 == 0
