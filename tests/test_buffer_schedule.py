"""Buffer schedule (§3.3.1): liveness, aliasing, bin-packing planners."""
import pytest

from repro.core.buffer_schedule import (BufferSpec, liveness_from_term,
                                        naive_peak, plan_greedy, plan_optimal,
                                        validate_plan)
from repro.core.tensor_ir import T, inp, matmul, unary


def test_liveness_intervals():
    x = inp("x", (8, 8))
    y = unary(unary(x, kind="exp"), kind="relu")
    bufs = liveness_from_term(y)
    assert bufs[0].end >= bufs[0].start
    # x is used by the first unary only
    assert bufs[0].end == 1


def test_alias_zero_copy():
    x = inp("x", (8, 8))
    v = T("reshape", x, shape=(64,))  # view op
    bufs = liveness_from_term(unary(x, kind="exp"))
    assert all(b.alias_of is None for b in bufs)


def test_reuse_beats_naive():
    x = inp("x", (64, 64))
    t = unary(unary(unary(x, kind="exp"), kind="relu"), kind="exp")
    bufs = liveness_from_term(t, dtype_bytes=4)
    off, peak = plan_greedy(bufs)
    assert validate_plan(bufs, off)
    assert peak < naive_peak(bufs)


def test_optimal_not_worse_than_greedy():
    t = matmul(unary(matmul(inp("a", (32, 32)), inp("b", (32, 32))),
                     kind="exp"), inp("c", (32, 32)))
    bufs = liveness_from_term(t, dtype_bytes=4)
    _, pg = plan_greedy(bufs)
    oo, po = plan_optimal(bufs)
    assert validate_plan(bufs, oo)
    assert po <= pg <= naive_peak(bufs)


def test_planners_always_valid():
    # property test degrades gracefully where the [test] extra isn't installed
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def interval_set(draw):
        n = draw(st.integers(2, 10))
        out = []
        for i in range(n):
            start = draw(st.integers(0, 20))
            end = start + draw(st.integers(1, 10))
            size = draw(st.sampled_from([64, 128, 256, 1024]))
            out.append(BufferSpec(f"b{i}", size, start, end))
        return out

    @given(interval_set())
    @settings(max_examples=50, deadline=None)
    def check(bufs):
        og, pg = plan_greedy(bufs)
        assert validate_plan(bufs, og)
        assert pg <= naive_peak(bufs)
        oo, po = plan_optimal(bufs)
        assert validate_plan(bufs, oo)
        assert po <= pg + 1e-9

    check()
