"""Smoke: the hillclimb harness drives ``repro.pipeline.compile`` in-process
and yields well-formed measurements for every cell + experiment — so ROADMAP
item 5 (measured-cost autotuning) starts from a harness that actually runs."""
from benchmarks import hillclimb


def test_quick_sweep_yields_wellformed_cells():
    results = hillclimb.main(quick=True)
    assert len(results) == len(hillclimb.CELLS) + len(hillclimb.EXPERIMENTS)
    for r in results:
        assert r["status"] == "ok", r.get("error")
        assert r["modeled_cost_s"] > 0
        assert r["modeled_speedup"] >= 1.0 - 1e-9
        assert set(r["pass_ms"]) >= {"rewrite", "extract", "buffer",
                                     "codegen"}
        assert r["buffer_peak"] <= r["buffer_naive"]
        assert fmtd(r)


def fmtd(r):
    line = hillclimb.fmt(r)
    assert "cost" in line and "compile" in line
    return line


def test_mesh_cell_actually_distributes():
    r = hillclimb.run_cell("mlp_tp16", quick=True)
    assert r["status"] == "ok"
    assert r.get("distribution_cost_s", 0) > 0
    assert "distribute" in r["pass_ms"]


def test_exact_extraction_never_worse_than_greedy():
    base = hillclimb.run_cell("attention", quick=True)
    exact = hillclimb.run_cell("attention", "t", dict(
        extraction="branch-and-bound"), quick=True)
    assert exact["modeled_cost_s"] <= base["modeled_cost_s"] + 1e-12


def test_quick_mode_leaves_no_cache_files(tmp_path, monkeypatch):
    monkeypatch.setattr(hillclimb, "RESULTS", tmp_path / "hillclimb")
    hillclimb.run_cell("matmul", quick=True)
    assert not (tmp_path / "hillclimb").exists()


def test_error_cells_are_reported_not_raised(monkeypatch):
    monkeypatch.setitem(hillclimb.CELLS, "boom",
                        (lambda quick: None, lambda quick: None))
    r = hillclimb.run_cell("boom", quick=True)
    assert r["status"] == "error" and "Traceback" in r["error"]
