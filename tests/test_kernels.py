"""Per-kernel allclose vs the ref.py oracles: shape + dtype sweeps
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import multi_head_attention

RNG = np.random.default_rng(42)


def _arr(shape, dtype, scale=0.5):
    return jnp.asarray(RNG.normal(size=shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 512, 128),
                                   (384, 256, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a, b = _arr((m, k), dtype), _arr((k, n), dtype)
    out = ops.matmul(a, b, block_m=128, block_n=128, block_k=128)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * k ** 0.5)


def test_matmul_block_divisibility_assert():
    a, b = _arr((100, 128), jnp.float32), _arr((128, 128), jnp.float32)
    with pytest.raises(AssertionError):
        ops.matmul(a, b, block_m=64, block_n=64, block_k=64)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("h,kv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_sweep(causal, h, kv):
    b, s, hd = 2, 256, 64
    q = _arr((b, s, h, hd), jnp.float32, 0.3)
    k = _arr((b, s, kv, hd), jnp.float32, 0.3)
    v = _arr((b, s, kv, hd), jnp.float32, 0.3)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_kv=64)
    want = multi_head_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    b, s, h, hd = 1, 128, 2, 64
    q = _arr((b, s, h, hd), jnp.bfloat16, 0.3)
    k = _arr((b, s, h, hd), jnp.bfloat16, 0.3)
    v = _arr((b, s, h, hd), jnp.bfloat16, 0.3)
    out = ops.flash_attention(q, k, v, block_q=64, block_kv=64)
    want = multi_head_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_attention_q_offset_decode_chunk():
    """Cross-attention of a q suffix against a longer kv prefix."""
    b, h, hd = 1, 2, 64
    sq, skv = 64, 256
    q = _arr((b, sq, h, hd), jnp.float32, 0.3)
    k = _arr((b, skv, h, hd), jnp.float32, 0.3)
    v = _arr((b, skv, h, hd), jnp.float32, 0.3)
    out = ops.flash_attention(q, k, v, causal=True, q_offset=skv - sq,
                              block_q=64, block_kv=64)
    want = multi_head_attention(q, k, v, causal=True, q_offset=skv - sq)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(64, 128), (256, 512), (8, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x, w = _arr(shape, dtype), _arr(shape[-1:], dtype)
    out = ops.rmsnorm(x, w)
    want = ref.rmsnorm_ref(x, w)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_rmsnorm_3d():
    x, w = _arr((2, 32, 256), jnp.float32), _arr((256,), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, w)),
                               np.asarray(ref.rmsnorm_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("t,d,n", [(16, 32, 8), (64, 128, 16), (32, 64, 4)])
def test_ssm_scan_sweep(t, d, n):
    b = 2
    a = jnp.asarray(RNG.uniform(0.6, 0.99, size=(b, t, d, n)), jnp.float32)
    bb = _arr((b, t, d, n), jnp.float32, 0.1)
    c = _arr((b, t, n), jnp.float32)
    h0 = _arr((b, d, n), jnp.float32, 0.1)
    y, hl = ops.ssm_scan(a, bb, c, h0, block_d=min(32, d))
    y_ref, hl_ref = jax.vmap(ref.ssm_scan_ref)(a, bb, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_ref),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t,chunk", [(32, 8), (24, 8), (19, 8), (16, 16), (7, 8)])
def test_ssm_scan_chunked_matches_oracle(t, chunk):
    """The chunked-prefill entry (state carried across kernel launches,
    identity-padded ragged tail) matches its sequential oracle AND the
    unchunked kernel bitwise on the carried state."""
    b, d, n = 2, 16, 4
    a = jnp.asarray(RNG.uniform(0.6, 0.99, size=(b, t, d, n)), jnp.float32)
    bb = _arr((b, t, d, n), jnp.float32, 0.1)
    c = _arr((b, t, n), jnp.float32)
    h0 = _arr((b, d, n), jnp.float32, 0.1)
    y, hl = ops.ssm_scan_chunked(a, bb, c, h0, chunk=chunk, block_d=16)
    y_ref, hl_ref = jax.vmap(
        lambda aa, bbb, cc, hh: ref.ssm_scan_chunked_ref(aa, bbb, cc, hh, chunk)
    )(a, bb, c, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hl), np.asarray(hl_ref),
                               rtol=1e-4, atol=1e-4)
    # identity pads are exact: chunked h_last == unchunked h_last bitwise
    y_full, h_full = ops.ssm_scan(a, bb, c, h0, block_d=16)
    assert np.array_equal(np.asarray(hl), np.asarray(h_full))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_full),
                               rtol=1e-5, atol=1e-5)


def test_ssm_scan_carries_state():
    """Chunked invocation with carried h == one long scan."""
    b, t, d, n = 1, 32, 16, 4
    a = jnp.asarray(RNG.uniform(0.6, 0.99, size=(b, t, d, n)), jnp.float32)
    bb = _arr((b, t, d, n), jnp.float32, 0.1)
    c = _arr((b, t, n), jnp.float32)
    h0 = jnp.zeros((b, d, n), jnp.float32)
    y_full, h_full = ops.ssm_scan(a, bb, c, h0, block_d=16)
    y1, h1 = ops.ssm_scan(a[:, :16], bb[:, :16], c[:, :16], h0, block_d=16)
    y2, h2 = ops.ssm_scan(a[:, 16:], bb[:, 16:], c[:, 16:], h1, block_d=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-5, atol=1e-5)
