"""End-to-end pipeline: golden numerics, caching, report shape, bridge."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.codegen import compile_term
from repro.core.tensor_ir import inp, matmul, unary
from repro.pipeline import (PASS_NAMES, CompileOptions, CompileTarget,
                            Compiler, cache_key, compile,
                            tile_graph_from_term)


def fig3_term():
    Q, K, V = inp("Q", (1024, 128)), inp("K", (128, 1024)), inp("V", (1024, 128))
    return matmul(unary(matmul(Q, K), kind="exp"), V)


def fig3_env():
    rng = np.random.default_rng(0)
    return {n: jnp.array(rng.normal(size=s) * 0.1, jnp.float32)
            for n, s in [("Q", (1024, 128)), ("K", (128, 1024)),
                         ("V", (1024, 128))]}


def test_golden_numerics_match_reference():
    """One-call compile on the quickstart Fig. 3 graph matches the reference
    compile_term interpretation to 1e-5."""
    term = fig3_term()
    res = Compiler().compile(term)
    env = fig3_env()
    ref = compile_term(term)(**env)
    got = res(**env)
    assert float(jnp.max(jnp.abs(got - ref))) < 1e-5
    # the pipeline actually vectorized: packed term differs and models faster
    assert res.report.modeled_speedup > 1.0
    assert res.term != term


def test_cache_hit_skips_saturation(tmp_path):
    term = fig3_term()
    c = Compiler(cache_dir=str(tmp_path))
    first = c.compile(term)
    second = c.compile(term)
    assert not first.report.cache_hit
    assert second.report.cache_hit
    assert c.stats == {"hits": 1, "misses": 1}
    # the hit only re-runs codegen — no search passes were re-timed
    assert second.report.pass_times["codegen"] >= 0.0
    assert second.report.total_seconds < first.report.total_seconds
    # numerics identical through the cached path
    env = fig3_env()
    np.testing.assert_allclose(np.asarray(first(**env)),
                               np.asarray(second(**env)))


def test_disk_cache_survives_new_compiler(tmp_path):
    term = fig3_term()
    Compiler(cache_dir=str(tmp_path)).compile(term)
    fresh = Compiler(cache_dir=str(tmp_path))
    res = fresh.compile(term)
    assert res.report.cache_hit
    assert fresh.stats["hits"] == 1


def test_module_level_compile_shares_cache():
    term = matmul(inp("a", (256, 256)), inp("b", (256, 256)))
    opts = CompileOptions(extraction="greedy", schedule=False)
    compile(term, options=opts)
    assert compile(term, options=opts).report.cache_hit


def test_report_shape():
    res = Compiler().compile(fig3_term(), options=CompileOptions(cache=False))
    r = res.report
    for name in ("rewrite", "extract", "vectorize", "schedule", "buffer",
                 "codegen"):
        assert name in r.pass_times, f"missing pass timing {name}"
        assert r.pass_times[name] >= 0.0
    assert set(r.pass_times) <= set(PASS_NAMES)
    # 1-device target: distribution skipped
    assert "distribute" not in r.pass_times
    assert r.distribution is None
    assert r.baseline_cost > 0 and r.optimized_cost > 0
    assert r.extraction_backend == "wpmaxsat"
    assert r.egraph["size_after_vectorize"] >= r.egraph["size_after_rewrite"]
    assert r.buffer["peak"] <= r.buffer["naive"]
    assert r.schedule is not None and r.schedule["latency"] > 0
    assert r.kernel_plan is not None
    assert r.total_seconds > 0
    assert len(r.cache_key) == 64


def test_multidevice_runs_distribution():
    term = matmul(unary(matmul(inp("x", (512, 256)), inp("w1", (256, 512))),
                        kind="exp"), inp("w2", (512, 256)))
    target = CompileTarget(mesh_axes=("data", "model"), mesh_sizes=(2, 2))
    res = Compiler().compile(term, target=target,
                             options=CompileOptions(extraction="greedy",
                                                    cache=False))
    assert "distribute" in res.report.pass_times
    d = res.report.distribution
    assert d is not None and d["cost"] > 0 and d["peak_memory"] > 0
    assert d["assignments"]


def test_memory_capped_distribution_respects_cap():
    # the quickstart MLP: unconstrained peak is ~30 MB/dev, so 25 MB binds
    term = matmul(unary(matmul(inp("x", (4096, 1024)),
                               inp("w1", (1024, 4096))),
                        kind="exp"), inp("w2", (4096, 1024)))
    cap = 25_000_000
    target = CompileTarget(mesh_axes=("data", "model"), mesh_sizes=(4, 4),
                           memory_capacity=cap)
    res = Compiler().compile(term, target=target,
                             options=CompileOptions(extraction="greedy",
                                                    cache=False))
    assert res.report.distribution["peak_memory"] <= cap


def test_extraction_backends_agree_on_cost():
    term = fig3_term()
    costs = {}
    for backend in ("greedy", "wpmaxsat"):
        res = Compiler().compile(
            term, options=CompileOptions(extraction=backend, schedule=False,
                                         cache=False))
        costs[backend] = res.report.optimized_cost
    # the optimal extractor can't be worse than greedy
    assert costs["wpmaxsat"] <= costs["greedy"] + 1e-12


def test_invalid_options_rejected():
    with pytest.raises(ValueError):
        CompileOptions(extraction="magic")
    with pytest.raises(ValueError):
        CompileOptions(buffer_plan="quantum")
    with pytest.raises(TypeError):
        Compiler().compile("not a term")


def test_cache_key_sensitivity():
    term = fig3_term()
    base = cache_key(term, CompileTarget(), CompileOptions())
    assert base != cache_key(term, CompileTarget(mesh_sizes=(2,)),
                             CompileOptions())
    assert base != cache_key(term, CompileTarget(),
                             CompileOptions(extraction="greedy"))
    other = matmul(inp("a", (128, 128)), inp("b", (128, 128)))
    assert base != cache_key(other, CompileTarget(), CompileOptions())
    assert base == cache_key(fig3_term(), CompileTarget(), CompileOptions())


def test_tile_graph_bridge_structure():
    tg = tile_graph_from_term(fig3_term())
    assert tg is not None
    # three compute ops, each its own group initially
    assert len(tg.ops) == 3 and len(tg.groups) == 3
    # the matmul contraction loops exist: 2 matmuls -> loops beyond out dims
    mm_ops = [o for o in tg.ops if o.ukernel == "matmul"]
    assert all(len(o.loops) == 3 for o in mm_ops)
    # producer/consumer buffers are shared so MCTS can fuse
    names = {o.write.name for o in tg.ops}
    reads = {b.name for o in tg.ops for b in o.reads}
    assert names & reads


def test_tile_graph_bridge_rejects_unsupported():
    from repro.core.tensor_ir import transpose
    t = transpose(inp("x", (64, 32)), (1, 0))
    assert tile_graph_from_term(t) is None
