"""tools.linkcheck: the stdlib markdown link walker CI runs over the docs."""
from pathlib import Path

from tools.linkcheck import anchors_of, check_file, main, slugify

ROOT = Path(__file__).resolve().parents[1]


def test_slugify_matches_github_style():
    assert slugify("The Serve Stack") == "the-serve-stack"
    assert slugify("`REPRO_*` env knobs") == "repro_-env-knobs"
    assert slugify("Tier-1 tests & CI") == "tier-1-tests--ci"


def test_detects_broken_and_valid_links(tmp_path):
    (tmp_path / "sub").mkdir()
    (tmp_path / "sub" / "real.md").write_text("# A Heading\nbody\n")
    doc = tmp_path / "doc.md"
    doc.write_text(
        "[ok](sub/real.md) [ok-anchor](sub/real.md#a-heading)\n"
        "[self](#local) \n## Local\n"
        "[gone](missing.md) [bad-anchor](sub/real.md#nope)\n"
        "[web](https://example.com/x) badge\n"
        "```\n[inside a fence](also_missing.md)\n```\n")
    errs = check_file(doc.resolve(), tmp_path.resolve())
    assert len(errs) == 2  # missing.md + the #nope anchor; the rest resolve
    joined = "\n".join(errs)
    assert "missing.md" in joined and "nope" in joined


def test_self_anchor_and_fence_handling(tmp_path):
    doc = tmp_path / "d.md"
    doc.write_text("## Real Section\n[jump](#real-section)\n"
                   "```\n[fenced](#not-a-heading)\n```\n")
    assert check_file(doc.resolve(), tmp_path.resolve()) == []


def test_outside_root_links_skipped(tmp_path):
    """GitHub-web-relative targets (badge routes) resolve above the repo
    root and must not be flagged."""
    doc = tmp_path / "d.md"
    doc.write_text("[badge](../../actions/workflows/ci.yml)\n")
    assert check_file(doc.resolve(), tmp_path.resolve()) == []


def test_repo_docs_are_clean():
    """The committed docs pass their own CI gate."""
    for name in ("README.md", "docs/architecture.md"):
        p = ROOT / name
        assert p.exists(), f"{name} missing"
        assert check_file(p, ROOT) == [], f"{name} has broken links"


def test_main_exit_codes(tmp_path, monkeypatch, capsys):
    good = tmp_path / "good.md"
    good.write_text("# T\n[x](#t)\n")
    monkeypatch.chdir(tmp_path)
    assert main([str(good)]) == 0
    bad = tmp_path / "bad.md"
    bad.write_text("[x](gone.md)\n")
    assert main([str(bad)]) == 1
    assert main([]) == 2
    assert main([str(tmp_path / "absent.md")]) == 1


def test_anchors_of_collects_heading_slugs(tmp_path):
    p = tmp_path / "a.md"
    p.write_text("# One\n## Two Words\n```\n# fenced out\n```\n")
    assert anchors_of(p) == {"one", "two-words"}
