"""§Perf hillclimbing driver: hypothesis -> change -> re-lower -> measure.

Three cells (chosen from the §Roofline baseline table):
  * qwen3-0.6b x train_4k x pod1      — the paper's own model family (most
    technique-representative); baseline memory-bound w/ 25.8 GB temp > HBM.
  * llama4-maverick x decode_32k x pod1 — most collective-bound cell (6.3s
    of expert-weight gathers).
  * qwen2-vl-72b x train_4k x pod1    — worst roofline fraction among the
    compute-heavy cells (4.2%), 453 GB/dev temp.

Each experiment is one knob flip (see repro/perf.py) with the napkin-math
prediction recorded next to the measurement.  Results land in
results/dryrun/<cell>__<tag>.json and are summarized to stdout +
results/hillclimb.md.

    PYTHONPATH=src python -m benchmarks.hillclimb [--only CELL]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"

# (cell, tag, env, hypothesis)
EXPERIMENTS = [
    # ---- qwen3-0.6b train_4k --------------------------------------------
    dict(arch="qwen3-0.6b", shape="train_4k", mesh="pod1", tag="iter1_rematN",
         env={"REPRO_REMAT_POLICY": "nothing"},
         hypothesis="remat=nothing stops saving per-layer dot outputs "
                    "(~768 f/token x 28L): HBM traffic and temp memory drop "
                    "~2x; compute rises ~30% (fwd recompute). Predict "
                    "mem_s 7.2->~4.5, temp 25.8GB -> <16GB."),
    dict(arch="qwen3-0.6b", shape="train_4k", mesh="pod1", tag="iter2_dp",
         env={"REPRO_TRAIN_SHARDING": "dp"},
         hypothesis="0.6B params fit replicated (1.2GB bf16): pure DP over "
                    "256 chips needs only a 1.2GB grad all-reduce "
                    "(2*(255/256)*1.2e9/50e9 = 48ms) vs 2.9s of TP/FSDP "
                    "traffic. Predict coll_s 2.9 -> ~0.1."),
    dict(arch="qwen3-0.6b", shape="train_4k", mesh="pod1",
         tag="iter3_dp_rematN",
         env={"REPRO_TRAIN_SHARDING": "dp", "REPRO_REMAT_POLICY": "nothing"},
         hypothesis="combine iter1+iter2: memory AND collective drop "
                    "together; step time should approach the compute term."),
    # ---- llama4 decode_32k ----------------------------------------------
    dict(arch="llama4-maverick-400b-a17b", shape="decode_32k", mesh="pod1",
         tag="iter1_dispatch",
         env={"REPRO_MOE_DECODE": "dispatch"},
         hypothesis="gather decode moves each token's expert weights "
                    "(128 tok x 250MB); dispatch moves token activations to "
                    "expert shards instead (128 x 5120 x 2B = 1.3MB/layer "
                    "all-to-all). Predict coll_s 6.3 -> <2."),
    # ---- qwen2-vl-72b train_4k ------------------------------------------
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter1_rematN",
         env={"REPRO_REMAT_POLICY": "nothing"},
         hypothesis="as qwen3/iter1 but at d=8192: saved dots are ~3.7x the "
                    "residual stream. Predict mem_s 231 -> ~120, temp "
                    "453GB -> ~90GB (layer boundaries still full-seq)."),
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter2_rematN_sp",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1"},
         hypothesis="sequence parallelism shards the saved layer boundaries "
                    "over the model axis (seq/16): temp ~90GB -> ~6-10GB "
                    "(fits HBM); collective unchanged or slightly up "
                    "(reduce-scatter/all-gather pairs replace all-reduce)."),
]


ROUND2 = [
    dict(arch="qwen3-0.6b", shape="train_4k", mesh="pod1",
         tag="iter4_mask_dp_rematN",
         env={"REPRO_TRAIN_SHARDING": "dp", "REPRO_REMAT_POLICY": "nothing"},
         hypothesis="CODE CHANGE (now default): additive (Sq,Skv) f32 causal "
                    "masks instead of boolean where-selects — the old path "
                    "materialized (chunks,B,H,q,kv) pred tensors that the "
                    "loop hoisted into carries. Predict mem_s 4.0 -> ~2."),
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter3_mask_rematN_sp",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1"},
         hypothesis="additive masks at d=8192/80L: predict mem_s 57 -> ~35, "
                    "temp 36GB -> ~25GB; collective unchanged."),
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter4_mask_rematN_sp_bf16norm",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1",
              "REPRO_NORM_F32": "0"},
         hypothesis="bf16 rms_norm stops the CPU-backend f32 convert-fold "
                    "that upgrades the TP collectives to f32: predict "
                    "coll_s ~63 -> ~32 (2 B vs 4 B payloads)."),
    dict(arch="llama4-maverick-400b-a17b", shape="decode_32k", mesh="pod1",
         tag="iter2_mask_dispatch",
         env={"REPRO_MOE_DECODE": "dispatch"},
         hypothesis="additive masks also shrink the decode attention "
                    "select; predict small mem win on top of dispatch."),
    dict(arch="llama4-maverick-400b-a17b", shape="train_4k", mesh="pod1",
         tag="bonus_int8_rematN_sp",
         env={"REPRO_OPT_STATE": "int8", "REPRO_REMAT_POLICY": "nothing",
              "REPRO_SEQ_PARALLEL": "1"},
         hypothesis="BONUS CELL (worst-memory cell in the table): int8 "
                    "AdamW moments cut optimizer HBM 8B->2.03B/param: args "
                    "16.24GB -> ~7.5GB (fits HBM); remat+SP cut temp."),
]
EXPERIMENTS = EXPERIMENTS + ROUND2


ROUND3 = [
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter5_weightAG",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1",
              "REPRO_WEIGHT_AG": "1"},
         hypothesis="HLO forensics showed 965GB/step of ACTIVATION "
                    "all-reduces: GSPMD partial-sums the FSDP-sharded "
                    "contraction instead of all-gathering the ~110MB/layer "
                    "weight shards. Constraining weights TP-only at use "
                    "sites flips it: predict coll 62.9 -> ~20s, step -> "
                    "~mem term (~45s)."),
    dict(arch="qwen3-0.6b", shape="train_4k", mesh="pod1",
         tag="iter5_dp_rematN_chunk4k",
         env={"REPRO_TRAIN_SHARDING": "dp", "REPRO_REMAT_POLICY": "nothing",
              "REPRO_ATTN_CHUNK": "4096"},
         hypothesis="in pure DP the per-device batch is 1 seq: the 4-chunk "
                    "q-scan only adds loop overhead and mask rebuilds; one "
                    "full-seq attention block (4096^2 x16H f32 scores = "
                    "1GB transient) is cheaper. Predict mem 3.5 -> ~3."),
]
EXPERIMENTS = EXPERIMENTS + ROUND3


ROUND4 = [
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter6_sp_mlpseq",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1"},
         hypothesis="iter5 REFUTED the weight-AG theory and exposed the real "
                    "bug: apply_mlp's own 'ff' constraint FORCED a seq->ff "
                    "reshard per layer under SP (2GB AG + AR per dot). Fix "
                    "(now default): the MLP stays sequence-sharded "
                    "end-to-end. Predict coll 62.9 -> ~25, step -> ~40."),
    dict(arch="llama4-maverick-400b-a17b", shape="train_4k", mesh="pod1",
         tag="bonus2_int8_rematN_sp",
         env={"REPRO_OPT_STATE": "int8", "REPRO_REMAT_POLICY": "nothing",
              "REPRO_SEQ_PARALLEL": "1"},
         hypothesis="retry of the bonus cell after fixing the Quantized "
                    "moment sharding guard: args 16.24GB -> ~7.5GB."),
]
EXPERIMENTS = EXPERIMENTS + ROUND4


ROUND5 = [
    dict(arch="qwen2-vl-72b", shape="train_4k", mesh="pod1",
         tag="iter7_sp_mlpseq_weightAG",
         env={"REPRO_REMAT_POLICY": "nothing", "REPRO_SEQ_PARALLEL": "1",
              "REPRO_WEIGHT_AG": "1"},
         hypothesis="post-iter6 probe: MLP dots fixed (4GB ARs -> 0.9GB "
                    "AGs), but the qkv/wo ATTENTION dots still partial-sum "
                    "over the FSDP d (224+165+160GB of f32 ARs). Re-apply "
                    "the weight TP-only constraint now that the MLP no "
                    "longer masks it: predict coll 59.3 -> ~35."),
]
EXPERIMENTS = EXPERIMENTS + ROUND5

BASELINES = [
    ("qwen3-0.6b", "train_4k", "pod1"),
    ("llama4-maverick-400b-a17b", "decode_32k", "pod1"),
    ("qwen2-vl-72b", "train_4k", "pod1"),
    # bonus (beyond the required three): the worst-memory cell in the table
    ("llama4-maverick-400b-a17b", "train_4k", "pod1"),
]


def run_cell(arch, shape, mesh, tag="", env=None, timeout=3000):
    suffix = f"__{tag}" if tag else ""
    out = RESULTS / f"{arch}__{shape}__{mesh}{suffix}.json"
    if out.exists():
        return json.load(open(out))
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh", mesh]
    if tag:
        cmd += ["--tag", tag]
    e = dict(os.environ)
    e["PYTHONPATH"] = "src"
    e.update(env or {})
    r = subprocess.run(cmd, env=e, cwd=ROOT, capture_output=True, text=True,
                       timeout=timeout)
    if r.returncode != 0:
        out.write_text(json.dumps({"arch": arch, "shape": shape,
                                   "mesh": mesh, "tag": tag,
                                   "status": "error",
                                   "error": (r.stderr or "")[-3000:]}))
    return json.load(open(out)) if out.exists() else {"status": "missing"}


def fmt(d):
    if d.get("status") != "ok":
        return f"status={d.get('status')}"
    r = d["roofline"]
    return (f"comp {r['compute_s']:7.3f}  mem {r['memory_s']:8.3f}  "
            f"coll {r['collective_s']:7.3f}  step {r['step_time_s']:8.3f}  "
            f"temp {d.get('temp_size_in_bytes', 0)/2**30:7.2f}GB  "
            f"args {d.get('argument_size_in_bytes', 0)/2**30:6.2f}GB")


def main(only=None):
    lines = []

    def emit(s):
        print(s, flush=True)
        lines.append(s)

    for arch, shape, mesh in BASELINES:
        if only and only not in arch:
            continue
        base = run_cell(arch, shape, mesh)
        emit(f"\n=== {arch} x {shape} x {mesh} ===")
        emit(f"  BASELINE (paper-faithful): {fmt(base)}")
        for ex in EXPERIMENTS:
            if (ex["arch"], ex["shape"], ex["mesh"]) != (arch, shape, mesh):
                continue
            emit(f"  -- {ex['tag']}")
            emit(f"     hypothesis: {ex['hypothesis']}")
            res = run_cell(arch, shape, mesh, ex["tag"], ex["env"])
            emit(f"     measured:   {fmt(res)}")
            if res.get("status") == "ok" and base.get("status") == "ok":
                b, n = base["roofline"], res["roofline"]
                emit(f"     delta:      step {b['step_time_s']:.3f} -> "
                     f"{n['step_time_s']:.3f} "
                     f"({b['step_time_s']/max(n['step_time_s'],1e-9):.2f}x)")
    (ROOT / "results" / "hillclimb.md").write_text("\n".join(lines))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    main(args.only)
