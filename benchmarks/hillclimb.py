"""Compiler-knob hillclimbing: hypothesis -> knob flip -> recompile -> measure.

Each cell is a representative term compiled end-to-end through
``repro.pipeline.compile()`` (the same driver serving uses for kernel
planning), and each experiment is one ``CompileOptions`` flip with the
napkin-math prediction recorded next to the measurement:

  * attention — the Fig. 3 softmax-attention chain; extraction-backend and
    buffer-planner experiments.
  * mlp_tp16  — the Fig. 6 MLP block on a 4x4 mesh; Auto Distribution
    experiments (SAT vs branch-and-bound plan search, vectorize ablation).
  * matmul    — a single square matmul; Auto Schedule MCTS-budget sweep.

Everything runs in-process (no subprocess, no XLA dry-run): the measured
quantities are the pipeline's own modeled costs, schedule latencies, buffer
peaks and per-pass wall times, which is exactly the feedback signal ROADMAP
item 5 (measured-cost autotuning) needs a working harness for.

Results are cached resumably in results/hillclimb/<cell>__<tag>.json and
summarized to stdout + results/hillclimb.md.

    PYTHONPATH=src python -m benchmarks.hillclimb [--only CELL] [--quick]

``main(only=None, quick=False)`` is importable; ``quick`` shrinks the terms
and search budgets to smoke-test size and skips the on-disk cache.
"""
from __future__ import annotations

import argparse
import json
import time
import traceback
from pathlib import Path

from repro.core.tensor_ir import inp, matmul, unary
from repro.pipeline import CompileOptions, CompileTarget, Compiler

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "hillclimb"


def _attention_term(quick):
    t, d = (256, 64) if quick else (1024, 128)
    return matmul(unary(matmul(inp("Q", (t, d)), inp("K", (d, t))),
                        kind="exp"), inp("V", (t, d)))


def _mlp_term(quick):
    t, d, f = (512, 256, 512) if quick else (4096, 1024, 4096)
    x = inp("x", (t, d))
    return matmul(unary(matmul(x, inp("w1", (d, f))), kind="exp"),
                  inp("w2", (f, d)))


def _matmul_term(quick):
    n = 256 if quick else 2048
    return matmul(inp("A", (n, n)), inp("B", (n, n)))


# cell -> (term builder, target builder).  The mesh cell is where Auto
# Distribution actually searches; single-device cells skip that pass.
CELLS = {
    "attention": (_attention_term, lambda quick: CompileTarget()),
    "mlp_tp16": (_mlp_term,
                 lambda quick: CompileTarget(
                     mesh_axes=("data", "model"),
                     mesh_sizes=(2, 2) if quick else (4, 4))),
    "matmul": (_matmul_term, lambda quick: CompileTarget()),
}

# Baseline knobs: greedy extraction (the cheapest backend) so every
# experiment's delta is against the same floor the serve path defaults to.
def _baseline_options(quick):
    return CompileOptions(extraction="greedy",
                          schedule_iterations=6 if quick else 25,
                          cache=False)


# (cell, tag, options overrides, hypothesis)
EXPERIMENTS = [
    # ---- attention: extraction + buffers --------------------------------
    dict(cell="attention", tag="iter1_bnb",
         options=dict(extraction="branch-and-bound"),
         hypothesis="greedy extraction prices shared subterms per-use; "
                    "branch-and-bound dedups them exactly. Predict modeled "
                    "cost <= greedy, extract pass ~10x slower."),
    dict(cell="attention", tag="iter2_wpmaxsat",
         options=dict(extraction="wpmaxsat"),
         hypothesis="WPMaxSAT reaches the same optimum as branch-and-bound "
                    "(both exact); the interesting delta is extract-pass "
                    "wall time on this e-graph size."),
    dict(cell="attention", tag="iter3_optbuf",
         options=dict(buffer_plan="optimal"),
         hypothesis="exact interval bin-packing beats greedy first-fit on "
                    "the arena peak when liveness ranges interleave; "
                    "modeled compute cost unchanged (same term)."),
    # ---- mlp_tp16: distribution + vectorize -----------------------------
    dict(cell="mlp_tp16", tag="iter1_satdist",
         options=dict(distribution_use_sat=True),
         hypothesis="the SBP e-graph is much larger than the vectorize "
                    "one: WPMaxSAT should find the same plan cost as the "
                    "default branch-and-bound but pay for it in distribute "
                    "pass time. Refutes/confirms the use_sat=False default."),
    dict(cell="mlp_tp16", tag="iter2_novec",
         options=dict(vectorize=False),
         hypothesis="packed variants carry most of the modeled speedup on "
                    "the MLP chain; disabling vectorize should collapse "
                    "modeled_speedup toward 1x with the distribution plan "
                    "unchanged (it searches the logical term)."),
    # ---- matmul: schedule budget ----------------------------------------
    dict(cell="matmul", tag="iter1_mcts4x",
         options="mcts4x",              # resolved per-quick in run_cell
         hypothesis="4x the MCTS structure budget: single-op graphs have a "
                    "tiny structure space, so latency should plateau at the "
                    "baseline value — measuring the diminishing return that "
                    "motivates measured-cost autotuning (ROADMAP item 5)."),
]


def _resolve_overrides(overrides, quick):
    if overrides == "mcts4x":
        return dict(schedule_iterations=(6 if quick else 25) * 4)
    return dict(overrides)


def run_cell(cell, tag="", overrides=None, quick=False):
    """Compile one (cell, knob) point in-process; returns a plain dict.

    Non-quick runs are cached resumably under results/hillclimb/ keyed on
    cell+tag, mirroring the old dry-run layout."""
    out = RESULTS / f"{cell}__{tag or 'baseline'}.json"
    if not quick and out.exists():
        return json.load(open(out))

    term_of, target_of = CELLS[cell]
    opts = _baseline_options(quick)
    if overrides:
        opts = CompileOptions(**{
            **{f: getattr(opts, f) for f in opts.__dataclass_fields__},
            **_resolve_overrides(overrides, quick)})
    result = {"cell": cell, "tag": tag, "quick": quick,
              "options": {f: getattr(opts, f)
                          for f in opts.__dataclass_fields__}}
    try:
        t0 = time.monotonic()
        res = Compiler(cache_dir=None).compile(
            term_of(quick), target=target_of(quick), options=opts)
        r = res.report
        result.update(
            status="ok",
            total_s=time.monotonic() - t0,
            baseline_cost_s=r.baseline_cost,
            modeled_cost_s=r.optimized_cost,
            modeled_speedup=r.modeled_speedup,
            pass_ms={k: v * 1e3 for k, v in r.pass_times.items()},
            buffer_peak=r.buffer.get("peak"),
            buffer_naive=r.buffer.get("naive"),
        )
        if r.schedule:
            result["schedule_latency_s"] = r.schedule["latency"]
            result["schedule_baseline_s"] = r.schedule["baseline_latency"]
            result["vmem_peak"] = r.schedule["vmem_peak"]
        if r.distribution:
            result["distribution_cost_s"] = r.distribution["cost"]
            result["distribution_peak_mb"] = \
                r.distribution["peak_memory"] / 1e6
    except Exception:
        result["status"] = "error"
        result["error"] = traceback.format_exc()[-4000:]
    if not quick:
        RESULTS.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(result, indent=1))
    return result


def fmt(d):
    if d.get("status") != "ok":
        return f"status={d.get('status')}"
    s = (f"cost {d['modeled_cost_s']:.3e}s "
         f"({d['modeled_speedup']:.2f}x model) ")
    if d.get("schedule_latency_s") is not None:
        s += (f"sched {d['schedule_latency_s']:.3e}s "
              f"vmem {d['vmem_peak'] / 2**20:5.1f}MB ")
    if d.get("distribution_cost_s") is not None:
        s += (f"dist {d['distribution_cost_s']:.3e}s "
              f"peak {d['distribution_peak_mb']:.1f}MB/dev ")
    s += (f"buf {d['buffer_peak']}/{d['buffer_naive']}B "
          f"compile {d['total_s'] * 1e3:.0f}ms")
    return s


def main(only=None, quick=False):
    """Run every cell's baseline + experiments; returns the result dicts.

    ``only`` substring-filters cells; ``quick`` shrinks terms/budgets and
    skips the disk cache (smoke-test mode)."""
    lines, results = [], []

    def emit(s):
        print(s, flush=True)
        lines.append(s)

    for cell in CELLS:
        if only and only not in cell:
            continue
        base = run_cell(cell, quick=quick)
        results.append(base)
        emit(f"\n=== {cell} ===")
        emit(f"  BASELINE (greedy extraction): {fmt(base)}")
        for ex in EXPERIMENTS:
            if ex["cell"] != cell:
                continue
            emit(f"  -- {ex['tag']}")
            emit(f"     hypothesis: {ex['hypothesis']}")
            res = run_cell(cell, ex["tag"], ex["options"], quick=quick)
            results.append(res)
            emit(f"     measured:   {fmt(res)}")
            if res.get("status") == "ok" and base.get("status") == "ok":
                b, n = base["modeled_cost_s"], res["modeled_cost_s"]
                emit(f"     delta:      cost {b:.3e} -> {n:.3e} "
                     f"({b / max(n, 1e-30):.2f}x), "
                     f"compile {base['total_s'] * 1e3:.0f} -> "
                     f"{res['total_s'] * 1e3:.0f}ms")
    if not quick:
        (ROOT / "results").mkdir(exist_ok=True)
        (ROOT / "results" / "hillclimb.md").write_text("\n".join(lines))
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    raise SystemExit(
        1 if any(r.get("status") != "ok"
                 for r in main(args.only, args.quick)) else 0)
