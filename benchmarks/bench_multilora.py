"""Multi-LoRA multiplexing lane for ``benchmarks.run``.

Thin registration shim: the implementation lives in
``benchmarks.bench_serve`` (``run_multilora`` / ``multilora_main``) because
it reuses the serve bench's engine builder and gateway plumbing.  Kept as
its own module so ``benchmarks.run`` lists it as a separate lane and a
failure here is attributed to tenant isolation, not closed-loop throughput.

    PYTHONPATH=src python -m benchmarks.bench_serve --multi-lora --quick

is the CLI equivalent (there is deliberately no separate bench_multilora
CLI).
"""
from __future__ import annotations

from benchmarks.bench_serve import multilora_main


def main(quick: bool = False):
    yield from multilora_main(quick=quick)
