"""Compiler-pass benchmarks: the paper's three modules measured on their own
running examples (modeled latencies + search wall time), all driven through
the unified ``repro.pipeline`` entry point.

  * vectorize — Fig. 3 attention-like chain + MLP chains: cost reduction,
    pack/unpack counts, search time.
  * distribution — SBP search on MLP block (Fig. 6 granularity): plan cost
    and peak memory, unconstrained vs memory-capped.
  * schedule — MCTS+MINLP vs unfused baseline on matmul / mlp / attention
    terms (Fig. 7), lowered through the Term -> TileGraph bridge.
  * buffer — liveness bin-packing vs naive allocation.
  * pipeline — the full chain end-to-end, cold and cache-warm.
"""
from __future__ import annotations

import time

from repro.core.buffer_schedule import (liveness_from_term, naive_peak,
                                        plan_greedy, plan_optimal)
from repro.core.tensor_ir import inp, matmul, unary
from repro.core.vectorize import count_ops
from repro.pipeline import CompileOptions, CompileTarget, Compiler


def _fig3_term():
    return matmul(unary(matmul(inp("Q", (1024, 128)), inp("K", (128, 1024))),
                        kind="exp"), inp("V", (1024, 128)))


def _mlp_term(t=4096, d=1024, f=4096, act="exp"):
    x = inp("x", (t, d))
    w1, w2 = inp("w1", (d, f)), inp("w2", (f, d))
    return matmul(unary(matmul(x, w1), kind=act), w2)


def bench_vectorize(quick: bool = False):
    rows = []
    cases = {
        "fig3_attention": _fig3_term(),
        "mlp_chain": _mlp_term(2048, 512, 2048, act="relu"),
    }
    opts = CompileOptions(extraction="greedy", schedule=False, cache=False)
    for name, term in cases.items():
        compiler = Compiler(cache_dir=None)
        t0 = time.monotonic()
        res = compiler.compile(term, options=opts)
        dt = time.monotonic() - t0
        r = res.report
        rows.append((f"vectorize_{name}", dt * 1e6,
                     f"modeled_speedup={r.modeled_speedup:.2f}x"
                     f"_packs={count_ops(res.term, 'pack')}"))
    return rows


def bench_distribution(quick: bool = False):
    rows = []
    term = _mlp_term()
    mesh = dict(mesh_axes=("data", "model"), mesh_sizes=(4, 4))
    opts = CompileOptions(extraction="greedy", vectorize=False,
                          schedule=False, cache=False)
    compiler = Compiler(cache_dir=None)
    t0 = time.monotonic()
    free = compiler.compile(term, target=CompileTarget(**mesh),
                            options=opts).report.distribution
    dt = time.monotonic() - t0
    rows.append(("distribute_mlp_free", dt * 1e6,
                 f"cost={free['cost']:.3e}s"
                 f"_peak={free['peak_memory'] / 1e6:.1f}MB"))
    t0 = time.monotonic()
    capped = compiler.compile(
        term, target=CompileTarget(**mesh, memory_capacity=25_000_000),
        options=opts).report.distribution
    dt = time.monotonic() - t0
    rows.append(("distribute_mlp_cap25MB", dt * 1e6,
                 f"cost={capped['cost']:.3e}s"
                 f"_peak={capped['peak_memory'] / 1e6:.1f}MB"))
    return rows


def bench_schedule(quick: bool = False):
    rows = []
    if quick:
        cases = [
            ("matmul1k", matmul(inp("A", (1024, 1024)), inp("B", (1024, 1024)))),
            ("mlp", _mlp_term(2048, 512, 1024, act="silu")),
            ("attention", matmul(unary(matmul(inp("Q", (1024, 64)),
                                              inp("K", (64, 1024))),
                                       kind="exp"),
                                 inp("V", (1024, 64)))),
        ]
    else:
        cases = [
            ("matmul4k", matmul(inp("A", (4096, 4096)), inp("B", (4096, 4096)))),
            ("mlp", _mlp_term(8192, 1024, 4096, act="silu")),
            ("attention", matmul(unary(matmul(inp("Q", (4096, 64)),
                                              inp("K", (64, 4096))),
                                       kind="exp"),
                                 inp("V", (4096, 64)))),
        ]
    opts = CompileOptions(extraction="greedy", vectorize=False,
                          schedule_iterations=8 if quick else 25, cache=False)
    for name, term in cases:
        compiler = Compiler(cache_dir=None)
        t0 = time.monotonic()
        s = compiler.compile(term, options=opts).report.schedule
        dt = time.monotonic() - t0
        fused = max(len(g) for g in s["groups"])
        rows.append((f"schedule_{name}", dt * 1e6,
                     f"latency={s['latency']:.3e}s"
                     f"_vs_base={s['baseline_latency']:.3e}s_fused={fused}"))
    return rows


def bench_buffer(quick: bool = False):
    term = matmul(unary(matmul(inp("a", (512, 512)), inp("b", (512, 512))),
                        kind="exp"), inp("c", (512, 512)))
    bufs = liveness_from_term(term, dtype_bytes=2)
    t0 = time.monotonic()
    _, pg = plan_greedy(bufs)
    _, po = plan_optimal(bufs)
    dt = time.monotonic() - t0
    return [("buffer_plan_attention", dt * 1e6,
             f"naive={naive_peak(bufs)}_greedy={pg}_optimal={po}")]


def bench_pipeline(quick: bool = False):
    """Full end-to-end chain: cold compile, then cache-warm recompile."""
    rows = []
    compiler = Compiler(cache_dir=None)
    term = _fig3_term()
    opts = CompileOptions(schedule_iterations=8 if quick else 25)
    t0 = time.monotonic()
    res = compiler.compile(term, options=opts)
    cold = time.monotonic() - t0
    r = res.report
    passes = "_".join(f"{k}={v * 1e3:.1f}ms" for k, v in r.pass_times.items())
    rows.append(("pipeline_fig3_cold", cold * 1e6,
                 f"speedup={r.modeled_speedup:.2f}x_{passes}"))
    t0 = time.monotonic()
    res2 = compiler.compile(term, options=opts)
    warm = time.monotonic() - t0
    rows.append(("pipeline_fig3_warm", warm * 1e6,
                 f"cache_hit={res2.report.cache_hit}"
                 f"_saved={(cold - warm) / cold * 100:.1f}%"))
    return rows


def main(quick: bool = False):
    rows = []
    rows += bench_vectorize(quick)
    rows += bench_distribution(quick)
    rows += bench_schedule(quick)
    rows += bench_buffer(quick)
    rows += bench_pipeline(quick)
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
