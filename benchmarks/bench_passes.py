"""Compiler-pass benchmarks: the paper's three modules measured on their own
running examples (modeled latencies + search wall time).

  * vectorize — Fig. 3 attention-like chain + MLP chains: cost reduction,
    pack/unpack counts, search time.
  * distribution — SBP search on MLP block (Fig. 6 granularity): plan cost
    and peak memory, unconstrained vs memory-capped.
  * schedule — MCTS+MINLP vs unfused baseline on matmul / mlp / attention
    tile graphs (Fig. 7).
  * buffer — liveness bin-packing vs naive allocation.
"""
from __future__ import annotations

import time

from repro.core.buffer_schedule import (liveness_from_term, naive_peak,
                                        plan_greedy, plan_optimal)
from repro.core.distribution import auto_distribute
from repro.core.sbp import Placement
from repro.core.schedule import (attention_tile_graph, auto_schedule,
                                 matmul_tile_graph, mlp_tile_graph)
from repro.core.tensor_ir import inp, matmul, unary
from repro.core.vectorize import auto_vectorize, count_ops


def bench_vectorize():
    rows = []
    cases = {
        "fig3_attention": matmul(unary(matmul(inp("Q", (1024, 128)),
                                              inp("K", (128, 1024))),
                                       kind="exp"), inp("V", (1024, 128))),
        "mlp_chain": matmul(unary(matmul(inp("x", (2048, 512)),
                                         inp("w1", (512, 2048))), kind="relu"),
                            inp("w2", (2048, 512))),
    }
    for name, term in cases.items():
        t0 = time.monotonic()
        cost, packed, stats = auto_vectorize(term, use_sat=False)
        dt = time.monotonic() - t0
        speedup = stats["baseline_cost"] / cost
        rows.append((f"vectorize_{name}", dt * 1e6,
                     f"modeled_speedup={speedup:.2f}x_packs={count_ops(packed, 'pack')}"))
    return rows


def bench_distribution():
    rows = []
    x = inp("x", (4096, 1024))
    w1, w2 = inp("w1", (1024, 4096)), inp("w2", (4096, 1024))
    term = matmul(unary(matmul(x, w1), kind="exp"), w2)
    pl = Placement(("data", "model"), (4, 4))
    t0 = time.monotonic()
    free = auto_distribute(term, pl, use_sat=False)
    dt = time.monotonic() - t0
    rows.append(("distribute_mlp_free", dt * 1e6,
                 f"cost={free.cost:.3e}s_peak={free.peak_memory/1e6:.1f}MB"))
    t0 = time.monotonic()
    capped = auto_distribute(term, pl, mem_capacity=25_000_000)
    dt = time.monotonic() - t0
    rows.append(("distribute_mlp_cap25MB", dt * 1e6,
                 f"cost={capped.cost:.3e}s_peak={capped.peak_memory/1e6:.1f}MB"))
    return rows


def bench_schedule():
    rows = []
    for name, tg in [("matmul4k", matmul_tile_graph(4096, 4096, 4096)),
                     ("mlp", mlp_tile_graph(8192, 1024, 4096)),
                     ("attention", attention_tile_graph(4096, 64))]:
        t0 = time.monotonic()
        state, sched, base = auto_schedule(tg, iterations=25)
        dt = time.monotonic() - t0
        rows.append((f"schedule_{name}", dt * 1e6,
                     f"latency={sched.latency:.3e}s_vs_base={base.latency:.3e}s"
                     f"_fused={max(len(g.ops) for g in state.groups)}"))
    return rows


def bench_buffer():
    term = matmul(unary(matmul(inp("a", (512, 512)), inp("b", (512, 512))),
                        kind="exp"), inp("c", (512, 512)))
    bufs = liveness_from_term(term, dtype_bytes=2)
    t0 = time.monotonic()
    _, pg = plan_greedy(bufs)
    _, po = plan_optimal(bufs)
    dt = time.monotonic() - t0
    return [("buffer_plan_attention", dt * 1e6,
             f"naive={naive_peak(bufs)}_greedy={pg}_optimal={po}")]


def main(quick: bool = False):
    rows = []
    rows += bench_vectorize()
    rows += bench_distribution()
    rows += bench_schedule()
    rows += bench_buffer()
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
