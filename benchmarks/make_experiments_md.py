"""Assemble EXPERIMENTS.md from the dry-run results + hillclimb logs.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
RESULTS = ROOT / "results" / "dryrun"
BASE_SNAP = ROOT / "results" / "dryrun_baseline_snapshot"

HW = ("TPU v5e model: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI, "
      "16 GiB HBM per chip; meshes 16x16 (pod1, 256 chips) and 2x16x16 "
      "(pod2, 512 chips).")


def _load(d):
    rows = {}
    for f in sorted(glob.glob(str(d / "*.json"))):
        r = json.load(open(f))
        if "__iter" in f or "__bonus" in f or "__hlodebug" in f:
            continue
        rows[(r.get("arch"), r.get("shape"), r.get("mesh"))] = r
    return rows


def dryrun_section(rows):
    ok = {k: v for k, v in rows.items() if v.get("status") == "ok"}
    sk = {k: v for k, v in rows.items() if v.get("status") == "skipped"}
    out = ["## §Dry-run", "",
           f"{HW}", "",
           f"Every (arch x shape) cell was lowered AND compiled with "
           f"`jax.jit(step, in_shardings=..., out_shardings=...).lower().compile()` "
           f"on both production meshes: **{len(ok)} cells ok, "
           f"{len(sk)} documented skips** (long_500k on pure full-attention "
           f"archs, per the assignment — see DESIGN.md §4).  Per-cell "
           f"artifacts (memory_analysis, cost_analysis, trip-count-aware "
           f"collective bytes) are in `results/dryrun/`.", "",
           "| arch | shape | mesh | devices | compile_s | args GB/dev | temp GB/dev | HLO collectives |",
           "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(ok.items()):
        coll = ", ".join(f"{k}:{v['count']}x(g{v['max_group']})"
                         for k, v in r.get("collectives", {}).items()) or "-"
        out.append(
            f"| {a} | {s} | {m} | {r['devices']} | {r.get('compile_s','-')} | "
            f"{r.get('argument_size_in_bytes',0)/2**30:.2f} | "
            f"{r.get('temp_size_in_bytes',0)/2**30:.2f} | {coll} |")
    out.append("")
    for (a, s, m), r in sorted(sk.items()):
        out.append(f"* skipped: {a} x {s} x {m} — {r.get('skip_reason','')}")
    return "\n".join(out)


def roofline_section(rows, title, note):
    ok = {k: v for k, v in rows.items() if v.get("status") == "ok"}
    out = [f"## {title}", "", note, "",
           "| arch | shape | mesh | compute_s | memory_s | collective_s | bottleneck | model/HLO flops | step_s | roofline frac |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(ok.items()):
        rf = r["roofline"]
        ratio = r.get("model_vs_hlo_flops")
        frac = rf["compute_s"] / max(rf["step_time_s"], 1e-12)
        out.append(
            f"| {a} | {s} | {m} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['bottleneck']} | "
            f"{ratio:.2f} | {rf['step_time_s']:.3f} | {frac*100:.1f}% |"
            if ratio else
            f"| {a} | {s} | {m} | {rf['compute_s']:.3f} | {rf['memory_s']:.3f} | "
            f"{rf['collective_s']:.3f} | {rf['bottleneck']} | - | "
            f"{rf['step_time_s']:.3f} | {frac*100:.1f}% |")
    return "\n".join(out)


PERF_SUMMARY = """\
### Summary (paper-faithful baseline -> best measured)

| cell | why chosen | baseline step_s | best step_s | gain | winning levers |
|---|---|---|---|---|---|
| qwen3-0.6b x train_4k x pod1 | paper's model family | 7.190 | 3.496 | **2.06x** | remat=nothing, pure-DP (auto-distribution's answer), additive masks |
| llama4-maverick x decode_32k x pod1 | most collective-bound | 6.345 | 1.998 | **3.18x** | MoE decode: capacity dispatch (all-to-all of activations) instead of expert-weight gathers |
| qwen2-vl-72b x train_4k x pod1 | worst roofline fraction | 230.772 | 50.909 | **4.53x** | remat=nothing, sequence parallelism, additive masks, MLP stays seq-sharded, weight TP-only constraints |
| llama4-maverick x train_4k x pod1 (bonus) | worst HBM fit (args 16.2GB > 16GiB) | 271.775 | 209.339 | 1.30x | **int8 AdamW moments: args 16.24 -> 6.21 GB/chip (now fits HBM)**; activations remain (next lever: grad accumulation) |

Confirmed hypotheses: remat policy (2x mem), pure-DP collectives (20x coll
for 0.6B), MoE dispatch (3.2x), SP activation sharding, additive masks,
int8 moments (args).  REFUTED: bf16-norm (f32 collectives were not
norm-induced; zero delta) and weight-AG v1 (masked by the MLP's own "ff"
constraint under SP — finding the real bug was worth the refutation).
Stopping rule: three consecutive <5% iterations on a cell's dominant term
(hit on qwen2-vl collective term after iter7).

### Roofline fractions (compute_s / step_s) — the §Perf score

| cell | baseline | best measured | on-TPU projection* |
|---|---|---|---|
| qwen3-0.6b x train_4k | 2.1% | 4.8% (comp 0.167 / step 3.496) | ~15-25% |
| llama4 x decode_32k | ~0% (decode: bandwidth-bound by nature) | memory-term-dominated (coll 6.35 -> 2.00) | KV/weight-read-bound, as expected |
| qwen2-vl-72b x train_4k | 4.2% | 23.4% (comp 11.92 / step 50.91) | ~40-55% |

*Projection basis (analytic, not measured — this container cannot execute
TPU kernels): (1) the jnp reference attention materializes (B,H,q,kv) f32
score tensors through HBM; the Pallas flash kernel (validated in interpret
mode, `kernels/flash_attention.py`) keeps them in VMEM — removing score
traffic cuts the measured memory term by the score share of bytes_traffic
(~35-45% for the train cells).  (2) The f32 collective payloads are a CPU
convert-folding artifact; TPU keeps bf16 MXU operands, halving the
collective term.  Both effects are structural, not speculative tuning, but
they are reported as projections and kept OUT of the measured tables.
"""


def perf_section():
    out = ["## §Perf — hillclimbing log (hypothesis -> change -> measure)",
           "", PERF_SUMMARY, "",
           "Full per-iteration logs (each entry: hypothesis with napkin "
           "math, measured roofline terms, delta):", ""]
    for log in ("hillclimb.log", "hillclimb2.log", "hillclimb3.log",
                "hillclimb4.log", "hillclimb5.log"):
        p = ROOT / "results" / log
        if p.exists():
            out.append(f"### {log}")
            out.append("```")
            out.append(p.read_text().strip())
            out.append("```")
            out.append("")
    return "\n".join(out)


def main():
    base = _load(BASE_SNAP) if BASE_SNAP.exists() else {}
    final = _load(RESULTS)
    fig9 = """\
## Paper-claim validation (Fig. 9 protocol)

The paper evaluates decode throughput of Qwen3-0.6B, batch 1, 8-token
prompt, single CPU core (AMD Ryzen 9 5900X): nncase 8.7 tok/s (F32) /
13.87 (F16); llama.cpp 10.61/17.21; IPEX 7.58/10.22.  We run the same
protocol through our stack on THIS container's single (much slower,
non-AVX2-tuned) core — see `fig9_decode_*` rows in bench_output.txt
(~0.22 tok/s F32).  Absolute numbers are not comparable across hosts; two
structural observations carry over and one deliberately does NOT:
(1) decode is memory-bandwidth-bound — per-token time tracks
bytes-of-weights/bandwidth, exactly the paper's memory-wall argument;
(2) the multi-chip analogue of Fig. 10's scaling — our pod1 vs pod2 decode
roofline terms — shows the near-linear release of parallel capacity until
the collective term takes over (decode cells halve their memory term
pod1->pod2 while collective-bound cells flatten: the same wall the paper
hits at 8T); (3) *measured and reported honestly*: bf16 decode is SLOWER
than f32 on this host (0.18 vs 0.22 tok/s) because this CPU emulates bf16
in software — the paper's 59% F16 uplift needs hardware f16 (AVX2 f16c /
TPU-native bf16), illustrating precisely the heterogeneous-compute-unit
adaptation problem the paper's Auto Vectorize targets.
"""
    parts = [
        "# EXPERIMENTS",
        "",
        "All numbers are derived from compiled XLA artifacts (this container "
        "is CPU-only; TPU v5e is the target, not the runtime).  FLOPs/bytes/"
        "collective bytes come from the trip-count-aware HLO analysis in "
        "`repro.launch.hlo_analysis` (XLA's own cost_analysis visits while "
        "bodies once and is recorded for reference only).",
        "",
        fig9,
        "",
        dryrun_section(final),
        "",
        roofline_section(
            base, "§Roofline — paper-faithful BASELINE (pre-optimization)",
            "Snapshot of the faithful implementation before §Perf "
            "(results/dryrun_baseline_snapshot/). Terms are per-chip seconds "
            "per step."),
        "",
        roofline_section(
            final, "§Roofline — current defaults (post-§Perf code changes)",
            "Same cells re-compiled with the post-hillclimb defaults "
            "(additive masks; opt-in knobs documented in repro/perf.py)."),
        "",
        perf_section(),
    ]
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(parts))
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
