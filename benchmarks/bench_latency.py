"""Open-loop gateway latency lane for ``benchmarks.run``.

Thin registration shim: the implementation lives in
``benchmarks.bench_serve`` (``run_open_loop`` / ``latency_main``) because it
reuses the serve bench's engine builder and workload generator.  Kept as its
own module so ``benchmarks.run`` lists it as a separate lane and a failure
here is attributed to the latency SLO, not closed-loop throughput.

    PYTHONPATH=src python -m benchmarks.bench_serve --open-loop --quick \
        --baseline benchmarks/baselines/latency.json

is the CLI equivalent (there is deliberately no separate bench_latency CLI).
"""
from __future__ import annotations

from benchmarks.bench_serve import latency_main


def main(quick: bool = False):
    yield from latency_main(quick=quick)
