"""Serving-throughput benchmark: the paged engine on a synthetic
multi-request workload, emitting a ``BENCH_serve.json`` trajectory point.

    PYTHONPATH=src python -m benchmarks.bench_serve --quick \
        --out BENCH_serve.json \
        --baseline benchmarks/baselines/serve.json --max-regress 0.2

Called from ``benchmarks.run`` it yields one CSV row per serving metric; the
CLI additionally writes the JSON point and gates on the committed baseline
(REASONING COMPILER's loop: serving metrics feed back into the compiler's CI,
so a pass that tanks tokens/sec fails the push that introduced it).

The workload is the acceptance scenario from the paged-engine PR: 12 requests
with mixed prompt/output lengths through ``max_batch=4``, which must all
finish, keep pool utilization under 100%, and peak strictly below the dense
``max_batch x max_len`` footprint.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import List, Tuple

WORKLOAD_REQUESTS = 12
MAX_BATCH = 4
MAX_LEN = 64
BLOCK_SIZE = 8


def _build_engine():
    import jax

    from repro.configs.base import get_config, reduced_config
    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                      block_size=BLOCK_SIZE)
    return cfg, eng


def _workload(cfg, n: int, seed: int = 0) -> List:
    """Mixed prompt lengths (3..20) and output lengths (4..14).  Every third
    request opens with a common 9-token prefix (a shared system prompt in
    miniature) so the tiered KVStore's prefix sharing / copy-on-write path is
    exercised by the measured run, not just by unit tests."""
    import numpy as np

    from repro.serve.engine import Request, SamplingParams

    rng = np.random.default_rng(seed)
    shared_prefix = rng.integers(1, cfg.vocab, size=9).tolist()
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 21))
        max_new = int(rng.integers(4, 15))
        prompt = rng.integers(1, cfg.vocab, size=plen).tolist()
        if i % 3 == 0:
            prompt = (shared_prefix + prompt)[:20]
        sp = SamplingParams() if i % 3 else \
            SamplingParams(temperature=0.8, top_k=40, seed=i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=max_new, sampling=sp))
    return reqs


def run_workload(quick: bool = False) -> Tuple[object, dict]:
    """Returns (ServeMetrics, workload descriptor).  ``quick`` is the CI
    smoke size; the full run pushes 3x the requests through the same pool so
    queueing/admission actually bites."""
    cfg, eng = _build_engine()
    n = WORKLOAD_REQUESTS if quick else 3 * WORKLOAD_REQUESTS

    # warm the prefill/decode jit caches outside the measured window (and
    # drop any prefixes it retained — the measured run starts cache-cold)
    for r in _workload(cfg, 2, seed=99):
        eng.submit(r)
    eng.run_until_done()
    eng.release_prefix_cache()
    eng.reset_metrics()

    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    m = eng.metrics()
    desc = {
        "requests": n,
        "finished": len(finished),
        "max_batch": MAX_BATCH,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "arch": cfg.name,
        "quick": quick,
    }
    return m, desc


def main(quick: bool = False):
    """benchmarks.run entry: one row per headline serving metric."""
    m, desc = run_workload(quick)
    if desc["finished"] != desc["requests"]:
        raise RuntimeError(
            f"serve workload incomplete: {desc['finished']}/{desc['requests']}")
    us_per_tok = 1e6 / max(m.tokens_per_sec, 1e-9)
    yield ("serve_paged_decode", f"{us_per_tok:.1f}",
           f"{m.tokens_per_sec:.1f} tok/s over {desc['requests']} reqs")
    yield ("serve_paged_ttft", f"{m.ttft_mean_s * 1e6:.0f}",
           f"mean time-to-first-token; max {m.ttft_max_s * 1e3:.0f}ms")
    yield ("serve_paged_pool", f"{m.peak_pool_utilization:.3f}",
           f"peak {m.peak_blocks_used}/{m.pool_blocks} blocks "
           f"(dense equiv {m.dense_equiv_blocks})")
    yield ("serve_prefix_reuse", f"{m.re_prefill_avoided}",
           f"prompt tokens not re-prefilled; {m.shared_blocks} shared / "
           f"{m.cow_copies} CoW blocks")
    yield ("serve_swap_traffic", f"{m.swap_out_blocks + m.swap_in_blocks}",
           f"host-tier blocks: {m.swap_out_blocks} out / "
           f"{m.swap_in_blocks} in ({m.preemptions} preemptions)")


def _check(m, desc) -> List[str]:
    """The PR's acceptance assertions, enforced on every bench run."""
    errs = []
    if desc["finished"] != desc["requests"]:
        errs.append(f"only {desc['finished']}/{desc['requests']} finished")
    if not m.tokens_per_sec > 0:
        errs.append("tokens_per_sec not positive")
    if not m.ttft_mean_s > 0:
        errs.append("ttft not recorded")
    if not m.peak_pool_utilization < 1.0:
        errs.append(f"pool peaked at {m.peak_pool_utilization:.0%} (expected <100%)")
    if not m.peak_blocks_used < m.dense_equiv_blocks:
        errs.append(f"peak blocks {m.peak_blocks_used} not below dense "
                    f"footprint {m.dense_equiv_blocks}")
    if not m.re_prefill_avoided > 0:
        errs.append("prefix sharing saved no prefill tokens on a workload "
                    "with shared prompt prefixes")
    return errs


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--baseline", default="")
    ap.add_argument("--max-regress", type=float, default=0.2,
                    help="fail if tokens/sec drops more than this fraction "
                         "below the committed baseline")
    args = ap.parse_args()

    m, desc = run_workload(quick=args.quick)
    point = {
        "bench": "serve",
        "unix_time": time.time(),
        "workload": desc,
        "tokens_per_sec": m.tokens_per_sec,
        "ttft_mean_s": m.ttft_mean_s,
        "itl_mean_s": m.itl_mean_s,
        "peak_pool_utilization": m.peak_pool_utilization,
        "peak_blocks_used": m.peak_blocks_used,
        "dense_equiv_blocks": m.dense_equiv_blocks,
        "preemptions": m.preemptions,
        "shared_blocks": m.shared_blocks,
        "cow_copies": m.cow_copies,
        "swap_out_blocks": m.swap_out_blocks,
        "swap_in_blocks": m.swap_in_blocks,
        "re_prefill_avoided": m.re_prefill_avoided,
        "metrics": m.to_dict(),
    }
    with open(args.out, "w") as f:
        json.dump(point, f, indent=2)
    print(m.summary())
    print(f"trajectory point written to {args.out}")

    errs = _check(m, desc)
    if args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["tokens_per_sec"] * (1.0 - args.max_regress)
        verdict = "OK" if m.tokens_per_sec >= floor else "REGRESSION"
        print(f"baseline gate: {m.tokens_per_sec:.1f} tok/s vs floor "
              f"{floor:.1f} (baseline {base['tokens_per_sec']:.1f} "
              f"- {args.max_regress:.0%}) -> {verdict}")
        if m.tokens_per_sec < floor:
            errs.append(f"throughput regression: {m.tokens_per_sec:.1f} < {floor:.1f}")
    for e in errs:
        print(f"bench_serve: FAIL: {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(cli())
