"""Serving-throughput benchmark: the paged engine on a synthetic
multi-request workload, emitting a ``BENCH_serve.json`` trajectory point.

    PYTHONPATH=src python -m benchmarks.bench_serve --quick \
        --out BENCH_serve.json \
        --baseline benchmarks/baselines/serve.json --max-regress 0.2

Called from ``benchmarks.run`` it yields one CSV row per serving metric; the
CLI additionally writes the JSON point and gates on the committed baseline
(REASONING COMPILER's loop: serving metrics feed back into the compiler's CI,
so a pass that tanks tokens/sec fails the push that introduced it).

The workload is the acceptance scenario from the paged-engine PR: 12 requests
with mixed prompt/output lengths through ``max_batch=4``, which must all
finish, keep pool utilization under 100%, and peak strictly below the dense
``max_batch x max_len`` footprint.

``--mesh N`` measures the mesh-sharded pool instead (fake N-device CPU pod
when real devices are missing): the KV slab is sharded on the kv-heads axis
and the run is verified **token-identical** against an unsharded engine on
the same workload before the point is written.  Sharded points carry
``mesh_devices`` and are a separate trajectory series — the single-device
baseline gate does not apply to them (see benchmarks.aggregate_serve).

``--tp N`` additionally shards the **weights** over the same mesh using the
partition rules Auto Distribution emits (``repro.distributed.param_sharding``):
the point records per-device vs replicated param bytes and the run is gated on
per-device bytes landing at ~1/N of replicated (within a slop for the norms
and router tables that stay replicated).  Decode stays token-identical to the
single-device oracle because the default mode gathers weights at their use
site; the ``REPRO_TP_REDUCE_SCATTER=1`` compute mode is fp32-close rather
than bitwise and its closeness is asserted by tests/test_param_sharding.py,
not by this bench.  TP points default to ``BENCH_serve_tp.json`` and are a
separate trajectory series like ``--mesh`` points.

``--family ssm|hybrid`` serves a stateful model family (``FAMILY_ARCHS``
smoke archs) through the same paged workload: pure-ssm requests keep their
recurrent state in the StateSlab tier (zero KV blocks — gated), hybrids
carry the mixed layout (KV blocks + slab slots).  One preemption-by-swap is
forced mid-decode and every output is verified token-identical against the
family's dense prefill+decode oracle.  Family points default to
``BENCH_serve_<family>.json`` and are a separate trajectory series — the
transformer ratchet does not apply (prefix sharing is structurally off for
stateful families, so the reuse gates would be meaningless).

``--open-loop`` measures **latency under load** instead of closed-loop
throughput: an in-process OpenAI gateway (``repro.serve.gateway``) is booted
on an ephemeral port and a Poisson client fires the same workload at it at
``--qps`` arrivals/sec over real HTTP + SSE, recording per-request TTFT
(first streamed token) and per-token inter-token latency.  The point goes to
``BENCH_latency.json`` (p50/p99 TTFT and ITL, delivered tokens/sec) and the
``--baseline`` gate becomes an SLO ceiling check against
``benchmarks/baselines/latency.json`` — open-loop points are a separate
trajectory series; they never touch the throughput ratchet.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import List, Optional, Tuple

WORKLOAD_REQUESTS = 12
MAX_BATCH = 4
MAX_LEN = 64
BLOCK_SIZE = 8

# the --family lane: one representative arch per stateful model family,
# served through the SAME engine/workload as the transformer lane and
# verified token-identical against the family's dense (unpaged) oracle
FAMILY_ARCHS = {
    "transformer": "qwen3-0.6b",
    "ssm": "falcon-mamba-7b",        # pure Mamba: StateSlab only, no KV
    "hybrid": "zamba2-2.7b",         # mixed layout: KV blocks + slab slots
}


def _knob_mesh_devices() -> int:
    """Effective REPRO_SERVE_MESH width (0 = off).  The bench resolves the
    knob itself so knob-sharded runs get the same kv-head widening and the
    same forced-single-device reference engine as --mesh runs."""
    import os
    knob = os.environ.get("REPRO_SERVE_MESH", "0")
    if knob in ("", "0", "off"):
        return 0
    if knob == "auto":
        import jax
        return len(jax.devices())
    return int(knob)


def _smoke_cfg(mesh_devices: int = 0, arch: str = "qwen3-0.6b"):
    """The bench arch.  A sharded run needs kv-heads divisible by the mesh:
    the qwen3 smoke config's GQA kv=2 is widened to the lcm (an explicitly
    different arch — which is why sharded points are a separate series)."""
    import dataclasses

    from repro.configs.base import get_config, reduced_config

    cfg = reduced_config(get_config(arch))
    if mesh_devices and cfg.n_kv_heads % mesh_devices:
        kv = math.lcm(cfg.n_kv_heads, mesh_devices)
        assert cfg.n_heads % kv == 0, \
            f"can't widen kv heads to {kv} under {cfg.n_heads} q heads"
        cfg = dataclasses.replace(cfg, n_kv_heads=kv)
    return cfg


def _build_engine(mesh_devices: int = 0, params=None, sharded: bool = True,
                  tp: bool = False, arch: str = "qwen3-0.6b",
                  **engine_kwargs):
    import jax

    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    # the reference engine passes mesh=False so the token-identity oracle
    # can never be silently sharded by ambient env; run_workload resolves
    # REPRO_SERVE_MESH into an explicit mesh_devices before calling here,
    # so mesh=None (knob passthrough) only remains for direct callers
    mesh = False if not sharded else None
    if mesh_devices and sharded:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_devices)
    cfg = _smoke_cfg(mesh_devices, arch)
    fns = build_model(cfg)
    if params is None:
        params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                      block_size=BLOCK_SIZE, mesh=mesh,
                      tp=True if (tp and sharded) else None,
                      **engine_kwargs)
    return cfg, eng, params


def _workload(cfg, n: int, seed: int = 0) -> List:
    """Mixed prompt lengths (3..20) and output lengths (4..14).  Every third
    request opens with a common 9-token prefix (a shared system prompt in
    miniature) so the tiered KVStore's prefix sharing / copy-on-write path is
    exercised by the measured run, not just by unit tests."""
    import numpy as np

    from repro.serve.engine import Request, SamplingParams

    rng = np.random.default_rng(seed)
    shared_prefix = rng.integers(1, cfg.vocab, size=9).tolist()
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 21))
        max_new = int(rng.integers(4, 15))
        prompt = rng.integers(1, cfg.vocab, size=plen).tolist()
        if i % 3 == 0:
            prompt = (shared_prefix + prompt)[:20]
        sp = SamplingParams() if i % 3 else \
            SamplingParams(temperature=0.8, top_k=40, seed=i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=max_new, sampling=sp))
    return reqs


def run_workload(quick: bool = False, mesh_devices: int = 0,
                 verify_identical: Optional[bool] = None,
                 tp: bool = False) -> Tuple[object, dict]:
    """Returns (ServeMetrics, workload descriptor).  ``quick`` is the CI
    smoke size; the full run pushes 3x the requests through the same pool so
    queueing/admission actually bites.  ``mesh_devices`` > 1 shards the KV
    pool; ``tp`` additionally shards the weights over the same mesh (rule-
    driven, see repro.distributed.param_sharding); ``verify_identical``
    replays the workload on a forced-unsharded engine (same params) and
    records whether outputs matched token-for-token — its default (None)
    means "whenever the engine's *effective* mesh is sharded", which also
    covers runs sharded by REPRO_SERVE_MESH rather than the --mesh flag.
    Exception: under REPRO_TP_REDUCE_SCATTER=1 compute follows the sharded
    layout and is only fp32-close, so identity is not asserted by default
    (tests/test_param_sharding.py owns the closeness check)."""
    # resolve the knob into an explicit width up front, so knob-sharded runs
    # get the widened smoke arch AND a matching-arch reference engine
    mesh_devices = mesh_devices or _knob_mesh_devices()
    cfg, eng, params = _build_engine(mesh_devices, tp=tp)
    n = WORKLOAD_REQUESTS if quick else 3 * WORKLOAD_REQUESTS

    # warm the prefill/decode jit caches outside the measured window (and
    # drop any prefixes it retained — the measured run starts cache-cold)
    for r in _workload(cfg, 2, seed=99):
        eng.submit(r)
    eng.run_until_done()
    eng.release_prefix_cache()
    eng.reset_metrics()

    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    m = eng.metrics()
    desc = {
        "requests": n,
        "finished": len(finished),
        "max_batch": MAX_BATCH,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "arch": cfg.name,
        "quick": quick,
        "mesh_devices": m.mesh_devices,
        # a 1-device mesh still runs the shard_map configuration (CPU
        # dispatch overhead and all): it must skip the single-device gate
        # even though its width puts it in the single-device table series
        "sharded": eng.mesh is not None,
        "tp_devices": m.tp_devices,
        "param_bytes_per_device": m.param_bytes_per_device,
        "param_bytes_replicated": m.param_bytes_replicated,
    }
    if verify_identical is None:
        from repro.perf import perf
        verify_identical = m.mesh_devices > 1 and \
            not (eng.tp and perf().tp_reduce_scatter)
    if verify_identical:
        _, ref_eng, _ = _build_engine(mesh_devices, params=params,
                                      sharded=False)
        ref = _workload(cfg, n)
        for r in ref:
            ref_eng.submit(r)
        ref_eng.run_until_done()
        desc["token_identical"] = all(
            a.out == b.out for a, b in zip(reqs, ref))
    return m, desc


# ---------------------------------------------------------------------------
# Model-family lane: SSM / hybrid archs through the same paged engine
# ---------------------------------------------------------------------------


def _family_oracle(cfg, fns, params, req, max_len: int) -> List[int]:
    """The family's dense reference: whole-prompt ``prefill`` + per-token
    ``decode_step`` on an unpaged cache, sampled with the engine's own
    stateless sampler — what the paged run must match token-for-token."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.serve.engine import ServeEngine

    cache, logits = fns.prefill(
        params, {"tokens": jnp.asarray([req.prompt], jnp.int32)})
    if cfg.family != "ssm":
        # grow the prompt-sized KV planes to max_len before decoding:
        # decode_step writes at cur_len, which would clamp against a
        # prompt-length cache and corrupt the final KV entry.  Pure-ssm
        # caches are fixed-size recurrent state — nothing to grow.
        def embed(small, big):
            if small.shape == big.shape:
                return small.astype(big.dtype)
            for ax in range(small.ndim):
                if small.shape[ax] != big.shape[ax]:
                    return jax.lax.dynamic_update_slice_in_dim(
                        big, small.astype(big.dtype), 0, axis=ax)
            return small
        cache = jax.tree.map(embed, cache, fns.make_cache(1, max_len))
    out = [ServeEngine._sample(np.asarray(logits[0]), req.sampling, 0)]
    cur = len(req.prompt)
    for _ in range(req.max_new - 1):
        batch = {"token": jnp.asarray([[out[-1]]], jnp.int32)}
        if cfg.family != "ssm":
            batch["cur_len"] = jnp.int32(cur)
        cache, lg = fns.decode_step(params, cache, batch)
        out.append(ServeEngine._sample(np.asarray(lg[0]), req.sampling,
                                       len(out)))
        cur += 1
    return out


def run_family_workload(family: str, quick: bool = False
                        ) -> Tuple[object, dict]:
    """The transformer lane's mixed workload served through a stateful-family
    arch (``FAMILY_ARCHS``), with one preemption-by-swap forced mid-decode so
    the measured run provably crosses the slab park/restore path, then every
    output verified token-identical against the family's dense oracle.

    Single-device by construction: stateful families refuse a mesh (the slab
    is not sharded), and ``sharded=False`` keeps ambient REPRO_SERVE_MESH
    from breaking the lane."""
    arch = FAMILY_ARCHS[family]
    cfg, eng, params = _build_engine(0, sharded=False, arch=arch)
    n = WORKLOAD_REQUESTS if quick else 3 * WORKLOAD_REQUESTS

    # warm the prefill/decode jit caches outside the measured window
    for r in _workload(cfg, 2, seed=99):
        eng.submit(r)
    eng.run_until_done()
    eng.release_prefix_cache()
    eng.reset_metrics()

    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    # drive the loop by hand: once some request is mid-generation, park the
    # one with the most tokens out (state slab + any KV blocks to the host
    # tier) — it must resume and finish without changing a token
    forced = False
    while eng.step():
        if forced or not eng.swap_enabled:
            continue
        live = [s for s in eng.slots if s is not None]
        mid = [s for s in live if len(s.req.out) >= 2]
        if mid:
            eng._requeue(max(mid, key=lambda s: len(s.req.out)))
            forced = True
    finished = eng.run_until_done()
    m = eng.metrics()

    from repro.models import build_model
    fns = build_model(cfg)
    identical = all(r.out == _family_oracle(cfg, fns, params, r, MAX_LEN)
                    for r in reqs)
    desc = {
        "requests": n,
        "finished": len(finished),
        "max_batch": MAX_BATCH,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "arch": cfg.name,
        "family": family,
        "quick": quick,
        "mesh_devices": m.mesh_devices,
        "sharded": False,
        "tp_devices": m.tp_devices,
        "token_identical": identical,
        "forced_preemption": forced,
        "state_slots_peak": (eng.state_store.device.pool.peak_used
                             if eng.state_store is not None else 0),
    }
    return m, desc


def check_family(m, desc) -> List[str]:
    """The SSM/hybrid serving PR's acceptance assertions: the stateful
    families complete the same workload, match their dense oracle across a
    forced preemption-by-swap, and prove their memory layout (no KV blocks
    for pure ssm, below-dense KV for hybrid, slab slots actually used).
    Prefix-reuse gates do NOT apply: sharing is structurally off for
    stateful families (recurrent state summarizes the whole prefix)."""
    errs = []
    if desc["finished"] != desc["requests"]:
        errs.append(f"only {desc['finished']}/{desc['requests']} finished")
    if not desc["token_identical"]:
        errs.append(f"{desc['family']} paged run NOT token-identical to its "
                    "dense oracle")
    if not m.tokens_per_sec > 0:
        errs.append("tokens_per_sec not positive")
    if not m.ttft_mean_s > 0:
        errs.append("ttft not recorded")
    if desc["forced_preemption"]:
        if not m.preemptions >= 1:
            errs.append("forced preemption not recorded")
        if not (m.swap_out_blocks >= 1 and m.swap_in_blocks >= 1):
            errs.append("preemption never crossed the swap tier "
                        f"({m.swap_out_blocks} out/{m.swap_in_blocks} in)")
    if desc["state_slots_peak"] < 1:
        errs.append("no state-slab slot was ever allocated for a stateful "
                    "family")
    if desc["family"] == "ssm":
        if m.peak_blocks_used != 0:
            errs.append(f"pure-ssm run allocated {m.peak_blocks_used} KV "
                        "blocks (state must live in the slab, not the pool)")
    elif not m.peak_blocks_used < m.dense_equiv_blocks:
        errs.append(f"hybrid peak blocks {m.peak_blocks_used} not below "
                    f"dense footprint {m.dense_equiv_blocks}")
    return errs


def family_main(quick: bool = False):
    """benchmarks.run entry for the ssm lane: every stateful family in the
    zoo through the paged engine, one row per family headline."""
    for family in ("ssm", "hybrid"):
        m, desc = run_family_workload(family, quick=quick)
        errs = check_family(m, desc)
        if errs:
            raise RuntimeError(f"{family}: " + "; ".join(errs))
        us_per_tok = 1e6 / max(m.tokens_per_sec, 1e-9)
        yield (f"serve_{family}_decode", f"{us_per_tok:.1f}",
               f"{desc['arch']}: {m.tokens_per_sec:.1f} tok/s over "
               f"{desc['requests']} reqs, dense-oracle "
               f"{'OK' if desc['token_identical'] else 'MISMATCH'}")
        yield (f"serve_{family}_state", f"{desc['state_slots_peak']}",
               f"peak slab slots; KV peak {m.peak_blocks_used}/"
               f"{m.pool_blocks} blocks, {m.preemptions} preemptions "
               f"({m.swap_out_blocks} out / {m.swap_in_blocks} in)")


# ---------------------------------------------------------------------------
# Open-loop latency: Poisson arrivals over HTTP/SSE against a live gateway
# ---------------------------------------------------------------------------

OPEN_LOOP_QPS = 8.0
OPEN_LOOP_REQUESTS = 16      # --quick; the full run triples it


async def _sse_request(host: str, port: int, payload: dict):
    """One streamed /v1/completions over a raw socket.  Returns
    (ttft_s, itl_samples_s, n_tokens, finish_reason, wall_s) — timing is
    measured from the moment the request bytes are flushed, so TTFT includes
    the gateway's queueing + admission + prefill, exactly what a caller
    sees.  A load-shed 429/503 comes back as finish ``"shed"`` (any other
    non-200 as ``"http_<status>"``) so open-loop accounting can tell
    refused work from completed work."""
    import asyncio
    import json as _json

    body = _json.dumps(payload).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            b"POST /v1/completions HTTP/1.1\r\nHost: bench\r\n"
            b"Content-Type: application/json\r\n"
            + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        t0 = time.monotonic()
        status_line = await reader.readline()        # HTTP/1.1 <code> ...
        parts = status_line.split()
        status = int(parts[1]) if len(parts) > 1 else 0
        await reader.readuntil(b"\r\n\r\n")          # rest of the headers
        if status != 200:
            finish = "shed" if status in (429, 503) else f"http_{status}"
            return None, [], 0, finish, time.monotonic() - t0
        ttft = None
        stamps = []
        n_tokens = 0
        finish = ""
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):].strip()
            if data == b"[DONE]":
                break
            chunk = _json.loads(data)
            if "error" in chunk:
                finish = f"error: {chunk['error']['message']}"
                break
            choice = chunk["choices"][0]
            ids = choice.get("token_ids") or []
            if ids:
                now = time.monotonic()
                if ttft is None:
                    ttft = now - t0
                stamps.extend([now] * len(ids))
                n_tokens += len(ids)
            if choice.get("finish_reason"):
                finish = choice["finish_reason"]
        itls = [b - a for a, b in zip(stamps, stamps[1:])]
        return ttft, itls, n_tokens, finish, time.monotonic() - t0
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def run_open_loop(quick: bool = False, qps: float = OPEN_LOOP_QPS,
                  n_requests: int = 0, seed: int = 0,
                  deadline_ms: float = 0.0) -> dict:
    """Boot the gateway in-process, replay the serve workload as Poisson
    arrivals at ``qps``, and return a BENCH_latency.json point.

    ``deadline_ms`` > 0 attaches a per-request ``timeout`` so the engine's
    deadline reaper is part of the measured system; the point then reports
    **goodput** (tokens of requests that completed within their deadline)
    alongside raw delivered throughput, plus shed/expired/errored tallies."""
    import asyncio

    import numpy as np

    from repro.serve.async_engine import AsyncServeEngine
    from repro.serve.gateway import (ByteTokenizer, Gateway, GatewayModel,
                                     Router)

    n = n_requests or (OPEN_LOOP_REQUESTS if quick else 3 * OPEN_LOOP_REQUESTS)
    cfg, eng, params = _build_engine(0)
    model = GatewayModel(model_id=cfg.name,
                         async_engine=AsyncServeEngine(eng, model_id=cfg.name),
                         tokenizer=ByteTokenizer(cfg.vocab))

    reqs = _workload(cfg, n, seed=seed)
    rng = np.random.default_rng(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, size=n))

    async def drive():
        async with Gateway(Router([model]), port=0) as gw:
            # warm the jit caches through the same HTTP path, then drop the
            # retained prefixes so the measured window starts cache-cold
            for r in _workload(cfg, 2, seed=99):
                await _sse_request(gw.host, gw.port, {
                    "model": cfg.name, "prompt": r.prompt,
                    "max_tokens": r.max_new, "stream": True})
            eng.release_prefix_cache()

            t_start = time.monotonic()

            async def one(i):
                await asyncio.sleep(float(arrivals[i]))
                r = reqs[i]
                sp = r.sampling
                payload = {
                    "model": cfg.name, "prompt": r.prompt,
                    "max_tokens": r.max_new, "stream": True,
                    "temperature": sp.temperature, "top_k": sp.top_k,
                    "seed": sp.seed}
                if deadline_ms > 0:
                    payload["timeout"] = deadline_ms / 1e3
                return await _sse_request(gw.host, gw.port, payload)

            results = await asyncio.gather(*[one(i) for i in range(n)])
            wall = time.monotonic() - t_start
            return results, wall

    results, wall = asyncio.run(drive())
    ttfts = [r[0] for r in results if r[0] is not None]
    itls = [x for r in results for x in r[1]]
    total_tokens = sum(r[2] for r in results)
    completed = sum(1 for r in results if r[3] in ("stop", "length"))
    shed = sum(1 for r in results if r[3] == "shed")
    expired = sum(1 for r in results if r[3] == "expired")
    errored = sum(1 for r in results
                  if r[3].startswith(("error", "http_")))
    # goodput: only tokens of requests that actually completed count —
    # work burned on expired/errored streams is throughput, not goodput
    good_tokens = sum(r[2] for r in results if r[3] in ("stop", "length"))

    def pct(xs, q):
        return float(np.percentile(xs, q)) if xs else 0.0

    return {
        "bench": "serve_latency",
        "open_loop": True,
        "unix_time": time.time(),
        "qps": qps,
        "requests": n,
        "completed": completed,
        "requests_shed": shed,
        "requests_expired": expired,
        "requests_errored": errored,
        "deadline_ms": deadline_ms,
        "mesh_devices": 1,
        "workload": {"requests": n, "max_batch": MAX_BATCH,
                     "max_len": MAX_LEN, "block_size": BLOCK_SIZE,
                     "arch": cfg.name, "quick": quick, "qps": qps},
        "wall_s": wall,
        "tokens_per_sec": total_tokens / wall if wall > 0 else 0.0,
        "goodput_tokens_per_sec": good_tokens / wall if wall > 0 else 0.0,
        "ttft_mean_s": float(np.mean(ttfts)) if ttfts else 0.0,
        "ttft_p50_ms": pct(ttfts, 50) * 1e3,
        "ttft_p99_ms": pct(ttfts, 99) * 1e3,
        "itl_mean_s": float(np.mean(itls)) if itls else 0.0,
        "itl_p50_ms": pct(itls, 50) * 1e3,
        "itl_p99_ms": pct(itls, 99) * 1e3,
    }


# ---------------------------------------------------------------------------
# Multi-LoRA: N tenants over one shared paged base, through the live gateway
# ---------------------------------------------------------------------------

MULTILORA_TENANTS = ["tenant-a", "tenant-b", "tenant-c", "tenant-d"]


async def _sse_collect(host: str, port: int, payload: dict):
    """One streamed /v1/completions, returning (token_ids, model_tag,
    finish_reason) — the multi-LoRA lane checks *which tenant* answered a
    stream, not just how fast."""
    import asyncio
    import json as _json

    body = _json.dumps(payload).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            b"POST /v1/completions HTTP/1.1\r\nHost: bench\r\n"
            b"Content-Type: application/json\r\n"
            + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        status_line = await reader.readline()
        parts = status_line.split()
        status = int(parts[1]) if len(parts) > 1 else 0
        await reader.readuntil(b"\r\n\r\n")
        if status != 200:
            return [], "", f"http_{status}"
        ids: List[int] = []
        model_tag = ""
        finish = ""
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):].strip()
            if data == b"[DONE]":
                break
            chunk = _json.loads(data)
            if "error" in chunk:
                finish = f"error: {chunk['error']['message']}"
                break
            model_tag = chunk.get("model", model_tag)
            choice = chunk["choices"][0]
            ids.extend(choice.get("token_ids") or [])
            if choice.get("finish_reason"):
                finish = choice["finish_reason"]
        return ids, model_tag, finish
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def run_multilora(quick: bool = False, seed: int = 0) -> dict:
    """Mixed-tenant serving through a live gateway: 4 LoRA tenants + the
    base model multiplexed over ONE engine, ONE block pool, ONE set of base
    weights.  Returns a ``BENCH_multilora.json`` point.

    The workload is adversarial for isolation: every tenant asks the SAME
    prompt (greedy), so any cross-tenant KV leak is observable.

    * phase 1 — one identical-prompt request per tenant, empty prefix
      registry: any prefix adoption here would necessarily be cross-tenant,
      so the gate is ``re_prefill_avoided == 0``;
    * phase 2 — the same five asks again: now each tenant owns a registered
      prefix in its own namespace, so reuse MUST happen
      (``re_prefill_avoided > 0``) and every stream must still be
      token-identical to its phase-1 run;
    * oracle — each tenant's stream is replayed on a fresh single-tenant
      reference engine (same params, same adapter name -> same
      deterministic factors) and must match token-for-token;
    * throughput — a mixed 5-way workload is timed against a base-only
      workload of the same size on the same engine (ratio recorded, loose
      floor gated: per-row adapter gathers must not crater decode).
    """
    import asyncio

    from repro.serve.async_engine import AsyncServeEngine
    from repro.serve.gateway import (ByteTokenizer, Gateway, GatewayModel,
                                     Router)

    import numpy as np

    # multi-LoRA is single-device (the engine refuses adapters on a mesh);
    # sharded=False keeps ambient REPRO_SERVE_MESH from breaking the lane.
    # The pool gets explicit registry headroom: conservative admission
    # reserves max_blocks_per_seq per slot, and the default pool is sized
    # exactly to those reservations — phase 2's adoption gate needs the 5
    # per-tenant prefix entries (2 blocks each) to SURVIVE a full batch.
    n_prefix = (len(MULTILORA_TENANTS) + 1) * ((16 + BLOCK_SIZE - 1)
                                               // BLOCK_SIZE)
    cfg, eng, params = _build_engine(
        0, sharded=False,
        num_blocks=MAX_BATCH * (MAX_LEN // BLOCK_SIZE) + n_prefix + 1,
        prefix_cache_blocks=n_prefix)
    model = GatewayModel(
        model_id=cfg.name,
        async_engine=AsyncServeEngine(eng, model_id=cfg.name),
        tokenizer=ByteTokenizer(cfg.vocab),
        adapters=list(MULTILORA_TENANTS))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(1, cfg.vocab, size=16).tolist()
    max_new = 8 if quick else 12
    asks = [None] + list(MULTILORA_TENANTS)      # base + 4 tenants

    def tag(t):
        return cfg.name if t is None else f"{cfg.name}:{t}"

    async def drive():
        async with Gateway(Router([model]), port=0) as gw:
            async def ask_one(t):
                return await _sse_collect(gw.host, gw.port, {
                    "model": tag(t), "prompt": prompt,
                    "max_tokens": max_new, "stream": True})

            # warm the jit caches (base and lora graphs), then reset
            await ask_one(None)
            await ask_one(MULTILORA_TENANTS[0])
            eng.release_prefix_cache()
            eng.reset_metrics()

            # phase 1: identical prompt, one request per tenant, cold
            # registry — any prefix hit would be cross-tenant
            phase1 = await asyncio.gather(*[ask_one(t) for t in asks])
            cross_tenant_hits = eng.metrics().re_prefill_avoided

            # phase 2: same asks again — now reuse must happen, within
            # namespace only, without changing a single token
            phase2 = await asyncio.gather(*[ask_one(t) for t in asks])
            reuse_tokens = eng.metrics().re_prefill_avoided

            # throughput: mixed 5-tenant round-robin vs base-only, same
            # size, same engine (prefixes dropped so neither is favored)
            n_tput = 10 if quick else 20
            eng.release_prefix_cache()

            async def timed(tenants):
                t0 = time.monotonic()
                rs = await asyncio.gather(*[
                    ask_one(tenants[i % len(tenants)]) for i in range(n_tput)])
                toks = sum(len(r[0]) for r in rs)
                return toks / max(time.monotonic() - t0, 1e-9)

            base_tps = await timed([None])
            eng.release_prefix_cache()
            mixed_tps = await timed(asks)
            return phase1, phase2, cross_tenant_hits, reuse_tokens, \
                base_tps, mixed_tps

    phase1, phase2, cross_hits, reuse_tokens, base_tps, mixed_tps = \
        asyncio.run(drive())

    # oracle: replay each tenant on a fresh single-tenant reference engine
    from repro.serve.engine import Request
    oracle_match = {}
    for t, (ids, _, _) in zip(asks, phase1):
        _, ref, _ = _build_engine(0, params=params, sharded=False)
        if t is not None:
            ref.load_adapter(t)
        r = Request(rid=0, prompt=list(prompt), max_new=max_new, adapter_id=t)
        ref.submit(r)
        ref.run_until_done()
        oracle_match[t or "base"] = (r.out == ids)

    m = eng.metrics()
    am = eng.adapters.metrics()
    slab_cap_bytes = eng.adapters.per_adapter_bytes() \
        * eng.adapters.max_adapters
    distinct = len({tuple(ids) for ids, _, _ in phase1})
    return {
        "bench": "multilora",
        "unix_time": time.time(),
        "quick": quick,
        "tenants": len(MULTILORA_TENANTS),
        "workload": {"arch": cfg.name, "prompt_tokens": len(prompt),
                     "max_new": max_new, "max_batch": MAX_BATCH,
                     "block_size": BLOCK_SIZE},
        "model_tags_ok": all(mt == tag(t)
                             for t, (_, mt, _) in zip(asks, phase1)),
        "streams_completed": all(f == "length"
                                 for _, _, f in phase1 + phase2),
        "distinct_streams": distinct,
        "cross_tenant_prefix_hits": int(cross_hits),
        "within_tenant_reuse_tokens": int(reuse_tokens - cross_hits),
        "phase2_token_identical": all(
            a[0] == b[0] for a, b in zip(phase1, phase2)),
        "oracle_match": oracle_match,
        "per_tenant": m.per_tenant,
        "adapters_loaded": am["adapters_loaded"],
        "adapter_device_bytes": am["adapter_device_bytes"],
        "adapter_host_bytes": am["adapter_host_bytes"],
        "adapter_slab_cap_bytes": slab_cap_bytes,
        "base_tokens_per_sec": base_tps,
        "mixed_tokens_per_sec": mixed_tps,
        "mixed_vs_base_ratio": mixed_tps / max(base_tps, 1e-9),
    }


def check_multilora(point: dict) -> List[str]:
    """The multi-LoRA PR's acceptance assertions, gated by the
    ``multilora-smoke`` CI lane."""
    errs = []
    if not point["model_tags_ok"]:
        errs.append("a stream's model tag did not echo the asked tenant")
    if not point["streams_completed"]:
        errs.append("not every tenant stream ran to completion")
    # base + 4 tenants with distinct adapters must produce distinct streams
    want = point["tenants"] + 1
    if point["distinct_streams"] != want:
        errs.append(f"only {point['distinct_streams']}/{want} distinct "
                    "streams for an identical prompt across tenants "
                    "(adapters not actually applied, or leaking)")
    if point["cross_tenant_prefix_hits"] != 0:
        errs.append(f"{point['cross_tenant_prefix_hits']} prefill tokens "
                    "adopted across tenant namespaces (KV isolation broken)")
    if not point["within_tenant_reuse_tokens"] > 0:
        errs.append("no within-tenant prefix reuse on repeated prompts "
                    "(namespacing is over-isolating)")
    if not point["phase2_token_identical"]:
        errs.append("prefix-reusing rerun changed tokens")
    bad = [t for t, ok in point["oracle_match"].items() if not ok]
    if bad:
        errs.append(f"streams diverged from single-tenant oracle: {bad}")
    if point["adapter_device_bytes"] > point["adapter_slab_cap_bytes"]:
        errs.append(f"adapter slab {point['adapter_device_bytes']}B exceeds "
                    f"its cap {point['adapter_slab_cap_bytes']}B")
    if point["adapters_loaded"] > point["tenants"]:
        errs.append(f"{point['adapters_loaded']} adapters resident for "
                    f"{point['tenants']} tenants")
    # per-row gathers cost something, but multiplexing must not crater the
    # shared engine (generous floor: CPU interpret-mode kernels + CI noise)
    if point["mixed_vs_base_ratio"] < 0.15:
        errs.append(f"mixed-tenant throughput is only "
                    f"{point['mixed_vs_base_ratio']:.1%} of base-only")
    return errs


def multilora_main(quick: bool = False):
    """benchmarks.run entry for the multi-LoRA lane: one row per isolation/
    cost headline, gated on the acceptance assertions."""
    point = run_multilora(quick=quick)
    errs = check_multilora(point)
    if errs:
        raise RuntimeError("; ".join(errs))
    yield ("multilora_isolation", f"{point['cross_tenant_prefix_hits']}",
           f"cross-tenant prefix hits over {point['tenants']} tenants "
           f"({point['within_tenant_reuse_tokens']} within-tenant reuse)")
    yield ("multilora_slab_mb",
           f"{point['adapter_device_bytes'] / 1e6:.2f}",
           f"{point['adapters_loaded']} adapters resident "
           f"(cap {point['adapter_slab_cap_bytes'] / 1e6:.2f} MB)")
    yield ("multilora_tput_ratio", f"{point['mixed_vs_base_ratio']:.3f}",
           f"mixed {point['mixed_tokens_per_sec']:.1f} vs base "
           f"{point['base_tokens_per_sec']:.1f} tok/s on one engine")


def check_latency(point: dict, baseline: Optional[dict] = None,
                  faulty: bool = False) -> List[str]:
    """Open-loop acceptance: everything reached a terminal outcome, latency
    was recorded, and the committed SLO ceilings (when given) held.
    ``faulty`` relaxes the all-completed check to all-*terminal* — under
    injected faults or tight deadlines some requests legitimately end shed/
    expired/errored, but none may vanish."""
    errs = []
    terminal = point["completed"] + point.get("requests_shed", 0) \
        + point.get("requests_expired", 0) + point.get("requests_errored", 0)
    if terminal != point["requests"]:
        errs.append(f"only {terminal}/{point['requests']} open-loop "
                    "requests reached a terminal outcome")
    if not faulty and point["completed"] != point["requests"]:
        errs.append(f"only {point['completed']}/{point['requests']} "
                    "open-loop requests completed")
    if faulty and point["completed"] == 0:
        errs.append("no open-loop request completed under faults "
                    "(zero goodput)")
    if not point["ttft_p50_ms"] > 0:
        errs.append("no TTFT samples recorded")
    if point["requests"] > 1 and not point["itl_p50_ms"] > 0:
        errs.append("no inter-token latency samples recorded")
    if baseline:
        for key in ("ttft_p99_ms", "itl_p99_ms"):
            ceil = baseline.get(key)
            if ceil is not None and point[key] > ceil:
                errs.append(f"SLO violation: {key} {point[key]:.1f}ms "
                            f"above ceiling {ceil:.1f}ms")
    return errs


def latency_main(quick: bool = False):
    """benchmarks.run entry for the open-loop lane: one row per percentile,
    gated on the committed SLO ceilings."""
    import json as _json
    import os
    point = run_open_loop(quick=quick)
    base_path = os.path.join(os.path.dirname(__file__), "baselines",
                             "latency.json")
    baseline = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            baseline = _json.load(f)
    errs = check_latency(point, baseline)
    if errs:
        raise RuntimeError("; ".join(errs))
    yield ("serve_ttft_p50", f"{point['ttft_p50_ms'] * 1e3:.0f}",
           f"open-loop @ {point['qps']:g} qps; p99 "
           f"{point['ttft_p99_ms']:.1f}ms")
    yield ("serve_ttft_p99", f"{point['ttft_p99_ms'] * 1e3:.0f}",
           f"time-to-first-token p99 over {point['requests']} reqs")
    yield ("serve_itl_p50", f"{point['itl_p50_ms'] * 1e3:.0f}",
           f"inter-token latency; p99 {point['itl_p99_ms']:.1f}ms")
    yield ("serve_open_loop_tput", f"{1e6 / max(point['tokens_per_sec'], 1e-9):.1f}",
           f"{point['tokens_per_sec']:.1f} delivered tok/s under open loop")


def main(quick: bool = False):
    """benchmarks.run entry: one row per headline serving metric."""
    m, desc = run_workload(quick)
    if desc["finished"] != desc["requests"]:
        raise RuntimeError(
            f"serve workload incomplete: {desc['finished']}/{desc['requests']}")
    us_per_tok = 1e6 / max(m.tokens_per_sec, 1e-9)
    yield ("serve_paged_decode", f"{us_per_tok:.1f}",
           f"{m.tokens_per_sec:.1f} tok/s over {desc['requests']} reqs")
    yield ("serve_paged_ttft", f"{m.ttft_mean_s * 1e6:.0f}",
           f"mean time-to-first-token; max {m.ttft_max_s * 1e3:.0f}ms")
    yield ("serve_paged_pool", f"{m.peak_pool_utilization:.3f}",
           f"peak {m.peak_blocks_used}/{m.pool_blocks} blocks "
           f"(dense equiv {m.dense_equiv_blocks})")
    yield ("serve_prefix_reuse", f"{m.re_prefill_avoided}",
           f"prompt tokens not re-prefilled; {m.shared_blocks} shared / "
           f"{m.cow_copies} CoW blocks")
    yield ("serve_swap_traffic", f"{m.swap_out_blocks + m.swap_in_blocks}",
           f"host-tier blocks: {m.swap_out_blocks} out / "
           f"{m.swap_in_blocks} in ({m.preemptions} preemptions)")


def _check(m, desc) -> List[str]:
    """The PR's acceptance assertions, enforced on every bench run."""
    errs = []
    if desc["finished"] != desc["requests"]:
        errs.append(f"only {desc['finished']}/{desc['requests']} finished")
    if desc.get("token_identical") is False:
        errs.append("sharded run NOT token-identical to single-device run")
    if not m.tokens_per_sec > 0:
        errs.append("tokens_per_sec not positive")
    if not m.ttft_mean_s > 0:
        errs.append("ttft not recorded")
    if not m.peak_pool_utilization < 1.0:
        errs.append(f"pool peaked at {m.peak_pool_utilization:.0%} (expected <100%)")
    if not m.peak_blocks_used < m.dense_equiv_blocks:
        errs.append(f"peak blocks {m.peak_blocks_used} not below dense "
                    f"footprint {m.dense_equiv_blocks}")
    if not m.re_prefill_avoided > 0:
        errs.append("prefix sharing saved no prefill tokens on a workload "
                    "with shared prompt prefixes")
    tp_n = desc.get("tp_devices", 1)
    if tp_n > 1:
        # the PR's memory acceptance: sharding must actually shrink the
        # per-device footprint to ~1/N (+5pt slop for the replicated norms,
        # router tables and any fallback-replicated weights)
        per_dev = desc.get("param_bytes_per_device", 0)
        total = desc.get("param_bytes_replicated", 0)
        ratio = per_dev / total if total else 1.0
        ceiling = 1.0 / tp_n + 0.05
        if not 0 < ratio <= ceiling:
            errs.append(f"TP x{tp_n} per-device param bytes {per_dev} are "
                        f"{ratio:.1%} of replicated {total} "
                        f"(ceiling {ceiling:.1%})")
    return errs


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--baseline", default="")
    ap.add_argument("--max-regress", type=float, default=0.2,
                    help="fail if tokens/sec drops more than this fraction "
                         "below the committed baseline")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the KV pool over this many devices (forces "
                         "a CPU fake pod when needed); the run is verified "
                         "token-identical against an unsharded engine")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel: shard the weights AND the KV pool "
                         "over this many devices (implies --mesh N); the "
                         "point records per-device param bytes and defaults "
                         "to BENCH_serve_tp.json")
    ap.add_argument("--open-loop", action="store_true",
                    help="measure latency under Poisson load through the "
                         "HTTP gateway instead of closed-loop throughput; "
                         "writes BENCH_latency.json and gates on the SLO "
                         "ceilings in --baseline (see "
                         "benchmarks/baselines/latency.json)")
    ap.add_argument("--qps", type=float, default=OPEN_LOOP_QPS,
                    help="open-loop Poisson arrival rate")
    ap.add_argument("--qps-sweep", default="",
                    help="comma-separated arrival rates (e.g. 1,2,4,8): run "
                         "the open-loop lane once per rate and write the "
                         "goodput-vs-QPS curve into the point's qps_sweep "
                         "list (implies --open-loop)")
    ap.add_argument("--multi-lora", action="store_true",
                    help="mixed-tenant multi-LoRA lane: 4 tenants + base "
                         "through the live gateway on ONE engine; gates "
                         "per-tenant isolation (zero cross-tenant prefix "
                         "hits, oracle-identical streams) and throughput "
                         "vs the shared base.  Writes BENCH_multilora.json")
    ap.add_argument("--family", default="", choices=["", "ssm", "hybrid"],
                    help="serve a stateful model family (falcon-mamba-7b / "
                         "zamba2-2.7b smoke archs) through the same paged "
                         "workload, verified token-identical to the family's "
                         "dense oracle across a forced preemption-by-swap; "
                         "writes BENCH_serve_<family>.json (a separate "
                         "trajectory series — the transformer ratchet does "
                         "not apply)")
    ap.add_argument("--requests", type=int, default=0,
                    help="open-loop request count override (0 = workload "
                         "default)")
    ap.add_argument("--deadline-ms", type=float, default=0.0,
                    help="open-loop per-request deadline (engine reaper); "
                         "0 = none.  Relaxes the all-completed gate to "
                         "all-terminal and reports goodput")
    args = ap.parse_args()

    mesh_n = max(args.mesh, args.tp)
    # must land before the jax backend initializes (the first jax import is
    # inside _build_engine, so this is early enough)
    from repro.launch.mesh import ensure_fake_pod
    ensure_fake_pod(mesh_n)

    if args.family:
        if mesh_n:
            print("bench_serve: FAIL: --family does not take --mesh/--tp "
                  "(stateful families are single-device; the slab is not "
                  "sharded)", file=sys.stderr)
            return 2
        out = args.out if args.out != "BENCH_serve.json" \
            else f"BENCH_serve_{args.family}.json"
        m, desc = run_family_workload(args.family, quick=args.quick)
        point = {
            "bench": "serve",
            "unix_time": time.time(),
            "family": args.family,
            "workload": desc,
            "mesh_devices": desc["mesh_devices"],
            "tp_devices": desc["tp_devices"],
            "tokens_per_sec": m.tokens_per_sec,
            "ttft_mean_s": m.ttft_mean_s,
            "itl_mean_s": m.itl_mean_s,
            "peak_pool_utilization": m.peak_pool_utilization,
            "peak_blocks_used": m.peak_blocks_used,
            "dense_equiv_blocks": m.dense_equiv_blocks,
            "state_slots_peak": desc["state_slots_peak"],
            "preemptions": m.preemptions,
            "swap_out_blocks": m.swap_out_blocks,
            "swap_in_blocks": m.swap_in_blocks,
            "metrics": m.to_dict(),
        }
        with open(out, "w") as f:
            json.dump(point, f, indent=2)
        print(m.summary())
        print(f"{args.family} ({desc['arch']}): dense-oracle token identity "
              f"{'OK' if desc['token_identical'] else 'MISMATCH'}, slab peak "
              f"{desc['state_slots_peak']} slots, {m.preemptions} "
              f"preemptions ({m.swap_out_blocks} out / {m.swap_in_blocks} "
              f"in)")
        print(f"{args.family} trajectory point written to {out}")
        if args.baseline:
            print("baseline gate skipped: family points are a separate "
                  "series (transformer ratchet does not apply)")
        errs = check_family(m, desc)
        for e in errs:
            print(f"bench_serve: FAIL: {e}", file=sys.stderr)
        return 1 if errs else 0

    if args.multi_lora:
        if mesh_n:
            print("bench_serve: FAIL: --multi-lora does not take --mesh/--tp"
                  " (multi-LoRA serving is single-device)", file=sys.stderr)
            return 2
        out = args.out if args.out != "BENCH_serve.json" \
            else "BENCH_multilora.json"
        point = run_multilora(quick=args.quick)
        with open(out, "w") as f:
            json.dump(point, f, indent=2)
        print(f"multi-lora: {point['tenants']} tenants + base, "
              f"{point['cross_tenant_prefix_hits']} cross-tenant prefix "
              f"hits, {point['within_tenant_reuse_tokens']} within-tenant "
              f"reuse tokens, {point['adapters_loaded']} adapters resident "
              f"({point['adapter_device_bytes'] / 1e6:.2f} MB slab <= "
              f"{point['adapter_slab_cap_bytes'] / 1e6:.2f} MB cap), mixed "
              f"{point['mixed_tokens_per_sec']:.1f} vs base "
              f"{point['base_tokens_per_sec']:.1f} tok/s "
              f"({point['mixed_vs_base_ratio']:.0%})")
        print(f"multi-lora trajectory point written to {out}")
        errs = check_multilora(point)
        for e in errs:
            print(f"bench_serve: FAIL: {e}", file=sys.stderr)
        return 1 if errs else 0

    if args.open_loop or args.qps_sweep:
        if mesh_n:
            print("bench_serve: FAIL: --open-loop does not take --mesh/--tp "
                  "(the latency lane is single-device)", file=sys.stderr)
            return 2
        out = args.out if args.out != "BENCH_serve.json" \
            else "BENCH_latency.json"
        rates = [float(x) for x in args.qps_sweep.split(",") if x.strip()] \
            if args.qps_sweep else [args.qps]
        sweep = []
        for q in rates:
            sweep.append(run_open_loop(quick=args.quick, qps=q,
                                       n_requests=args.requests,
                                       deadline_ms=args.deadline_ms))
        # the written point is the HIGHEST-rate measurement (the most
        # loaded, the one an SLO ceiling should bite on) and carries the
        # whole goodput-vs-QPS curve for aggregate_serve to render
        point = dict(sweep[-1])
        if len(sweep) > 1:
            point["qps_sweep"] = sweep
        with open(out, "w") as f:
            json.dump(point, f, indent=2)
        for p in sweep:
            print(f"open-loop @ {p['qps']:g} qps over {p['requests']} "
                  f"requests ({p['completed']} completed, "
                  f"{p['requests_shed']} shed / {p['requests_expired']} "
                  f"expired / {p['requests_errored']} errored): "
                  f"TTFT p50/p99 {p['ttft_p50_ms']:.1f}/"
                  f"{p['ttft_p99_ms']:.1f}ms, ITL p50/p99 "
                  f"{p['itl_p50_ms']:.1f}/{p['itl_p99_ms']:.1f}ms, "
                  f"{p['tokens_per_sec']:.1f} delivered tok/s "
                  f"({p['goodput_tokens_per_sec']:.1f} goodput)")
        print(f"latency trajectory point written to {out}")
        baseline = None
        if args.baseline:
            with open(args.baseline) as f:
                baseline = json.load(f)
        import os as _os
        faulty = args.deadline_ms > 0 or bool(_os.environ.get("REPRO_FAULT"))
        errs = []
        for p in sweep:
            for e in check_latency(p, baseline, faulty=faulty):
                errs.append(f"@ {p['qps']:g} qps: {e}"
                            if len(sweep) > 1 else e)
        for e in errs:
            print(f"bench_serve: FAIL: {e}", file=sys.stderr)
        return 1 if errs else 0

    m, desc = run_workload(quick=args.quick, mesh_devices=mesh_n,
                           tp=args.tp >= 1)
    out = args.out
    if args.tp >= 1 and out == "BENCH_serve.json":
        out = "BENCH_serve_tp.json"
    point = {
        "bench": "serve",
        "unix_time": time.time(),
        "workload": desc,
        "mesh_devices": desc["mesh_devices"],
        "tp_devices": desc["tp_devices"],
        "param_bytes_per_device": desc["param_bytes_per_device"],
        "param_bytes_replicated": desc["param_bytes_replicated"],
        "tokens_per_sec": m.tokens_per_sec,
        "ttft_mean_s": m.ttft_mean_s,
        "itl_mean_s": m.itl_mean_s,
        "peak_pool_utilization": m.peak_pool_utilization,
        "peak_blocks_used": m.peak_blocks_used,
        "dense_equiv_blocks": m.dense_equiv_blocks,
        "preemptions": m.preemptions,
        "shared_blocks": m.shared_blocks,
        "cow_copies": m.cow_copies,
        "swap_out_blocks": m.swap_out_blocks,
        "swap_in_blocks": m.swap_in_blocks,
        "re_prefill_avoided": m.re_prefill_avoided,
        "metrics": m.to_dict(),
    }
    with open(out, "w") as f:
        json.dump(point, f, indent=2)
    print(m.summary())
    print(f"trajectory point written to {out}")

    if desc["tp_devices"] > 1:
        ratio = desc["param_bytes_per_device"] / desc["param_bytes_replicated"]
        print(f"tensor parallel x{desc['tp_devices']}: "
              f"{desc['param_bytes_per_device'] / 1e6:.2f} MB/device of "
              f"{desc['param_bytes_replicated'] / 1e6:.2f} MB params "
              f"({ratio:.1%} of replicated)")
    if desc.get("token_identical") is not None:
        print(f"sharded-vs-single token identity: "
              f"{'OK' if desc['token_identical'] else 'MISMATCH'}")
    errs = _check(m, desc)
    # classify by the engine's EFFECTIVE mesh (the --mesh flag and the
    # REPRO_SERVE_MESH knob both count): a sharded point must never be
    # gated against — nor ratcheted into — the single-device series
    if args.baseline and desc.get("sharded"):
        print("baseline gate skipped: sharded points are a separate series "
              "(single-device floor does not apply)")
    elif args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["tokens_per_sec"] * (1.0 - args.max_regress)
        verdict = "OK" if m.tokens_per_sec >= floor else "REGRESSION"
        print(f"baseline gate: {m.tokens_per_sec:.1f} tok/s vs floor "
              f"{floor:.1f} (baseline {base['tokens_per_sec']:.1f} "
              f"- {args.max_regress:.0%}) -> {verdict}")
        if m.tokens_per_sec < floor:
            errs.append(f"throughput regression: {m.tokens_per_sec:.1f} < {floor:.1f}")
    for e in errs:
        print(f"bench_serve: FAIL: {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(cli())
