"""Serving-throughput benchmark: the paged engine on a synthetic
multi-request workload, emitting a ``BENCH_serve.json`` trajectory point.

    PYTHONPATH=src python -m benchmarks.bench_serve --quick \
        --out BENCH_serve.json \
        --baseline benchmarks/baselines/serve.json --max-regress 0.2

Called from ``benchmarks.run`` it yields one CSV row per serving metric; the
CLI additionally writes the JSON point and gates on the committed baseline
(REASONING COMPILER's loop: serving metrics feed back into the compiler's CI,
so a pass that tanks tokens/sec fails the push that introduced it).

The workload is the acceptance scenario from the paged-engine PR: 12 requests
with mixed prompt/output lengths through ``max_batch=4``, which must all
finish, keep pool utilization under 100%, and peak strictly below the dense
``max_batch x max_len`` footprint.

``--mesh N`` measures the mesh-sharded pool instead (fake N-device CPU pod
when real devices are missing): the KV slab is sharded on the kv-heads axis
and the run is verified **token-identical** against an unsharded engine on
the same workload before the point is written.  Sharded points carry
``mesh_devices`` and are a separate trajectory series — the single-device
baseline gate does not apply to them (see benchmarks.aggregate_serve).
"""
from __future__ import annotations

import argparse
import json
import math
import sys
import time
from typing import List, Optional, Tuple

WORKLOAD_REQUESTS = 12
MAX_BATCH = 4
MAX_LEN = 64
BLOCK_SIZE = 8


def _knob_mesh_devices() -> int:
    """Effective REPRO_SERVE_MESH width (0 = off).  The bench resolves the
    knob itself so knob-sharded runs get the same kv-head widening and the
    same forced-single-device reference engine as --mesh runs."""
    import os
    knob = os.environ.get("REPRO_SERVE_MESH", "0")
    if knob in ("", "0", "off"):
        return 0
    if knob == "auto":
        import jax
        return len(jax.devices())
    return int(knob)


def _smoke_cfg(mesh_devices: int = 0):
    """The bench arch.  A sharded run needs kv-heads divisible by the mesh:
    the qwen3 smoke config's GQA kv=2 is widened to the lcm (an explicitly
    different arch — which is why sharded points are a separate series)."""
    import dataclasses

    from repro.configs.base import get_config, reduced_config

    cfg = reduced_config(get_config("qwen3-0.6b"))
    if mesh_devices and cfg.n_kv_heads % mesh_devices:
        kv = math.lcm(cfg.n_kv_heads, mesh_devices)
        assert cfg.n_heads % kv == 0, \
            f"can't widen kv heads to {kv} under {cfg.n_heads} q heads"
        cfg = dataclasses.replace(cfg, n_kv_heads=kv)
    return cfg


def _build_engine(mesh_devices: int = 0, params=None, sharded: bool = True):
    import jax

    from repro.models import build_model
    from repro.serve.engine import ServeEngine

    # the reference engine passes mesh=False so the token-identity oracle
    # can never be silently sharded by ambient env; run_workload resolves
    # REPRO_SERVE_MESH into an explicit mesh_devices before calling here,
    # so mesh=None (knob passthrough) only remains for direct callers
    mesh = False if not sharded else None
    if mesh_devices and sharded:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_devices)
    cfg = _smoke_cfg(mesh_devices)
    fns = build_model(cfg)
    if params is None:
        params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                      block_size=BLOCK_SIZE, mesh=mesh)
    return cfg, eng, params


def _workload(cfg, n: int, seed: int = 0) -> List:
    """Mixed prompt lengths (3..20) and output lengths (4..14).  Every third
    request opens with a common 9-token prefix (a shared system prompt in
    miniature) so the tiered KVStore's prefix sharing / copy-on-write path is
    exercised by the measured run, not just by unit tests."""
    import numpy as np

    from repro.serve.engine import Request, SamplingParams

    rng = np.random.default_rng(seed)
    shared_prefix = rng.integers(1, cfg.vocab, size=9).tolist()
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 21))
        max_new = int(rng.integers(4, 15))
        prompt = rng.integers(1, cfg.vocab, size=plen).tolist()
        if i % 3 == 0:
            prompt = (shared_prefix + prompt)[:20]
        sp = SamplingParams() if i % 3 else \
            SamplingParams(temperature=0.8, top_k=40, seed=i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=max_new, sampling=sp))
    return reqs


def run_workload(quick: bool = False, mesh_devices: int = 0,
                 verify_identical: Optional[bool] = None
                 ) -> Tuple[object, dict]:
    """Returns (ServeMetrics, workload descriptor).  ``quick`` is the CI
    smoke size; the full run pushes 3x the requests through the same pool so
    queueing/admission actually bites.  ``mesh_devices`` > 1 shards the KV
    pool; ``verify_identical`` replays the workload on a forced-unsharded
    engine (same params) and records whether outputs matched token-for-token
    — its default (None) means "whenever the engine's *effective* mesh is
    sharded", which also covers runs sharded by REPRO_SERVE_MESH rather
    than the --mesh flag."""
    # resolve the knob into an explicit width up front, so knob-sharded runs
    # get the widened smoke arch AND a matching-arch reference engine
    mesh_devices = mesh_devices or _knob_mesh_devices()
    cfg, eng, params = _build_engine(mesh_devices)
    n = WORKLOAD_REQUESTS if quick else 3 * WORKLOAD_REQUESTS

    # warm the prefill/decode jit caches outside the measured window (and
    # drop any prefixes it retained — the measured run starts cache-cold)
    for r in _workload(cfg, 2, seed=99):
        eng.submit(r)
    eng.run_until_done()
    eng.release_prefix_cache()
    eng.reset_metrics()

    reqs = _workload(cfg, n)
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    m = eng.metrics()
    desc = {
        "requests": n,
        "finished": len(finished),
        "max_batch": MAX_BATCH,
        "max_len": MAX_LEN,
        "block_size": BLOCK_SIZE,
        "arch": cfg.name,
        "quick": quick,
        "mesh_devices": m.mesh_devices,
        # a 1-device mesh still runs the shard_map configuration (CPU
        # dispatch overhead and all): it must skip the single-device gate
        # even though its width puts it in the single-device table series
        "sharded": eng.mesh is not None,
    }
    if verify_identical is None:
        verify_identical = m.mesh_devices > 1
    if verify_identical:
        _, ref_eng, _ = _build_engine(mesh_devices, params=params,
                                      sharded=False)
        ref = _workload(cfg, n)
        for r in ref:
            ref_eng.submit(r)
        ref_eng.run_until_done()
        desc["token_identical"] = all(
            a.out == b.out for a, b in zip(reqs, ref))
    return m, desc


def main(quick: bool = False):
    """benchmarks.run entry: one row per headline serving metric."""
    m, desc = run_workload(quick)
    if desc["finished"] != desc["requests"]:
        raise RuntimeError(
            f"serve workload incomplete: {desc['finished']}/{desc['requests']}")
    us_per_tok = 1e6 / max(m.tokens_per_sec, 1e-9)
    yield ("serve_paged_decode", f"{us_per_tok:.1f}",
           f"{m.tokens_per_sec:.1f} tok/s over {desc['requests']} reqs")
    yield ("serve_paged_ttft", f"{m.ttft_mean_s * 1e6:.0f}",
           f"mean time-to-first-token; max {m.ttft_max_s * 1e3:.0f}ms")
    yield ("serve_paged_pool", f"{m.peak_pool_utilization:.3f}",
           f"peak {m.peak_blocks_used}/{m.pool_blocks} blocks "
           f"(dense equiv {m.dense_equiv_blocks})")
    yield ("serve_prefix_reuse", f"{m.re_prefill_avoided}",
           f"prompt tokens not re-prefilled; {m.shared_blocks} shared / "
           f"{m.cow_copies} CoW blocks")
    yield ("serve_swap_traffic", f"{m.swap_out_blocks + m.swap_in_blocks}",
           f"host-tier blocks: {m.swap_out_blocks} out / "
           f"{m.swap_in_blocks} in ({m.preemptions} preemptions)")


def _check(m, desc) -> List[str]:
    """The PR's acceptance assertions, enforced on every bench run."""
    errs = []
    if desc["finished"] != desc["requests"]:
        errs.append(f"only {desc['finished']}/{desc['requests']} finished")
    if desc.get("token_identical") is False:
        errs.append("sharded run NOT token-identical to single-device run")
    if not m.tokens_per_sec > 0:
        errs.append("tokens_per_sec not positive")
    if not m.ttft_mean_s > 0:
        errs.append("ttft not recorded")
    if not m.peak_pool_utilization < 1.0:
        errs.append(f"pool peaked at {m.peak_pool_utilization:.0%} (expected <100%)")
    if not m.peak_blocks_used < m.dense_equiv_blocks:
        errs.append(f"peak blocks {m.peak_blocks_used} not below dense "
                    f"footprint {m.dense_equiv_blocks}")
    if not m.re_prefill_avoided > 0:
        errs.append("prefix sharing saved no prefill tokens on a workload "
                    "with shared prompt prefixes")
    return errs


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--baseline", default="")
    ap.add_argument("--max-regress", type=float, default=0.2,
                    help="fail if tokens/sec drops more than this fraction "
                         "below the committed baseline")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the KV pool over this many devices (forces "
                         "a CPU fake pod when needed); the run is verified "
                         "token-identical against an unsharded engine")
    args = ap.parse_args()

    # must land before the jax backend initializes (the first jax import is
    # inside _build_engine, so this is early enough)
    from repro.launch.mesh import ensure_fake_pod
    ensure_fake_pod(args.mesh)

    m, desc = run_workload(quick=args.quick, mesh_devices=args.mesh)
    point = {
        "bench": "serve",
        "unix_time": time.time(),
        "workload": desc,
        "mesh_devices": desc["mesh_devices"],
        "tokens_per_sec": m.tokens_per_sec,
        "ttft_mean_s": m.ttft_mean_s,
        "itl_mean_s": m.itl_mean_s,
        "peak_pool_utilization": m.peak_pool_utilization,
        "peak_blocks_used": m.peak_blocks_used,
        "dense_equiv_blocks": m.dense_equiv_blocks,
        "preemptions": m.preemptions,
        "shared_blocks": m.shared_blocks,
        "cow_copies": m.cow_copies,
        "swap_out_blocks": m.swap_out_blocks,
        "swap_in_blocks": m.swap_in_blocks,
        "re_prefill_avoided": m.re_prefill_avoided,
        "metrics": m.to_dict(),
    }
    with open(args.out, "w") as f:
        json.dump(point, f, indent=2)
    print(m.summary())
    print(f"trajectory point written to {args.out}")

    if desc.get("token_identical") is not None:
        print(f"sharded-vs-single token identity: "
              f"{'OK' if desc['token_identical'] else 'MISMATCH'}")
    errs = _check(m, desc)
    # classify by the engine's EFFECTIVE mesh (the --mesh flag and the
    # REPRO_SERVE_MESH knob both count): a sharded point must never be
    # gated against — nor ratcheted into — the single-device series
    if args.baseline and desc.get("sharded"):
        print("baseline gate skipped: sharded points are a separate series "
              "(single-device floor does not apply)")
    elif args.baseline:
        with open(args.baseline) as f:
            base = json.load(f)
        floor = base["tokens_per_sec"] * (1.0 - args.max_regress)
        verdict = "OK" if m.tokens_per_sec >= floor else "REGRESSION"
        print(f"baseline gate: {m.tokens_per_sec:.1f} tok/s vs floor "
              f"{floor:.1f} (baseline {base['tokens_per_sec']:.1f} "
              f"- {args.max_regress:.0%}) -> {verdict}")
        if m.tokens_per_sec < floor:
            errs.append(f"throughput regression: {m.tokens_per_sec:.1f} < {floor:.1f}")
    for e in errs:
        print(f"bench_serve: FAIL: {e}", file=sys.stderr)
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(cli())
