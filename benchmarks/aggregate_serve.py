"""Serve-bench trajectory aggregator: fold accumulated ``BENCH_serve.json``
artifacts into a trend table and a ratchet suggestion for the committed
baseline floor.

Every CI push uploads one ``BENCH_serve.json`` point (see
``benchmarks.bench_serve``).  Download a pile of them (or collect local
runs) and run

    PYTHONPATH=src python -m benchmarks.aggregate_serve points/*.json \
        --baseline benchmarks/baselines/serve.json [--ratchet]

to get a time-ordered markdown trend table plus a suggested
``tokens_per_sec`` floor: the trailing-median throughput discounted by the
regression margin the CI gate already tolerates.  ``--ratchet`` rewrites the
baseline file in place when (and only when) the suggestion is *above* the
committed floor — the floor only ever moves up, so a noisy slow run can
never loosen the gate.

Points carry a ``mesh_devices`` label (1 = single device; absent in
pre-mesh history, treated as 1) and, since the tensor-parallel PR, a
``tp_devices`` label: ``kv xN`` points shard only the KV pool, ``tp xN``
points also shard the weights (``bench_serve --tp N`` ->
``BENCH_serve_tp.json``).  The trend table distinguishes the two, but the
**ratchet series is single-device only**: sharded runs of either flavour
measure a different engine configuration (GSPMD partitioning, widened kv
heads on the smoke arch, weight gathers), so mixing them into one trailing
median would let a fast sharded run tighten — or a slow one loosen the
pressure on — the single-device floor.

``BENCH_serve_ssm.json`` / ``BENCH_serve_hybrid.json`` points from the
model-family lane (``bench_serve --family ssm|hybrid``) carry a ``family``
label and render in their own table column, but are **excluded from the
ratchet** like sharded ones: a Mamba or hybrid smoke arch measures a
different model entirely — its throughput must never move the transformer
floor.  Unlabelled history is transformer by construction.

``BENCH_latency.json`` points from the open-loop gateway lane
(``bench_serve --open-loop``) mix into the same table: they carry
``open_loop: true`` plus p50/p99 TTFT and inter-token latency, rendered in
their own columns.  History predating those fields gets blank latency cells
(closed-loop points show ``~mean`` from ``ttft_mean_s`` when present) — old
artifacts never crash the aggregator.  Open-loop points are **excluded from
the throughput ratchet** like sharded ones: delivered tok/s under a Poisson
arrival schedule measures the client-visible stream, not engine capacity.
"""
from __future__ import annotations

import argparse
import json
import statistics
import sys
from typing import Dict, List, Optional

# floor = discount * trailing median: mirrors the CI gate's 20% tolerance so
# a freshly-ratcheted floor is passable by the very runs that produced it
DISCOUNT = 0.8
TRAILING = 8           # points in the trailing-median window
MIN_RATCHET_POINTS = 3  # one lucky idle-runner point must not tighten the gate


def load_points(paths: List[str],
                skipped: Optional[List[str]] = None) -> List[Dict]:
    """Load trajectory points, tolerating a missing/empty history: a path
    that doesn't exist or doesn't parse as JSON (a failed CI run uploads an
    empty artifact) is skipped with a note in ``skipped`` instead of a
    traceback.  A file that IS valid JSON but isn't a serve point still
    raises — that's a caller error, not history noise."""
    points = []
    for path in paths:
        try:
            with open(path) as f:
                p = json.load(f)
        except FileNotFoundError:
            if skipped is not None:
                skipped.append(f"{path}: missing (no history yet?)")
            continue
        except json.JSONDecodeError:
            if skipped is not None:
                skipped.append(f"{path}: empty or unparseable JSON")
            continue
        if p.get("bench") == "multilora":
            # BENCH_multilora.json rides the same artifact glob: render its
            # mixed-tenant throughput in the table (ratchet-excluded — a
            # 5-tenant gateway workload is not single-tenant capacity)
            p.setdefault("tokens_per_sec", p.get("mixed_tokens_per_sec", 0.0))
        if "tokens_per_sec" not in p and "ttft_p50_ms" not in p:
            raise ValueError(f"{path}: not a serve/latency trajectory point "
                             "(no tokens_per_sec or ttft_p50_ms)")
        if p.get("qps_sweep"):
            # a --qps-sweep artifact: the top-level point duplicates the
            # highest-rate measurement, so render the sub-points instead —
            # one row per swept rate IS the goodput-vs-QPS curve
            for sub in p["qps_sweep"]:
                sub["_path"] = f"{path}@{sub.get('qps', 0):g}qps"
                points.append(sub)
            continue
        p["_path"] = path
        points.append(p)
    points.sort(key=lambda p: p.get("unix_time", 0.0))
    return points


EMPTY_ROW = ("| – | – | – | – | – | – | – | – | – | – | – | – | no "
             "trajectory points yet — run benchmarks.bench_serve or "
             "download CI artifacts |")


def point_mesh(p: Dict) -> int:
    """A point's serve-mesh width (devices the pool was sharded over).
    Pre-mesh history has no label and is single-device by construction."""
    return int(p.get("mesh_devices")
               or p.get("workload", {}).get("mesh_devices") or 1)


def point_open_loop(p: Dict) -> bool:
    """Whether the point came from the open-loop gateway latency lane
    (``bench_serve --open-loop`` -> BENCH_latency.json)."""
    return bool(p.get("open_loop") or p.get("bench") == "serve_latency")


def point_multilora(p: Dict) -> bool:
    """Whether the point came from the multi-LoRA multiplexing lane
    (``bench_serve --multi-lora`` -> BENCH_multilora.json)."""
    return p.get("bench") == "multilora"


def point_family(p: Dict) -> str:
    """A point's model family (``transformer`` / ``ssm`` / ``hybrid``).
    Pre-family history has no label and is transformer by construction."""
    return str(p.get("family")
               or p.get("workload", {}).get("family") or "transformer")


def point_tp(p: Dict) -> int:
    """A point's tensor-parallel width (devices the *weights* were sharded
    over; 1 = replicated).  Pre-TP history has no label."""
    return int(p.get("tp_devices")
               or p.get("workload", {}).get("tp_devices") or 1)


def point_sharded(p: Dict) -> bool:
    """Whether the point ran the shard_map engine at all — a 1-device mesh
    still measures the sharded configuration (bench_serve sets the flag).
    TP points are sharded by construction (weights need the mesh)."""
    return bool(p.get("sharded")
                or p.get("workload", {}).get("sharded")
                or point_mesh(p) > 1
                or point_tp(p) > 1)


def single_device_points(points: List[Dict]) -> List[Dict]:
    """The ratchet series: only closed-loop points comparable to the
    committed single-device baseline floor (no shard_map engine of any
    width, no open-loop latency runs, no mixed-tenant multi-LoRA runs,
    no ssm/hybrid family lanes — those measure a different model)."""
    return [p for p in points
            if not point_sharded(p) and not point_open_loop(p)
            and not point_multilora(p)
            and point_family(p) == "transformer"]


def _lat_cell(p: Dict, p50_key: str, p99_key: str, mean_key: str) -> str:
    """One 'p50/p99 ms' table cell.  Points predating the percentile fields
    fall back to '~mean' when the mean exists, else a blank dash — old
    artifacts render, they never crash."""
    if p50_key in p:
        return f"{p[p50_key]:.1f}/{p.get(p99_key, 0):.1f}"
    if p.get(mean_key):
        return f"~{p[mean_key] * 1e3:.1f}"
    return "–"


def trend_table(points: List[Dict]) -> str:
    """Markdown trend table, one row per trajectory point, time-ordered,
    labelled closed vs open loop and single-device vs mesh-sharded.  An
    empty history renders one explanatory row rather than nothing."""
    lines = [
        "| # | unix_time | mode | family | mesh | tok/s | ttft p50/p99 ms "
        "| itl p50/p99 ms | shed/exp/err | goodput | pool_peak | preempt "
        "| point |",
        "|---|-----------|------|--------|------|-------|-----------------"
        "|----------------|--------------|---------|-----------|---------"
        "|-------|",
    ]
    if not points:
        return "\n".join(lines + [EMPTY_ROW])
    for i, p in enumerate(points):
        if point_tp(p) > 1:
            label = f"tp x{point_tp(p)}"        # weights + KV pool sharded
        elif point_sharded(p):
            label = f"kv x{point_mesh(p)}"      # KV pool only
        else:
            label = "single"
        if point_multilora(p):
            mode = f"multilora x{p.get('tenants', 0)}"
        elif point_open_loop(p):
            mode = f"open @{p.get('qps', 0):g}qps"
        else:
            mode = "closed"
        pool = f"{p['peak_pool_utilization']:.3f}" \
            if "peak_pool_utilization" in p else "–"
        preempt = str(p["preemptions"]) if "preemptions" in p else "–"
        # fault-tolerance columns (PR 8): history predating them renders
        # blank dashes, never crashes
        if any(k in p for k in ("requests_shed", "requests_expired",
                                "requests_errored")):
            outcomes = (f"{p.get('requests_shed', 0)}/"
                        f"{p.get('requests_expired', 0)}/"
                        f"{p.get('requests_errored', 0)}")
        else:
            outcomes = "–"
        goodput = f"{p['goodput_tokens_per_sec']:.1f}" \
            if "goodput_tokens_per_sec" in p else "–"
        lines.append(
            f"| {i} | {p.get('unix_time', 0):.0f} "
            f"| {mode} "
            f"| {point_family(p)} "
            f"| {label} "
            f"| {p.get('tokens_per_sec', 0):.1f} "
            f"| {_lat_cell(p, 'ttft_p50_ms', 'ttft_p99_ms', 'ttft_mean_s')} "
            f"| {_lat_cell(p, 'itl_p50_ms', 'itl_p99_ms', 'itl_mean_s')} "
            f"| {outcomes} "
            f"| {goodput} "
            f"| {pool} "
            f"| {preempt} "
            f"| {p['_path']} |")
    return "\n".join(lines)


def suggest_floor(points: List[Dict]) -> float:
    """Trailing-median throughput discounted by the gate margin.  Callers
    pass the single-device series only (see ``single_device_points``)."""
    tail = [p["tokens_per_sec"] for p in points[-TRAILING:]]
    return DISCOUNT * statistics.median(tail)


def ratchet(baseline_path: str, suggestion: float, apply: bool,
            veto_reason: str = "") -> str:
    with open(baseline_path) as f:
        base = json.load(f)
    floor = base["tokens_per_sec"]
    if suggestion <= floor:
        return (f"floor stays {floor:.1f} tok/s "
                f"(suggestion {suggestion:.1f} not above it)")
    if not apply:
        hint = f"not applied: {veto_reason}" if veto_reason \
            else "re-run with --ratchet to apply"
        return f"floor {floor:.1f} -> suggest {suggestion:.1f} tok/s ({hint})"
    base["tokens_per_sec"] = round(suggestion, 1)
    base["_comment"] = (base.get("_comment", "").split(" [ratcheted")[0]
                        + f" [ratcheted from {floor:.1f} by "
                          f"benchmarks.aggregate_serve over the last "
                          f"{TRAILING}-point trailing median]")
    with open(baseline_path, "w") as f:
        json.dump(base, f, indent=2)
        f.write("\n")
    return f"floor ratcheted {floor:.1f} -> {base['tokens_per_sec']:.1f} tok/s"


def cli() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("points", nargs="*",
                    help="BENCH_serve.json trajectory points")
    ap.add_argument("--baseline", default="benchmarks/baselines/serve.json")
    ap.add_argument("--ratchet", action="store_true",
                    help="rewrite the baseline floor when the trailing "
                         "median supports a higher one")
    ap.add_argument("--markdown", default="",
                    help="also write the trend table to this file")
    args = ap.parse_args()

    skipped: List[str] = []
    points = load_points(args.points, skipped=skipped)
    table = trend_table(points)
    print(table)
    for note in skipped:
        print(f"skipped: {note}")
    if args.markdown:
        with open(args.markdown, "w") as f:
            f.write(table + "\n")
    if not points:
        # an empty history is a normal state (first push, failed bench run):
        # report it and succeed — the gate lives in bench_serve, not here
        print("\n0 points; nothing to aggregate, baseline floor untouched")
        return 0
    singles = single_device_points(points)
    n_open = sum(1 for p in points if point_open_loop(p))
    n_multilora = sum(1 for p in points if point_multilora(p))
    n_family = sum(1 for p in points if point_family(p) != "transformer"
                   and not point_open_loop(p) and not point_multilora(p)
                   and not point_sharded(p))
    n_sharded = len(points) - len(singles) - n_open - n_multilora - n_family
    if n_family:
        print(f"\n{n_family} ssm/hybrid family point(s) labelled in the "
              "table but excluded from the transformer ratchet series "
              "(a different model's throughput must not move the floor)")
    if n_multilora:
        print(f"\n{n_multilora} multi-LoRA point(s) labelled in the table "
              "but excluded from the single-device ratchet series "
              "(mixed-tenant gateway throughput is not base capacity)")
    if n_sharded:
        print(f"\n{n_sharded} mesh-sharded point(s) labelled in the table "
              "but excluded from the single-device ratchet series")
    if n_open:
        prefix = "" if n_sharded else "\n"
        print(f"{prefix}{n_open} open-loop latency point(s) labelled in "
              "the table but excluded from the throughput ratchet "
              "(Poisson-paced delivery is not engine capacity)")
    if not singles:
        print("no closed-loop single-device points; baseline floor "
              "untouched (the ratchet series is closed-loop "
              "single-device only)")
        return 0
    latest = singles[-1]["tokens_per_sec"]
    suggestion = suggest_floor(singles)
    print(f"\n{len(singles)} single-device points; latest {latest:.1f} "
          f"tok/s; trailing-median floor suggestion {suggestion:.1f}")
    apply = args.ratchet and len(singles) >= MIN_RATCHET_POINTS
    veto = ""
    if args.ratchet and not apply:
        veto = (f"need >= {MIN_RATCHET_POINTS} single-device points, got "
                f"{len(singles)} — one lucky run must not tighten the gate")
        print(f"--ratchet ignored: {veto}")
    print(ratchet(args.baseline, suggestion, apply=apply, veto_reason=veto))
    return 0


if __name__ == "__main__":
    sys.exit(cli())
