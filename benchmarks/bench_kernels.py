"""Kernel-level benchmarks: interpret-mode correctness + modeled μkernel
roofline times (no wall-clock meaning on CPU interpret; the modeled numbers
are the NTT timing model the MINLP optimizes against), plus the jnp
reference's real CPU wall time as a sanity anchor."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule.ntt import ukernel_time
from repro.kernels import ops, ref


def bench_matmul(quick=False):
    m = k = n = 512 if quick else 1024
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    f = jax.jit(ref.matmul_ref)
    f(a, b).block_until_ready()
    t0 = time.monotonic()
    for _ in range(3):
        f(a, b).block_until_ready()
    wall = (time.monotonic() - t0) / 3
    modeled = ukernel_time("matmul", m * k * n)
    out = ops.matmul(a, b, 256, 256, 256)
    err = float(jnp.max(jnp.abs(out - ref.matmul_ref(a, b))))
    return [("kernel_matmul_1024", wall * 1e6,
             f"modeled_tpu={modeled*1e6:.1f}us_err={err:.1e}")]


def bench_flash(quick=False):
    b, s, h, hd = 1, 256 if quick else 512, 4, 64
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    t0 = time.monotonic()
    o = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
    jax.block_until_ready(o)
    wall = time.monotonic() - t0
    from repro.models.attention import multi_head_attention
    err = float(jnp.max(jnp.abs(o - multi_head_attention(q, k, v))))
    return [("kernel_flash_512", wall * 1e6, f"err={err:.1e}")]


def main(quick: bool = False):
    return bench_matmul(quick) + bench_flash(quick)


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
