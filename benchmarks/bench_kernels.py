"""Kernel-level benchmarks: interpret-mode correctness + modeled μkernel
roofline times (no wall-clock meaning on CPU interpret; the modeled numbers
are the NTT timing model the MINLP optimizes against), plus the jnp
reference's real CPU wall time as a sanity anchor.

``python -m benchmarks.bench_kernels --out BENCH_paged_attn.json`` also
emits the paged-attention trajectory point (per-residency traffic model +
kernel-vs-oracle error) for the CI artifact trail.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.schedule.ntt import ukernel_time
from repro.kernels import ops, ref


def bench_matmul(quick=False):
    m = k = n = 512 if quick else 1024
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
    f = jax.jit(ref.matmul_ref)
    f(a, b).block_until_ready()
    t0 = time.monotonic()
    for _ in range(3):
        f(a, b).block_until_ready()
    wall = (time.monotonic() - t0) / 3
    modeled = ukernel_time("matmul", m * k * n)
    out = ops.matmul(a, b, 256, 256, 256)
    err = float(jnp.max(jnp.abs(out - ref.matmul_ref(a, b))))
    return [("kernel_matmul_1024", wall * 1e6,
             f"modeled_tpu={modeled*1e6:.1f}us_err={err:.1e}")]


def bench_flash(quick=False):
    b, s, h, hd = 1, 256 if quick else 512, 4, 64
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, hd)) * 0.3, jnp.float32)
    t0 = time.monotonic()
    o = ops.flash_attention(q, k, v, block_q=128, block_kv=128)
    jax.block_until_ready(o)
    wall = time.monotonic() - t0
    from repro.models.attention import multi_head_attention
    err = float(jnp.max(jnp.abs(o - multi_head_attention(q, k, v))))
    return [("kernel_flash_512", wall * 1e6, f"err={err:.1e}")]


def _paged_attention_results(quick=False):
    """Paged decode at several residency ratios: the dense-gather fallback's
    real CPU wall time vs the streamed kernel's modeled HBM traffic (the
    interpret-mode kernel has no wall-clock meaning — it is emulation — so
    correctness error is reported instead, like bench_flash).

    The traffic model is the point of the kernel: the gather path moves the
    *full* table span (M*bs positions) per decode token regardless of how
    much of it is resident; the kernel streams only ceil(len/bs) pages.

    Returns structured dicts; ``bench_paged_attention`` formats the CSV rows
    and ``cli`` reads the numeric errors for the trajectory point / gate.
    """
    b, h, kv, hd = 4, 4, 2, 64
    bs = 8
    m = 8 if quick else 16
    span = m * bs
    n_pages = b * m + 1
    rng = np.random.default_rng(7)
    k_pages = jnp.asarray(rng.normal(size=(n_pages, bs, kv, hd)) * 0.3,
                          jnp.float32)
    v_pages = jnp.asarray(rng.normal(size=(n_pages, bs, kv, hd)) * 0.3,
                          jnp.float32)
    # each row owns m distinct blocks (block 0 reserved as the null block)
    tables = jnp.asarray(
        rng.permutation(np.arange(1, n_pages))[:b * m].reshape(b, m),
        jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)) * 0.3, jnp.float32)

    gather = jax.jit(ref.paged_attention_ref)
    rows = []
    for ratio in (0.25, 0.5, 1.0):
        lens = jnp.full((b,), max(1, int(span * ratio)), jnp.int32)
        gather(q, k_pages, v_pages, tables, lens).block_until_ready()
        t0 = time.monotonic()
        reps = 5
        for _ in range(reps):
            gather(q, k_pages, v_pages, tables, lens).block_until_ready()
        wall = (time.monotonic() - t0) / reps
        out = ops.paged_attention(q, k_pages, v_pages, tables, lens,
                                  pages_per_fetch=2)
        err = float(jnp.max(jnp.abs(
            out - gather(q, k_pages, v_pages, tables, lens))))
        pages_resident = -(-int(lens[0]) // bs)
        rows.append({"name": f"kernel_paged_attn_r{int(ratio * 100)}",
                     "gather_us": wall * 1e6, "err": err,
                     "streamed_traffic_x": m / pages_resident})
    return rows


def _paged_rows(results):
    return [(r["name"], r["gather_us"],
             f"err={r['err']:.1e}_streamed_traffic="
             f"{r['streamed_traffic_x']:.1f}x_less") for r in results]


def bench_paged_attention(quick=False):
    return _paged_rows(_paged_attention_results(quick))


def _all_rows(quick: bool, paged_rows):
    """One composition shared by the suite entry and the standalone cli."""
    return bench_matmul(quick) + bench_flash(quick) + paged_rows


def main(quick: bool = False):
    return _all_rows(quick, bench_paged_attention(quick))


def cli() -> int:
    """Standalone entry: write the paged-attention trajectory point
    (BENCH_paged_attn.json) for the CI artifact trail and gate on the
    kernel-vs-oracle error.  ``--only paged`` skips the matmul/flash rows
    the benchmarks.run suite already covers."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_paged_attn.json")
    ap.add_argument("--only", choices=("all", "paged"), default="all")
    args = ap.parse_args()
    results = _paged_attention_results(quick=args.quick)
    rows = _paged_rows(results)
    if args.only == "all":
        rows = _all_rows(args.quick, rows)
    for r in rows:
        print(",".join(str(x) for x in r))
    max_err = max(r["err"] for r in results)
    point = {
        "bench": "paged_attn",
        "unix_time": time.time(),
        "quick": args.quick,
        "rows": results,
        "max_err_vs_oracle": max_err,
    }
    with open(args.out, "w") as f:
        json.dump(point, f, indent=2)
    print(f"trajectory point written to {args.out}")
    if max_err > 1e-4:
        print(f"bench_kernels: FAIL: paged kernel err {max_err:.2e} > 1e-4",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(cli())
