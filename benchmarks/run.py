"""Benchmark harness: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--skip-fig9]

Prints ``name,us_per_call,derived`` CSV rows.  Exits nonzero when any module
emits an ERROR row, so CI smoke runs fail loudly instead of swallowing
exceptions into the CSV.
"""
from __future__ import annotations

import argparse
import sys
import traceback
import types


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small shapes / fewer repeats")
    ap.add_argument("--skip-fig9", action="store_true",
                    help="skip the real full-size qwen3 decode benchmark")
    args, _ = ap.parse_known_args()

    from benchmarks import (bench_kernels, bench_latency, bench_multilora,
                            bench_passes, bench_serve, roofline)
    modules = [("passes", bench_passes), ("kernels", bench_kernels),
               ("serve", bench_serve),
               ("serve_ssm", types.SimpleNamespace(main=bench_serve.family_main)),
               ("latency", bench_latency),
               ("multilora", bench_multilora), ("roofline", roofline)]
    if not args.skip_fig9:
        from benchmarks import bench_single_chip
        modules.insert(0, ("fig9", bench_single_chip))

    print("name,us_per_call,derived")
    n_errors = 0
    for name, mod in modules:
        try:
            for row in mod.main(quick=args.quick):
                print(",".join(str(x) for x in row), flush=True)
        except Exception:
            n_errors += 1
            print(f"{name},ERROR,{traceback.format_exc().splitlines()[-1]}",
                  flush=True)
    if n_errors:
        print(f"benchmarks.run: {n_errors} module(s) errored", file=sys.stderr)
    return 1 if n_errors else 0


if __name__ == "__main__":
    sys.exit(main())
