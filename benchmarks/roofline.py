"""Roofline report generator (§Roofline deliverable): reads the dry-run JSON
results and renders the per-(arch x shape x mesh) table with the three terms,
the dominant bottleneck, MODEL_FLOPS/HLO_FLOPS usefulness ratio, and the
suggested lever for the dominant term.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh pod1] [--markdown]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

LEVERS = {
    "compute": "raise arithmetic intensity: larger per-chip batch/tile, fuse "
               "elementwise into matmul epilogues, drop remat recompute",
    "memory": "cut HBM traffic: slimmer remat policy, fused kernels "
              "(flash/ssm-scan keep state in VMEM), bf16 intermediates",
    "collective": "cheaper boxing: reduce-scatter instead of all-reduce, "
                  "bf16 collectives, shard experts/seq to shrink groups",
}


def load(mesh=None):
    rows = []
    for f in sorted(glob.glob(str(RESULTS / "*.json"))):
        stem = os.path.basename(f)
        if stem.count("__") > 2:   # tagged §Perf iteration files
            continue
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        rows.append(d)
    return rows


def render(rows, markdown=False):
    ok = [d for d in rows if d.get("status") == "ok"]
    skipped = [d for d in rows if d.get("status") == "skipped"]
    sep = "|" if markdown else " "
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "bottleneck", "model/hlo_flops", "step_s"]
    lines = []
    if markdown:
        lines.append("| " + " | ".join(hdr) + " |")
        lines.append("|" + "---|" * len(hdr))
    else:
        lines.append(f"{'arch':26s} {'shape':12s} {'mesh':5s} "
                     f"{'comp_s':>9s} {'mem_s':>9s} {'coll_s':>9s} "
                     f"{'bottleneck':>11s} {'mdl/hlo':>8s} {'step_s':>8s}")
    for d in ok:
        r = d["roofline"]
        ratio = d.get("model_vs_hlo_flops")
        vals = [d["arch"], d["shape"], d["mesh"],
                f"{r['compute_s']:.3f}", f"{r['memory_s']:.3f}",
                f"{r['collective_s']:.3f}", r["bottleneck"],
                f"{ratio:.2f}" if ratio else "-",
                f"{r['step_time_s']:.3f}"]
        if markdown:
            lines.append("| " + " | ".join(vals) + " |")
        else:
            lines.append(f"{vals[0]:26s} {vals[1]:12s} {vals[2]:5s} "
                         f"{vals[3]:>9s} {vals[4]:>9s} {vals[5]:>9s} "
                         f"{vals[6]:>11s} {vals[7]:>8s} {vals[8]:>8s}")
    lines.append("")
    lines.append(f"skipped cells (documented): {len(skipped)}")
    for d in skipped:
        lines.append(f"  {d['arch']} x {d['shape']} x {d['mesh']}: "
                     f"{d.get('skip_reason', '')}")
    return "\n".join(lines)


def summarize_bottlenecks(rows):
    ok = [d for d in rows if d.get("status") == "ok"]
    out = ["", "per-bottleneck lever (applies to the dominant-term cells):"]
    seen = set()
    for d in ok:
        b = d["roofline"]["bottleneck"]
        if b not in seen:
            seen.add(b)
            out.append(f"  {b}: {LEVERS[b]}")
    # roofline fraction = compute term / step time (MFU-like upper bound)
    frac = [(d["roofline"]["compute_s"] / max(d["roofline"]["step_time_s"], 1e-12),
             d["arch"], d["shape"], d["mesh"]) for d in ok]
    frac.sort()
    out.append("")
    out.append("worst roofline fractions (compute_s / step_s):")
    for f, a, s, m in frac[:5]:
        out.append(f"  {f*100:5.1f}%  {a} x {s} x {m}")
    out.append("most collective-bound:")
    coll = sorted(ok, key=lambda d: -d["roofline"]["collective_s"])[:3]
    for d in coll:
        out.append(f"  {d['roofline']['collective_s']:.2f}s  "
                   f"{d['arch']} x {d['shape']} x {d['mesh']}")
    return "\n".join(out)


def main(quick=False):
    rows = load()
    print(render(rows))
    print(summarize_bottlenecks(rows))
    ok = [d for d in rows if d.get("status") == "ok"]
    return [("roofline_cells_ok", 0.0, f"n={len(ok)}")]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    rows = load(args.mesh)
    print(render(rows, markdown=args.markdown))
    print(summarize_bottlenecks(rows))
