"""Paper Fig. 9 (single-core decode throughput, batch=1, 8-token prompt).

Faithful protocol on THIS host's single CPU core: real qwen3-0.6b decode via
our stack, f32 and bf16.  The paper's numbers on its Ryzen 5900X 1T:
nncase 8.7 (F32) / 13.87 (F16) tok/s; llama.cpp 10.61/17.21; IPEX 7.58/10.22.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config
from repro.models import build_model


def bench_decode_throughput(arch="qwen3-0.6b", dtype="float32",
                            n_tokens=8, prompt_len=8, max_len=32):
    cfg = dataclasses.replace(get_config(arch), dtype=dtype)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    prompt = jnp.asarray(np.arange(1, prompt_len + 1)[None, :], jnp.int32)
    cache_small, logits = fns.prefill(params, {"tokens": prompt})

    def embed(small, big):
        if small.shape == big.shape:
            return small.astype(big.dtype)
        for ax in range(small.ndim):
            if small.shape[ax] != big.shape[ax]:
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), 0, axis=ax)
        return small

    cache = jax.tree.map(embed, cache_small, fns.make_cache(1, max_len))
    step = jax.jit(lambda p, c, b: fns.decode_step(p, c, b))
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    # warmup (compile)
    c2, lg = step(params, cache, {"token": tok, "cur_len": jnp.int32(prompt_len)})
    jax.block_until_ready(lg)
    t0 = time.monotonic()
    cur = prompt_len
    cache2 = c2
    for i in range(n_tokens):
        cache2, lg = step(params, cache2,
                          {"token": tok, "cur_len": jnp.int32(cur)})
        cur += 1
    jax.block_until_ready(lg)
    dt = time.monotonic() - t0
    return n_tokens / dt, dt / n_tokens


def main(quick: bool = False):
    rows = []
    variants = [("qwen3-0.6b", "float32")] if quick else [
        ("qwen3-0.6b", "float32"), ("qwen3-0.6b", "bfloat16")]
    for arch, dt in variants:
        tput, per_tok = bench_decode_throughput(arch, dt,
                                                n_tokens=4 if quick else 8)
        rows.append((f"fig9_decode_{arch}_{dt}", per_tok * 1e6,
                     f"{tput:.2f}_tok_s"))
    return rows


if __name__ == "__main__":
    for r in main():
        print(",".join(str(x) for x in r))
