"""Chaos lane: the open-loop gateway workload under injected faults.

    JAX_PLATFORMS=cpu PYTHONPATH=src python -m tools.chaos_smoke \
        --fault "alloc:p=0.05,step:exc=2" --requests 12 --out chaos_report.json

Boots the same in-process gateway the open-loop latency bench uses, but
with a seeded ``FaultInjector`` (repro.serve.faults) wired into the live
engine's allocator, swap paths and step dispatch, then fires the serve
workload at it as Poisson arrivals and holds the wreckage to the PR's
fault-tolerance contract:

  * **no hung streams** — every client either finishes its SSE stream or a
    per-client deadline trips (reusing ``tools.gateway_smoke.Deadline`` for
    the whole-run budget);
  * **every request reaches a terminal outcome** — a finished stream
    (``length``), a load-shed 429, or an engine-side terminal
    (``error`` / ``expired``), never silence;
  * **no leaked KV blocks** — after the run drains, both tiers are empty,
    the reservation ledger is zero, and ``ServeEngine.check_invariants()``
    (plus every violation recorded during crash recovery) is clean;
  * **fault-free survivors are oracle-identical** — requests that ran to
    ``length`` stream exactly the tokens a fresh fault-free
    ``run_until_done()`` engine produces for the same request, i.e.
    quarantine/recovery never corrupts an innocent neighbour's KV.

Writes a ``chaos_report.json`` with outcome tallies, per-site fault
counts, and any failures.  Exit status is the number of failed checks.
The chaos-smoke CI job runs this with ``REPRO_FAULT`` exported; the spec
is consumed from the environment (and cleared, so the oracle engine stays
fault-free) when ``--fault`` is not given.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Optional, Tuple

from tools.gateway_smoke import Deadline

DEFAULT_FAULT = "alloc:p=0.05,step:exc=2,swap_out:p=0.2"


async def _served_model_id(host: str, port: int) -> str:
    """The gateway's own base-model id from ``/v1/models`` — smoke clients
    must target what the server advertises, not re-derive the name from
    config (a multi-LoRA gateway also lists ``base:adapter`` cards, so the
    base card is the one without a ``parent``)."""
    import asyncio

    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(b"GET /v1/models HTTP/1.1\r\nHost: chaos\r\n\r\n")
        await writer.drain()
        status_line = await reader.readline()
        assert b" 200 " in status_line, f"/v1/models -> {status_line!r}"
        headers = await reader.readuntil(b"\r\n\r\n")
        length = 0
        for h in headers.decode().split("\r\n"):
            if h.lower().startswith("content-length:"):
                length = int(h.split(":", 1)[1])
        models = json.loads(await reader.readexactly(length))
        bases = [m["id"] for m in models["data"] if not m.get("parent")]
        assert bases, f"no base model card in {models}"
        return bases[0]
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


async def _sse_collect(host: str, port: int, payload: dict
                       ) -> Tuple[List[int], str]:
    """One streamed /v1/completions; returns (token_ids, finish_reason).
    A load-shed 429/503 maps to finish ``"shed"``; any other non-200 to
    ``"http_<status>"``."""
    import asyncio

    body = json.dumps(payload).encode("utf-8")
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(
            b"POST /v1/completions HTTP/1.1\r\nHost: chaos\r\n"
            b"Content-Type: application/json\r\n"
            + f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        await writer.drain()
        status_line = await reader.readline()
        parts = status_line.split()
        status = int(parts[1]) if len(parts) > 1 else 0
        await reader.readuntil(b"\r\n\r\n")
        if status != 200:
            return [], ("shed" if status in (429, 503) else f"http_{status}")
        token_ids: List[int] = []
        finish = ""
        while True:
            line = await reader.readline()
            if not line:
                break
            if not line.startswith(b"data: "):
                continue
            data = line[len(b"data: "):].strip()
            if data == b"[DONE]":
                break
            chunk = json.loads(data)
            if "error" in chunk:
                finish = f"rejected: {chunk['error']['message']}"
                break
            choice = chunk["choices"][0]
            token_ids.extend(choice.get("token_ids") or [])
            if choice.get("finish_reason"):
                finish = choice["finish_reason"]
        return token_ids, finish or "NO_TERMINAL"
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionError, RuntimeError):
            pass


def build_engines(fault_spec: str, seed: int):
    """(cfg, live engine with faults, fault-free oracle engine) sharing one
    set of params, built exactly the way the open-loop bench builds its
    engine."""
    import jax

    from benchmarks.bench_serve import BLOCK_SIZE, MAX_BATCH, MAX_LEN, \
        _smoke_cfg
    from repro.models import build_model
    from repro.serve.engine import ServeEngine
    from repro.serve.faults import FaultInjector

    cfg = _smoke_cfg(0)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    live = ServeEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                       block_size=BLOCK_SIZE, mesh=False,
                       fault_injector=FaultInjector.parse(fault_spec,
                                                          seed=seed))
    oracle = ServeEngine(cfg, params, max_batch=MAX_BATCH, max_len=MAX_LEN,
                         block_size=BLOCK_SIZE, mesh=False,
                         fault_injector=False)
    return cfg, live, oracle


def run_chaos(fault_spec: str, seed: int, n_requests: int, qps: float,
              deadline: Deadline) -> Tuple[Dict, List[str]]:
    import asyncio

    import numpy as np

    from benchmarks.bench_serve import _workload
    from repro.serve.async_engine import AsyncServeEngine
    from repro.serve.gateway import (ByteTokenizer, Gateway, GatewayModel,
                                     Router)

    cfg, live, oracle_eng = build_engines(fault_spec, seed)

    # oracle pass first: exact expected tokens per request AND a warm jit
    # cache, so the chaotic run measures recovery, not compilation
    oracle_reqs = _workload(cfg, n_requests, seed=seed)
    for r in oracle_reqs:
        oracle_eng.submit(r)
    oracle_eng.run_until_done()
    oracle_out = [list(r.out) for r in oracle_reqs]

    reqs = _workload(cfg, n_requests, seed=seed)
    rng = np.random.default_rng(seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / max(qps, 1e-9),
                                         size=n_requests))
    model = GatewayModel(model_id=cfg.name,
                         async_engine=AsyncServeEngine(live,
                                                       model_id=cfg.name),
                         tokenizer=ByteTokenizer(cfg.vocab))

    async def drive():
        async with Gateway(Router([model]), port=0) as gw:
            served_id = await _served_model_id(gw.host, gw.port)

            async def one(i: int):
                await asyncio.sleep(float(arrivals[i]))
                r = reqs[i]
                sp = r.sampling
                try:
                    return await asyncio.wait_for(
                        _sse_collect(gw.host, gw.port, {
                            "model": served_id, "prompt": r.prompt,
                            "max_tokens": r.max_new, "stream": True,
                            "temperature": sp.temperature, "top_k": sp.top_k,
                            "seed": sp.seed}),
                        timeout=max(deadline.remaining, 1.0))
                except asyncio.TimeoutError:
                    return [], "HUNG"
            return await asyncio.gather(*[one(i) for i in range(n_requests)])

    results = asyncio.run(drive())

    failures: List[str] = []
    outcomes: Dict[str, int] = {}
    for i, (ids, finish) in enumerate(results):
        key = finish.split(":", 1)[0]
        outcomes[key] = outcomes.get(key, 0) + 1
        if finish == "HUNG":
            failures.append(f"request {i}: stream hung past the deadline")
        elif finish == "NO_TERMINAL":
            failures.append(f"request {i}: SSE stream ended without a "
                            "terminal event")
        elif finish in ("length", "stop") and ids != oracle_out[i]:
            failures.append(
                f"request {i}: survived but diverged from the fault-free "
                f"oracle: {ids} != {oracle_out[i]}")

    # drain check: with every stream terminal, both tiers must be empty
    live.release_prefix_cache()
    leaks = live.check_invariants()
    host_used = live.store.host.num_used
    if live.pool.num_used != 0:
        failures.append(f"{live.pool.num_used} device blocks leaked "
                        "after drain")
    if host_used != 0:
        failures.append(f"{host_used} host blocks leaked after drain")
    if live.pool.num_reserved != 0:
        failures.append(f"reservation ledger nonzero after drain: "
                        f"{live.pool.num_reserved}")
    failures.extend(f"invariant violation at drain: {e}" for e in leaks)
    failures.extend(f"invariant violation during recovery: {e}"
                    for e in live.invariant_violations)

    m = live.metrics()
    report = {
        "fault_spec": fault_spec,
        "fault_seed": seed,
        "requests": n_requests,
        "qps": qps,
        "unix_time": time.time(),
        "outcomes": outcomes,
        "fault_counts": live.faults.counts(),
        "step_crashes": m.step_crashes,
        "swap_failures": m.swap_failures,
        "requests_errored": m.requests_errored,
        "requests_expired": m.requests_expired,
        "requests_shed": m.requests_shed,
        "degraded": m.degraded,
        "failures": failures,
    }
    return report, failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fault", default="",
                    help="fault spec (site:mode=value,...); default: the "
                         "REPRO_FAULT env var, else a stock chaos mix")
    ap.add_argument("--seed", type=int,
                    default=int(os.environ.get("REPRO_FAULT_SEED", "0")))
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--qps", type=float, default=8.0)
    ap.add_argument("--deadline-s", type=float, default=300.0,
                    help="whole-run wall-clock budget (0 = unlimited)")
    ap.add_argument("--out", default="chaos_report.json")
    args = ap.parse_args()

    # consume (don't inherit) the env spec: the oracle engine and any other
    # ServeEngine built in this process must stay fault-free
    spec = args.fault or os.environ.pop("REPRO_FAULT", "") or DEFAULT_FAULT
    deadline = Deadline(args.deadline_s or None)

    report, failures = run_chaos(spec, args.seed, args.requests, args.qps,
                                 deadline)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"chaos over {args.requests} requests under {spec!r} "
          f"(seed {args.seed}): outcomes {report['outcomes']}, "
          f"{report['step_crashes']} step crashes, "
          f"{report['swap_failures']} swap failures, fault counts "
          f"{report['fault_counts']}")
    print(f"chaos report written to {args.out}")
    for e in failures:
        print(f"chaos_smoke: FAIL: {e}", file=sys.stderr)
    if not failures:
        print("chaos_smoke: all checks passed (no hangs, no leaks, "
              "survivors oracle-identical)")
    return len(failures)


if __name__ == "__main__":
    sys.exit(main())
