"""Live-gateway smoke client: strict SSE framing + batch-oracle identity.

    # terminal 1
    PYTHONPATH=src python -m repro.launch.gateway --smoke --no-plan-kernels \
        --max-batch 2 --max-len 64 --block-size 8 --port 8011
    # terminal 2
    JAX_PLATFORMS=cpu PYTHONPATH=src python -m tools.gateway_smoke \
        --url http://127.0.0.1:8011 --max-batch 2 --max-len 64 --block-size 8

Drives a *running* gateway over real HTTP (stdlib only — http.client for
JSON endpoints, a raw socket for the SSE stream so framing is checked on
the wire, not through a parser that would paper over malformed events) and
asserts:

  * ``/health`` and ``/v1/models`` answer with well-formed JSON;
  * a streamed ``/v1/completions`` emits only ``data: <json>`` events,
    each a valid ``text_completion`` chunk, terminated by exactly one
    ``data: [DONE]``, with ``finish_reason`` and a usage block on the
    final chunk;
  * the streamed ``token_ids`` are **identical** to what a fresh
    ``ServeEngine.run_until_done()`` produces for the same request — the
    engine's stateless (seed, index)-keyed sampling makes the stream
    reproducible no matter what the live engine served before;
  * a streamed ``/v1/chat/completions`` opens with a role delta and ends
    with ``[DONE]``.

The gateway-smoke CI job runs this between booting the gateway and
SIGTERM-ing it.  Exit status is the number of failed checks (0 = ok).
"""
from __future__ import annotations

import argparse
import http.client
import json
import socket
import sys
import time
from typing import List, Optional, Tuple
from urllib.parse import urlparse

# the request both sides generate: mixed sampling, long enough to cross a
# block boundary at the smoke block_size
PROMPT = [3, 5, 7, 11, 13, 17]
MAX_TOKENS = 12
SAMPLING = {"temperature": 0.7, "top_k": 20, "seed": 5}


class Deadline:
    """Whole-run wall-clock budget for a smoke client.

    A wedged gateway (stream that never sends its terminal event) would
    otherwise park the SSE read loops forever and hang the CI job until the
    runner-level timeout.  ``check()`` raises ``TimeoutError`` the moment
    the budget is gone; ``remaining`` doubles as a per-read socket timeout.
    ``tools.chaos_smoke`` reuses this for its no-hung-streams assertion.
    """

    def __init__(self, seconds: Optional[float] = None):
        self.seconds = seconds
        self._t0 = time.monotonic()

    @property
    def remaining(self) -> float:
        if self.seconds is None:
            return float("inf")
        return self.seconds - (time.monotonic() - self._t0)

    def check(self, what: str) -> None:
        if self.remaining <= 0:
            raise TimeoutError(
                f"wall-clock deadline of {self.seconds:.0f}s exhausted "
                f"while {what}")


def _get_json(host: str, port: int, path: str) -> dict:
    conn = http.client.HTTPConnection(host, port, timeout=30)
    try:
        conn.request("GET", path)
        resp = conn.getresponse()
        body = resp.read()
        assert resp.status == 200, f"GET {path} -> {resp.status}: {body!r}"
        return json.loads(body)
    finally:
        conn.close()


def _stream(host: str, port: int, path: str, payload: dict,
            deadline: Optional[Deadline] = None) -> Tuple[List[bytes], dict]:
    """POST a streaming request; return (raw data-lines, response headers).
    Raw socket so the SSE bytes are inspected exactly as sent."""
    body = json.dumps(payload).encode()
    with socket.create_connection((host, port), timeout=60) as sk:
        sk.sendall(f"POST {path} HTTP/1.1\r\nHost: smoke\r\n"
                   "Content-Type: application/json\r\n"
                   f"Content-Length: {len(body)}\r\n\r\n".encode() + body)
        f = sk.makefile("rb")
        status = f.readline()
        assert b" 200 " in status, f"POST {path} -> {status!r}"
        headers = {}
        while True:
            if deadline is not None:
                deadline.check(f"reading response headers of {path}")
                sk.settimeout(min(60.0, max(deadline.remaining, 0.1)))
            line = f.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.decode().partition(":")
            headers[k.strip().lower()] = v.strip()
        assert headers.get("content-type", "").startswith(
            "text/event-stream"), f"not SSE: {headers}"
        lines = []
        while True:
            if deadline is not None:
                deadline.check(f"reading the SSE stream of {path}")
                sk.settimeout(min(60.0, max(deadline.remaining, 0.1)))
            line = f.readline()
            if not line:
                break
            line = line.rstrip(b"\r\n")
            if not line:
                continue
            assert line.startswith(b"data: "), f"malformed SSE line {line!r}"
            lines.append(line[len(b"data: "):])
            if lines[-1] == b"[DONE]":
                break
        return lines, headers


def check_completions(host: str, port: int, model_id: str,
                      oracle: List[int],
                      deadline: Optional[Deadline] = None) -> List[str]:
    errs = []
    lines, headers = _stream(host, port, "/v1/completions", {
        "model": model_id, "prompt": PROMPT, "max_tokens": MAX_TOKENS,
        "stream": True, **SAMPLING}, deadline=deadline)
    if "x-request-id" not in headers:
        errs.append("stream response missing x-request-id header")
    if lines.count(b"[DONE]") != 1 or lines[-1] != b"[DONE]":
        errs.append(f"stream not terminated by exactly one [DONE]: {lines}")
        return errs
    token_ids, finish, usage = [], "", None
    for raw in lines[:-1]:
        chunk = json.loads(raw)
        if chunk.get("object") != "text_completion":
            errs.append(f"bad chunk object: {chunk.get('object')!r}")
        choice = chunk["choices"][0]
        token_ids.extend(choice.get("token_ids") or [])
        if choice.get("finish_reason"):
            finish = choice["finish_reason"]
            usage = chunk.get("usage")
    if finish != "length":
        errs.append(f"finish_reason {finish!r}, want 'length'")
    if not usage or usage.get("completion_tokens") != len(oracle):
        errs.append(f"bad usage block on final chunk: {usage}")
    if token_ids != oracle:
        errs.append(f"streamed tokens {token_ids} != batch oracle {oracle}")
    else:
        print(f"stream == oracle over {len(oracle)} tokens: {token_ids}")
    return errs


def check_chat(host: str, port: int, model_id: str,
               deadline: Optional[Deadline] = None) -> List[str]:
    errs = []
    lines, _ = _stream(host, port, "/v1/chat/completions", {
        "model": model_id, "stream": True, "max_tokens": 4,
        "messages": [{"role": "user", "content": "hi"}]}, deadline=deadline)
    if lines[-1] != b"[DONE]":
        errs.append("chat stream not [DONE]-terminated")
        return errs
    first = json.loads(lines[0])
    if first.get("object") != "chat.completion.chunk":
        errs.append(f"bad chat chunk object: {first.get('object')!r}")
    if first["choices"][0].get("delta", {}).get("role") != "assistant":
        errs.append(f"first chat delta carries no role: {first}")
    return errs


def build_oracle(arch: str, max_batch: int, max_len: int,
                 block_size: int) -> List[int]:
    """What ``run_until_done`` emits for the smoke request — a fresh engine
    built exactly the way ``repro.launch.gateway --smoke`` builds its own."""
    import jax

    from repro.configs.base import get_config, reduced_config
    from repro.models import build_model
    from repro.serve.engine import Request, SamplingParams, ServeEngine

    cfg = reduced_config(get_config(arch))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=max_batch, max_len=max_len,
                      block_size=block_size, plan_kernels=False)
    req = Request(rid=0, prompt=list(PROMPT), max_new=MAX_TOKENS,
                  sampling=SamplingParams(**SAMPLING))
    eng.submit(req)
    eng.run_until_done()
    return list(req.out)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", default="http://127.0.0.1:8011")
    ap.add_argument("--arch", default="qwen3-0.6b",
                    help="arch the gateway serves (reduced config)")
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--deadline-s", type=float, default=120.0,
                    help="whole-run wall-clock budget (0 = unlimited)")
    args = ap.parse_args()
    u = urlparse(args.url)
    host, port = u.hostname, u.port or 80
    deadline = Deadline(args.deadline_s or None)

    health = _get_json(host, port, "/health")
    print(f"health: {health}")
    models = _get_json(host, port, "/v1/models")
    assert models["object"] == "list" and models["data"], models
    # pick the BASE card, not whatever happens to list first: a multi-LoRA
    # gateway also lists `base:adapter` cards (marked with a parent), and
    # the batch oracle below replays the base model only
    bases = [m["id"] for m in models["data"] if not m.get("parent")]
    assert bases, f"no base model card in {models}"
    model_id = bases[0]
    print(f"models: {[m['id'] for m in models['data']]}")

    oracle = build_oracle(args.arch, args.max_batch, args.max_len,
                          args.block_size)
    try:
        errs = check_completions(host, port, model_id, oracle,
                                 deadline=deadline)
        errs += check_chat(host, port, model_id, deadline=deadline)
    except (TimeoutError, socket.timeout) as e:
        errs = [f"hung stream: {e}"]
    for e in errs:
        print(f"gateway_smoke: FAIL: {e}", file=sys.stderr)
    if not errs:
        print("gateway_smoke: all checks passed")
    return len(errs)


if __name__ == "__main__":
    sys.exit(main())
