"""Markdown link walker: verify relative links and intra-doc anchors.

    python -m tools.linkcheck README.md docs/architecture.md

Stdlib-only (the CI container installs nothing for it).  For every
``[text](target)`` in the given files it checks that

  * relative file targets exist on disk (resolved against the linking
    file's directory);
  * ``#fragment`` targets resolve to a github-slugged heading in the
    target markdown file (or the linking file itself for bare ``#...``).

Skipped, deliberately: absolute URLs (no network in CI gates), mailto:,
and targets that resolve outside the repository root — GitHub-web-relative
links like a badge's ``../../actions/...`` are routes on github.com, not
files in the checkout.  Exit status is the number of broken links (0 = ok).
"""
from __future__ import annotations

import re
import sys
from pathlib import Path
from typing import List

# [text](target) — target up to the first ')' or whitespace; images too.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
FENCE_RE = re.compile(r"```.*?```", re.DOTALL)


def slugify(heading: str) -> str:
    """GitHub's anchor slug: lowercase, punctuation stripped, spaces to
    hyphens.  Backticks and asterisks go; underscores stay (GitHub's
    slugger keeps word characters, and ``_`` is one)."""
    h = re.sub(r"[`*]", "", heading.strip()).lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def anchors_of(path: Path) -> set:
    text = FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    return {slugify(m) for m in HEADING_RE.findall(text)}


def check_file(path: Path, root: Path) -> List[str]:
    """Broken-link descriptions for one markdown file (empty = clean)."""
    errors = []
    text = FENCE_RE.sub("", path.read_text(encoding="utf-8"))
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            if target.startswith("#") and \
                    target[1:] not in anchors_of(path):
                errors.append(f"{path}: broken anchor {target!r}")
            continue
        base, _, frag = target.partition("#")
        dest = (path.parent / base).resolve()
        try:
            dest.relative_to(root)
        except ValueError:
            continue  # GitHub-web-relative (badge routes etc.), not a file
        if not dest.exists():
            errors.append(f"{path}: broken link {target!r} "
                          f"(no such file {dest})")
            continue
        if frag and dest.suffix == ".md" and frag not in anchors_of(dest):
            errors.append(f"{path}: broken anchor {target!r} "
                          f"(no heading slugs to {frag!r} in {dest.name})")
    return errors


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python -m tools.linkcheck FILE.md [FILE.md ...]",
              file=sys.stderr)
        return 2
    root = Path.cwd().resolve()
    errors = []
    for name in argv:
        p = Path(name)
        if not p.exists():
            errors.append(f"{name}: file to check does not exist")
            continue
        errors.extend(check_file(p.resolve(), root))
    for e in errors:
        print(f"linkcheck: FAIL: {e}", file=sys.stderr)
    n = len(LINK_RE.findall("".join(
        Path(a).read_text(encoding="utf-8") for a in argv
        if Path(a).exists())))
    print(f"linkcheck: {len(argv)} files, {n} links, "
          f"{len(errors)} broken")
    return min(len(errors), 125)


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
