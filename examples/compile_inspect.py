"""Mini dry-run: lower + compile one (arch x shape) cell on the production
mesh and print its roofline terms.  (512 fake devices — set before jax
import, which is why this example re-execs through repro.launch.dryrun.)

    PYTHONPATH=src python examples/compile_inspect.py --arch qwen3-0.6b --shape decode_32k
"""
import argparse
import json
import subprocess
import sys
import tempfile
from pathlib import Path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="pod1")
    args = ap.parse_args()
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
         "--shape", args.shape, "--mesh", args.mesh],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        capture_output=True, text=True, cwd=Path(__file__).parents[1])
    print(r.stdout[-4000:])
    if r.returncode != 0:
        print(r.stderr[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
