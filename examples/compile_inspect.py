"""Compile inspection: the unified pipeline report for one arch's attention
block, then (optionally) the full XLA dry-run of the (arch x shape) cell on
the production mesh.  (The dry-run fakes 512 devices — that flag must be set
before jax imports, which is why it re-execs through repro.launch.dryrun.)

    PYTHONPATH=src python examples/compile_inspect.py --arch qwen3-0.6b --shape decode_32k
    PYTHONPATH=src python examples/compile_inspect.py --pipeline-only
"""
import argparse
import subprocess
import sys
from pathlib import Path


def pipeline_report(arch: str, shape: str) -> None:
    """Term-level compile of the arch's attention block through
    repro.pipeline, with per-pass telemetry."""
    sys.path.insert(0, str(Path(__file__).parents[1] / "src"))
    from repro.configs.base import SHAPES, get_config
    from repro.pipeline import CompileOptions, compile
    from repro.serve.engine import attention_block_term

    cfg = get_config(arch)
    spec = SHAPES[shape]
    # cap the modeled sequence so the e-graph stays inspection-sized
    seq = min(spec.seq_len, 4096)
    term = attention_block_term(seq, cfg.resolved_head_dim)
    res = compile(term, options=CompileOptions(extraction="greedy"))
    print(f"=== pipeline report: {arch} attention block "
          f"(seq {seq} x head_dim {cfg.resolved_head_dim}) ===")
    print(res.report.summary())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--mesh", default="pod1")
    ap.add_argument("--pipeline-only", action="store_true",
                    help="skip the (slow) XLA dry-run subprocess")
    args = ap.parse_args()

    pipeline_report(args.arch, args.shape)
    if args.pipeline_only:
        return

    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", args.arch,
         "--shape", args.shape, "--mesh", args.mesh],
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin"},
        capture_output=True, text=True, cwd=Path(__file__).parents[1])
    print(r.stdout[-4000:])
    if r.returncode != 0:
        print(r.stderr[-2000:])
        sys.exit(1)


if __name__ == "__main__":
    main()
