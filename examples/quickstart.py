"""Quickstart: the three nncase passes + a training step, all on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.core.codegen import compile_term
from repro.core.distribution import auto_distribute, ndsbp_to_pspec, build_distributed_egraph
from repro.core.sbp import Placement
from repro.core.schedule import attention_tile_graph, auto_schedule
from repro.core.tensor_ir import inp, matmul, unary
from repro.core.vectorize import auto_vectorize, count_ops
from repro.models import build_model


def demo_auto_vectorize():
    print("=== Auto Vectorize (Fig. 3): O = MatMul(Exp(MatMul(Q,K)), V) ===")
    Q, K, V = inp("Q", (1024, 128)), inp("K", (128, 1024)), inp("V", (1024, 128))
    term = matmul(unary(matmul(Q, K), kind="exp"), V)
    cost, packed, stats = auto_vectorize(term)
    print(f"  baseline {stats['baseline_cost']:.3e}s -> packed {cost:.3e}s "
          f"({stats['baseline_cost'] / cost:.1f}x modeled)")
    print(f"  pack ops: {count_ops(packed, 'pack')} (inputs only), "
          f"unpack: {count_ops(packed, 'unpack')} (output only) — "
          "blocked layout passes through Exp")
    # semantics preserved
    rng = np.random.default_rng(0)
    env = {n: jnp.array(rng.normal(size=s) * 0.1, jnp.float32)
           for n, s in [("Q", (1024, 128)), ("K", (128, 1024)), ("V", (1024, 128))]}
    err = float(jnp.max(jnp.abs(compile_term(packed)(**env)
                                - compile_term(term)(**env))))
    print(f"  max abs err packed-vs-logical: {err:.2e}")


def demo_auto_distribute():
    print("=== Auto Distribution (SBP search on a 4x4 mesh) ===")
    x = inp("x", (4096, 1024))
    w1, w2 = inp("w1", (1024, 4096)), inp("w2", (4096, 1024))
    y = matmul(unary(matmul(x, w1), kind="exp"), w2)
    pl = Placement(("data", "model"), (4, 4))
    dg = build_distributed_egraph(y, pl)
    free = auto_distribute(y, pl, use_sat=False)
    print(f"  unconstrained: cost {free.cost:.3e}s, peak {free.peak_memory/1e6:.1f} MB/dev")
    capped = auto_distribute(y, pl, mem_capacity=25_000_000)
    print(f"  25MB cap:      cost {capped.cost:.3e}s, peak {capped.peak_memory/1e6:.1f} MB/dev")
    for tid, nd in sorted(capped.assignments.items()):
        t = dg.terms[tid]
        print(f"    {t.op:8s} {t.attr('name') or '':4s} -> {nd} "
              f"(pspec {ndsbp_to_pspec(nd, pl, 2)})")


def demo_auto_schedule():
    print("=== Auto Schedule (MCTS structure + MINLP tiles) ===")
    tg = attention_tile_graph(4096, 128)
    state, sched, base = auto_schedule(tg, iterations=25)
    print(f"  baseline {base.latency:.3e}s -> scheduled {sched.latency:.3e}s")
    print(f"  fused groups: {[g.ops for g in state.groups]}")
    print(f"  VMEM tiles: {sched.tiles} (peak {sched.vmem_peak/2**20:.1f} MB)")


def demo_train_step():
    print("=== One train step (reduced qwen3 on CPU) ===")
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    loss = fns.loss(params, {"tokens": toks, "labels": toks}, remat=False)
    print(f"  loss: {float(loss):.4f}")


if __name__ == "__main__":
    demo_auto_vectorize()
    demo_auto_distribute()
    demo_auto_schedule()
    demo_train_step()
