"""Quickstart: the full nncase pipeline in one call + a training step, on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.core.codegen import compile_term
from repro.core.distribution import ndsbp_to_pspec
from repro.core.tensor_ir import inp, matmul, unary
from repro.core.vectorize import count_ops
from repro.models import build_model
from repro.pipeline import CompileTarget, Compiler


def fig3_term():
    """O = MatMul(Exp(MatMul(Q, K)), V) — the paper's running example."""
    Q, K, V = inp("Q", (1024, 128)), inp("K", (128, 1024)), inp("V", (1024, 128))
    return matmul(unary(matmul(Q, K), kind="exp"), V)


def demo_pipeline(compiler: Compiler):
    print("=== One-call pipeline (Fig. 3): O = MatMul(Exp(MatMul(Q,K)), V) ===")
    term = fig3_term()
    res = compiler.compile(term)
    r = res.report
    print(f"  baseline {r.baseline_cost:.3e}s -> packed {r.optimized_cost:.3e}s "
          f"({r.modeled_speedup:.1f}x modeled)")
    print(f"  pack ops: {count_ops(res.term, 'pack')} (inputs only), "
          f"unpack: {count_ops(res.term, 'unpack')} (output only) — "
          "blocked layout passes through Exp")
    print("  pass times: " + " ".join(
        f"{k}={v * 1e3:.1f}ms" for k, v in r.pass_times.items()))
    # semantics preserved vs. the unoptimized reference interpretation
    rng = np.random.default_rng(0)
    env = {n: jnp.array(rng.normal(size=s) * 0.1, jnp.float32)
           for n, s in [("Q", (1024, 128)), ("K", (128, 1024)), ("V", (1024, 128))]}
    err = float(jnp.max(jnp.abs(res(**env) - compile_term(term)(**env))))
    print(f"  max abs err packed-vs-logical: {err:.2e}")
    res2 = compiler.compile(term)
    print(f"  recompile: cache_hit={res2.report.cache_hit} "
          f"({res2.report.total_seconds * 1e3:.1f}ms vs "
          f"{res.report.total_seconds * 1e3:.1f}ms cold)")


def demo_auto_distribute(compiler: Compiler):
    print("=== Auto Distribution (SBP search on a 4x4 mesh) ===")
    x = inp("x", (4096, 1024))
    w1, w2 = inp("w1", (1024, 4096)), inp("w2", (4096, 1024))
    y = matmul(unary(matmul(x, w1), kind="exp"), w2)
    mesh = dict(mesh_axes=("data", "model"), mesh_sizes=(4, 4))
    free = compiler.compile(y, target=CompileTarget(**mesh)).report.distribution
    print(f"  unconstrained: cost {free['cost']:.3e}s, "
          f"peak {free['peak_memory'] / 1e6:.1f} MB/dev")
    capped_res = compiler.compile(
        y, target=CompileTarget(**mesh, memory_capacity=25_000_000))
    capped = capped_res.report.distribution
    print(f"  25MB cap:      cost {capped['cost']:.3e}s, "
          f"peak {capped['peak_memory'] / 1e6:.1f} MB/dev")
    pl = CompileTarget(**mesh).placement
    for tid, nd in sorted(capped["assignments"].items()):
        print(f"    term {tid:2d} -> {nd} (pspec {ndsbp_to_pspec(nd, pl, 2)})")


def demo_auto_schedule(compiler: Compiler):
    print("=== Auto Schedule (MCTS structure + MINLP tiles) ===")
    res = compiler.compile(fig3_term())
    s = res.report.schedule
    print(f"  baseline {s['baseline_latency']:.3e}s -> scheduled {s['latency']:.3e}s")
    print(f"  fused groups: {s['groups']}")
    print(f"  kernel plan: {res.report.kernel_plan} "
          f"(vmem peak {s['vmem_peak'] / 2**20:.1f} MB)")


def demo_train_step():
    print("=== One train step (reduced qwen3 on CPU) ===")
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    loss = fns.loss(params, {"tokens": toks, "labels": toks}, remat=False)
    print(f"  loss: {float(loss):.4f}")


if __name__ == "__main__":
    compiler = Compiler()
    demo_pipeline(compiler)
    demo_auto_distribute(compiler)
    demo_auto_schedule(compiler)
    demo_train_step()
