"""End-to-end training with checkpoint/restart: trains a small LM on the
synthetic corpus, injects a failure mid-run, and recovers from the latest
checkpoint.

    PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""
import argparse
import tempfile

from repro.configs.base import get_config, reduced_config
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    workdir = args.workdir or tempfile.mkdtemp(prefix="repro_train_")
    tcfg = TrainerConfig(seq_len=128, global_batch=4, steps=args.steps,
                         checkpoint_every=20, log_every=5, workdir=workdir)
    opt = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    trainer = Trainer(cfg, tcfg, opt)
    # inject one failure at 2/3 of the run: the loop restores from the last
    # checkpoint and replays (batches are (seed, step)-keyed, so training is
    # bit-identical to an uninterrupted run)
    result = trainer.train(fail_at=int(args.steps * 2 / 3))
    first, last = result["log"][0]["loss"], result["log"][-1]["loss"]
    print(f"\nloss {first:.4f} -> {last:.4f} "
          f"({'DECREASED' if last < first else 'no improvement'}); "
          f"checkpoints in {workdir}")


if __name__ == "__main__":
    main()
