"""Batched serving example: continuous batching over slot-based KV cache.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(1, cfg.vocab, size=6).tolist(),
                    max_new=12) for i in range(8)]
    for r in reqs:
        eng.submit(r)
    t0 = time.monotonic()
    eng.run_until_done()
    dt = time.monotonic() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out) for r in reqs)
    print(f"completed {done}/8 requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s, {eng.steps} batched decode steps)")
    for r in reqs[:3]:
        print(f"  req {r.rid}: prompt {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
