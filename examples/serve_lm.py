"""Paged-KV serving example: continuous batching with per-request sampling.

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, SamplingParams, ServeEngine


def main():
    cfg = reduced_config(get_config("qwen3-0.6b"))
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_len=64, block_size=8)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(8):
        prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 10))).tolist()
        # half greedy, half temperature sampling — per-request strategies
        sp = SamplingParams() if i % 2 == 0 else \
            SamplingParams(temperature=0.8, top_k=40, seed=i)
        reqs.append(Request(rid=i, prompt=prompt, max_new=12, sampling=sp))
    for r in reqs:
        eng.submit(r)
    finished = eng.run_until_done()
    m = eng.metrics()
    print(m.summary())
    print(f"dense slot cache would pin {m.dense_equiv_blocks} blocks; "
          f"paged peak was {m.peak_blocks_used}")
    for r in finished[:3]:
        mode = "greedy" if r.sampling.temperature <= 0 else \
            f"T={r.sampling.temperature}/top{r.sampling.top_k}"
        print(f"  req {r.rid} ({mode}): prompt {r.prompt} -> {r.out}")


if __name__ == "__main__":
    main()
