"""Sharding policy: pytree -> PartitionSpec trees for params, batches, caches.

The rules here are the *materialization* of the Auto Distribution module's SBP
assignments (see ``repro.core.distribution``): S(axis) on a tensor dim becomes
a mesh axis name in that dim's PartitionSpec entry, B becomes None, and P
never appears on stored tensors (partial values only exist transiently inside
einsums, where GSPMD inserts the reduction).

Conventions:
  * mesh axes: ("data", "model") single-pod, ("pod", "data", "model") 2-pod.
  * FSDP axes = ("pod","data") when present — weights are sharded over them on
    a non-contracting dim and all-gathered per layer by XLA.
  * TP axis = "model" — heads / ffn / experts / d_inner.
  * Any rule entry is dropped (-> None) if the dim size is not divisible by
    the mesh axis size (e.g. whisper's vocab 51865), keeping GSPMD padding out
    of the memory analysis.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


# Logical activation axes -> mesh axes.  This table is the Auto Distribution
# module's output surface: models annotate tensors with *logical* names and
# the ambient mesh decides the physical placement.
LOGICAL_AXES = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "heads": ("model",),
    "kv": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "dinner": ("model",),
    "experts": ("model",),
    "seq_mp": ("model",),          # sequence-parallel over the model axis
    "seq_dp": ("pod", "data"),     # sequence-parallel over the data axes
    None: (),
}


def _ambient_mesh() -> Optional[Mesh]:
    from jax._src.mesh import thread_resources
    m = thread_resources.env.physical_mesh
    return None if m.empty else m


def constrain(x, *logical):
    """with_sharding_constraint via logical axis names; silently no-ops when
    no mesh is active or a dim isn't divisible by the target axes."""
    mesh = _ambient_mesh()
    if mesh is None or not hasattr(x, "shape") or len(logical) != len(x.shape):
        return x
    from repro.perf import perf
    dp_mode = perf().train_sharding == "dp"
    entries = []
    for dim, name in zip(x.shape, logical):
        table = LOGICAL_AXES.get(name, ())
        if dp_mode:
            if name in ("batch", "fsdp"):
                table = tuple(mesh.shape.keys())
            elif name not in (None, "seq_dp"):
                table = ()   # no tensor-parallel constraints in pure DP
        axes = tuple(a for a in table if a in mesh.shape and mesh.shape[a] > 1)
        size = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1
        if not axes or dim % size != 0:
            entries.append(None)
        else:
            entries.append(axes if len(axes) > 1 else axes[0])
    if all(e is None for e in entries):
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))


def weight_use(w, *logical):
    """Constrain a weight AT ITS USE SITE to TP-only placement (drops the
    FSDP axes).  Under REPRO_WEIGHT_AG=1 this forces GSPMD to all-gather the
    small weight shard instead of partial-summing the large activations over
    the FSDP-sharded contraction dim — see perf.py.

    On the serve path with weight tensor parallelism armed (``ServeEngine``
    with ``tp=True``), this instead defers to ``param_sharding.tp_use``:
    replicate-at-use for bitwise identity, or passthrough under
    REPRO_TP_REDUCE_SCATTER=1 so compute follows the stored column/row
    layout with one all-reduce per layer."""
    from repro.distributed import param_sharding as _psh
    if _psh.serve_tp_active():
        return _psh.tp_use(w)
    from repro.perf import perf
    if not perf().weight_ag:
        return w
    return constrain(w, *logical)


def mesh_axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh_axis_size(mesh, n) for n in name]))
    return mesh.shape[name] if name in mesh.shape else 1


def fsdp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_axes(mesh: Mesh):
    from repro.perf import perf
    if perf().train_sharding == "dp":
        # pure data parallelism: batch over EVERY mesh axis
        return _unwrap(tuple(mesh.shape.keys()))
    return ("pod", "data") if "pod" in mesh.shape else "data"


def _unwrap(entry):
    """1-tuples -> bare axis name: PartitionSpec(('data',)) and
    PartitionSpec('data') shard identically but no longer compare equal."""
    if isinstance(entry, tuple) and len(entry) == 1:
        return entry[0]
    return entry


def _fits(dim: int, mesh: Mesh, entry) -> bool:
    return entry is None or dim % mesh_axis_size(mesh, entry) == 0


def _spec_for(shape: Tuple[int, ...], trailing, mesh: Mesh) -> P:
    """Build a PartitionSpec: Nones for leading dims + `trailing` rules for the
    last len(trailing) dims, with divisibility guards."""
    n = len(shape)
    t = list(trailing)[-n:] if len(trailing) > n else list(trailing)
    entries = [None] * (n - len(t)) + t
    entries = [e if _fits(shape[i], mesh, e) else None
               for i, e in enumerate(entries)]
    return P(*entries)


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, params_abstract, mesh: Mesh):
    from repro.perf import perf
    if perf().train_sharding == "dp":
        # weights fully replicated (Auto Distribution's answer for small
        # models under a satisfied memory constraint): every spec is None
        return jax.tree.map(lambda l: P(*([None] * len(l.shape))),
                            params_abstract)
    FS = fsdp_axes(mesh)
    TP = "model"

    def rule(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        in_moe = "moe" in keys
        shape = leaf.shape

        if name in ("ln", "ln1", "ln2", "ln_x", "final_norm", "enc_norm",
                    "norm", "q_norm", "k_norm", "dt_bias", "conv_b", "D"):
            # conv_b/D are d_inner-sized: shard over TP when they fit
            if name in ("conv_b", "D", "dt_bias") and shape:
                return _spec_for(shape, (TP,), mesh)
            return P(*([None] * len(shape)))
        if name == "embed":
            return _spec_for(shape, (TP, FS), mesh)
        if name == "unembed":
            return _spec_for(shape, (FS, TP), mesh)
        if name in ("wq", "wk", "wv"):
            return _spec_for(shape, (FS, TP), mesh)
        if name in ("wi", "wi_gate", "wi_up"):
            if in_moe and len(shape) >= 3:      # (..., E, d, f): expert parallel
                return _spec_for(shape, (TP, FS, None), mesh)
            return _spec_for(shape, (FS, TP), mesh)
        if name in ("wo", "out_proj"):
            if in_moe and len(shape) >= 3:      # (..., E, f, d)
                return _spec_for(shape, (TP, None, FS), mesh)
            return _spec_for(shape, (TP, FS), mesh)
        if name == "router":
            return _spec_for(shape, (FS, None), mesh)
        if name in ("in_proj", "in_proj_zx"):
            return _spec_for(shape, (FS, TP), mesh)
        if name == "in_proj_bcdt":
            return _spec_for(shape, (FS, None), mesh)
        if name == "x_proj":
            return _spec_for(shape, (TP, None), mesh)
        if name == "dt_proj":
            return _spec_for(shape, (None, TP), mesh)
        if name == "conv_w":
            return _spec_for(shape, (None, TP), mesh)
        if name == "A_log":
            if shape and shape[-1] > 1 and len(shape) >= 2 and shape[-2] % 8 == 0:
                return _spec_for(shape, (TP, None), mesh)   # mamba1 (di, N)
            return _spec_for(shape, (TP,), mesh)            # mamba2 (H,)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, params_abstract)


# ---------------------------------------------------------------------------
# Batches
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_abstract: Dict, mesh: Mesh):
    BA = batch_axes(mesh)
    nb = mesh_axis_size(mesh, BA)

    def rule(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        shape = leaf.shape
        if name in ("tokens", "labels", "token"):
            e = BA if shape[0] % nb == 0 else None
            return P(e, *([None] * (len(shape) - 1)))
        if name in ("embeds", "frames"):
            e = BA if shape[0] % nb == 0 else None
            return P(e, None, None)
        if name == "positions":
            e = BA if shape[1] % nb == 0 else None
            return P(None, e, *([None] * (len(shape) - 2)))
        if name == "cur_len":
            return P()
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, batch_abstract)


# ---------------------------------------------------------------------------
# Decode / prefill caches
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, cache_abstract, mesh: Mesh):
    BA = batch_axes(mesh)
    nb = mesh_axis_size(mesh, BA)
    tp_n = mesh_axis_size(mesh, "model")

    def rule(path, leaf):
        name = getattr(path[-1], "key", str(path[-1]))
        shape = leaf.shape
        if name in ("k", "v", "xk", "xv"):
            # (..., B, S, KV, hd)
            b, s, kv = shape[-4], shape[-3], shape[-2]
            lead = [None] * (len(shape) - 4)
            if b % nb == 0:
                bent, sent = BA, None
            else:
                bent, sent = None, BA if s % nb == 0 else None
            if kv % tp_n == 0:
                kvent, s2 = "model", sent
            else:
                # GQA with KV < model size: sequence-parallel KV cache
                kvent = None
                s2 = (sent, "model") if sent and s % (nb * tp_n) == 0 else (
                    "model" if s % tp_n == 0 else sent)
            return P(*lead, bent, s2, kvent, None)
        if name == "h":
            # mamba1 (L,B,di,N) / hybrid (nseg,per,B,H,P,N)
            if len(shape) == 4:
                b, di = shape[1], shape[2]
                return P(None, BA if b % nb == 0 else None,
                         "model" if di % tp_n == 0 else None, None)
            lead = [None] * (len(shape) - 4)
            b, hh = shape[-4], shape[-3]
            return P(*lead, BA if b % nb == 0 else None,
                     "model" if hh % tp_n == 0 else None, None, None)
        if name == "conv":
            # (..., B, K-1, di)
            lead = [None] * (len(shape) - 3)
            b, di = shape[-3], shape[-1]
            return P(*lead, BA if b % nb == 0 else None, None,
                     "model" if di % tp_n == 0 else None)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_abstract)


def paged_cache_specs(cfg: ModelConfig, cache_abstract, mesh: Mesh):
    """PartitionSpecs for the serve engine's paged KV block slab.

    The paged layout ``(n_layers, num_blocks, block_size, KV, hd)`` lines up
    with the dense cache rule's trailing ``(B, S, KV, hd)`` dims, so
    ``cache_specs`` already lands "model" on the kv-heads axis when it
    divides.  This wrapper then drops every OTHER entry: the block axis is
    indexed host-side by the allocator / swap / copy-on-write data plane and
    the block_size axis is the token offset within a block — neither may be
    partitioned (the GQA seq-parallel fallback in ``cache_specs`` would
    otherwise split block_size when KV doesn't divide the model axis).  The
    result shards exactly one thing: each device owns ``KV / n_model`` heads
    of every block in the pool."""
    base = cache_specs(cfg, cache_abstract, mesh)

    def rule(spec, leaf):
        n = len(leaf.shape)
        entries = list(spec) + [None] * (n - len(spec))
        return P(*[e if (i == n - 2 and e == "model") else None
                   for i, e in enumerate(entries)])

    return jax.tree.map(rule, base, cache_abstract,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Optimizer state
# ---------------------------------------------------------------------------

def opt_state_specs(param_spec_tree, opt_state_abstract, mesh: Mesh):
    """Moments mirror the param specs; Quantized leaves shard blocks over all
    axes; step is replicated.  Under pure-DP sharding the moments are
    ZeRO-sharded along their largest divisible dim over all axes."""
    from repro.perf import perf
    from repro.train.optimizer import Quantized
    all_axes = tuple(mesh.shape.keys())
    n_all = mesh_axis_size(mesh, all_axes)
    zero_style = perf().train_sharding == "dp"

    def moment_spec(spec, leaf):
        if isinstance(leaf, Quantized):
            nb = leaf.q.shape[0] if hasattr(leaf.q, "shape") else 0
            qspec = P(all_axes, None) if nb % max(1, n_all) == 0 else P(None, None)
            nsc = leaf.scale.shape[0] if hasattr(leaf.scale, "shape") else 0
            sspec = P(all_axes, None) if nsc % max(1, n_all) == 0 else P(None, None)
            return Quantized(qspec, sspec, leaf.shape, leaf.pad)
        if zero_style and hasattr(leaf, "shape"):
            # ZeRO-1: shard the first dim divisible by the full device count
            entries = [None] * len(leaf.shape)
            for i, d in enumerate(leaf.shape):
                if d % n_all == 0:
                    entries[i] = all_axes
                    break
            return P(*entries)
        return spec

    specs = {
        "step": P(),
        "m": jax.tree.map(moment_spec, param_spec_tree, opt_state_abstract["m"],
                          is_leaf=lambda x: isinstance(x, Quantized)),
        "v": jax.tree.map(moment_spec, param_spec_tree, opt_state_abstract["v"],
                          is_leaf=lambda x: isinstance(x, Quantized)),
    }
    return specs


def to_named(tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s, tree,
        is_leaf=lambda x: isinstance(x, P))
