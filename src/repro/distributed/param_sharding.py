"""Rule-driven tensor-parallel parameter sharding for serving.

The flow (see docs/sharding.md for the worked example):

  1. :func:`choose_tp_rules` asks Auto Distribution's SBP cost model
     (``repro.core.distribution.choose_tp_layout``) which layout each weight
     family should get — column-parallel (S(1)), row-parallel (S(0)) or
     replicated (B) over the ``('model',)`` mesh axis — and translates the
     chosen kinds into an ordered list of :class:`ShardRule` regex rules.
     The matmul-weight rules are *emitted* by the search, never hard-coded;
     only structurally-replicated leaves (norms, the MoE router) and the
     embedding lookup table carry ``structural:*`` sources.
  2. :func:`tp_param_specs` matches every parameter path against the rules
     (redco-style contiguous-window regex over the flattened path keys) and
     builds a PyTree of ``PartitionSpec``.  Every leaf must match some rule
     — an unmatched path raises, so new param families fail loudly.
  3. ``ServeEngine`` turns the specs into ``NamedSharding``s
     (``sharding.to_named``) and ``jax.device_put``s the params, composing
     with the PR 5 KV-head sharding under the same mesh.

Execution has two modes, switched by the ``REPRO_TP_REDUCE_SCATTER`` knob
via the trace-time state set by :func:`set_serve_tp`:

  * knob **off** (default): weights are *stored* sharded (1/n per-device
    bytes) but :func:`tp_use` constrains each weight to replicated at its
    use site, so XLA all-gathers the weight and the arithmetic is exactly
    the single-device computation — decode output is **bitwise identical**.
  * knob **on**: :func:`tp_use` is a passthrough, so compute follows the
    stored layout — column-parallel in-projections need no collective and
    the row-parallel output projections produce partial sums that XLA
    reduces with **one all-reduce per layer**.  This halves weight traffic
    but reorders the reduction, so outputs match within fp32 tolerance
    rather than bitwise.

Like ``attention.set_serve_mesh``, the state here is trace-time only: the
engine sets it around its jitted prefill/decode wrappers and resets it in
a ``finally``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class ShardRule:
    """One partition rule: ``patterns`` is a sequence of regexes matched
    against a *contiguous window* of the parameter's path keys (so
    ``("attn", "w[qkv]")`` matches ``layers/3/attn/wq`` but not
    ``layers/3/moe/shared/wq``); ``trailing`` gives the mesh-axis entries
    for the trailing tensor dims (leading stack/expert dims are always
    unsharded); ``source`` records provenance (``sbp:<kind>`` = emitted by
    the cost model, ``structural:*`` = trivially replicated/derived)."""
    name: str
    patterns: Tuple[str, ...]
    trailing: Tuple[Optional[str], ...]
    source: str


def _path_keys(path) -> Tuple[str, ...]:
    keys = []
    for entry in path:
        if hasattr(entry, "key"):
            keys.append(str(entry.key))
        elif hasattr(entry, "idx"):
            keys.append(str(entry.idx))
        elif hasattr(entry, "name"):
            keys.append(str(entry.name))
        else:
            keys.append(str(entry))
    return tuple(keys)


def _match(patterns: Tuple[str, ...], keys: Tuple[str, ...]) -> bool:
    m = len(patterns)
    if m == 0 or m > len(keys):
        return False
    for start in range(len(keys) - m + 1):
        if all(re.fullmatch(p, k)
               for p, k in zip(patterns, keys[start:start + m])):
            return True
    return False


def _trail(kind: str) -> Tuple[Optional[str], ...]:
    """WeightChoice.kind for a 2-D (in, out) weight -> trailing spec."""
    if kind == "column":
        return (None, "model")
    if kind == "row":
        return ("model", None)
    return ()


def _trailing_spec(shape: Tuple[int, ...],
                   trailing: Tuple[Optional[str], ...],
                   n_model: int) -> PartitionSpec:
    ndim = len(shape)
    entries: List[Optional[str]] = [None] * ndim
    off = ndim - len(trailing)
    if off >= 0:
        for i, ax in enumerate(trailing):
            if ax is not None and shape[off + i] % n_model == 0:
                entries[off + i] = ax
    return PartitionSpec(*entries)


def choose_tp_rules(cfg, n_model: int) -> List[ShardRule]:
    """Emit the ordered partition-rule list for ``cfg`` over ``n_model``
    model-axis devices, with the matmul layouts chosen by Auto
    Distribution's SBP cost model (canonically: column qkv/up/gate, row
    wo/down — one collective per layer)."""
    from repro.core.distribution import choose_tp_layout

    d_ff = cfg.moe.d_ff_expert if cfg.moe is not None else cfg.d_ff
    plan = choose_tp_layout(d_model=cfg.d_model, q_dim=cfg.q_dim,
                            d_ff=d_ff, vocab=cfg.vocab, n_model=n_model)
    qkv = plan.choices["wq"]
    attn_out = plan.choices["wo"]
    mlp_in = plan.choices["wi"]
    mlp_out = plan.choices["wdown"]
    head = plan.choices["wu"]

    rules = [
        ShardRule("attn_qkv", ("attn", "w[qkv]"),
                  _trail(qkv.kind), f"sbp:{qkv.kind}"),
        ShardRule("attn_out", ("attn", "wo"),
                  _trail(attn_out.kind), f"sbp:{attn_out.kind}"),
        ShardRule("mlp_in", ("mlp|shared", "wi(_gate|_up)?"),
                  _trail(mlp_in.kind), f"sbp:{mlp_in.kind}"),
        ShardRule("mlp_out", ("mlp|shared", "wo"),
                  _trail(mlp_out.kind), f"sbp:{mlp_out.kind}"),
        ShardRule("moe_expert_in", ("moe", "wi(_gate|_up)?"),
                  _trail(mlp_in.kind), f"sbp:{mlp_in.kind}"),
        ShardRule("moe_expert_out", ("moe", "wo"),
                  _trail(mlp_out.kind), f"sbp:{mlp_out.kind}"),
        ShardRule("moe_router", ("moe", "router"),
                  (), "structural:replicated"),
    ]
    if cfg.tie_embeddings:
        # the (vocab, d) table doubles as the unembed matmul weight; the
        # head choice on the logical (d, vocab) weight maps transposed
        tied = {"column": ("model", None), "row": (None, "model")}
        rules.append(ShardRule("embed_tied", ("embed", "embed"),
                               tied.get(head.kind, ()), f"sbp:{head.kind}"))
    else:
        rules.append(ShardRule("lm_head", ("embed", "unembed"),
                               _trail(head.kind), f"sbp:{head.kind}"))
        # shard the lookup table on vocab iff the head sharded at all —
        # vocab-parallel embedding, derived from (not chosen by) the search
        rules.append(ShardRule("embed_table", ("embed", "embed"),
                               ("model", None) if head.kind != "replicated"
                               else (), "structural:vocab"))
    rules.append(ShardRule("replicated_rest", (".*",),
                           (), "structural:replicated"))
    return rules


def tp_param_specs(cfg, params, n_model: int,
                   rules: Optional[List[ShardRule]] = None):
    """Match every param path against the rules; returns ``(spec_tree,
    report)`` where ``report`` maps ``"a/b/c"`` path strings to the
    :class:`ShardRule` that claimed them.  Raises ``ValueError`` if any
    leaf goes unmatched (the catch-all makes that impossible for the
    default rule set, but custom rule lists must stay total)."""
    if rules is None:
        rules = choose_tp_rules(cfg, n_model)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    report: Dict[str, ShardRule] = {}
    for path, leaf in leaves:
        keys = _path_keys(path)
        for rule in rules:
            if _match(rule.patterns, keys):
                specs.append(_trailing_spec(leaf.shape, rule.trailing,
                                            n_model))
                report["/".join(keys)] = rule
                break
        else:
            raise ValueError(
                f"no sharding rule matched param {'/'.join(keys)}")
    return jax.tree_util.tree_unflatten(treedef, specs), report


def validate_tp_divisibility(cfg, n_model: int) -> None:
    """Fail fast at engine construction when ``cfg`` can't tensor-parallel
    over ``n_model`` devices (the rule matcher would silently degrade the
    offending leaves to replicated, which defeats the point of TP)."""
    if n_model <= 1:
        return
    d_ff = cfg.moe.d_ff_expert if cfg.moe is not None else cfg.d_ff
    problems = []
    if cfg.n_heads % n_model:
        problems.append(f"n_heads={cfg.n_heads}")
    if cfg.n_kv_heads % n_model:
        problems.append(f"n_kv_heads={cfg.n_kv_heads}")
    if d_ff % n_model:
        problems.append(f"d_ff={d_ff}")
    if problems:
        raise ValueError(
            f"config {cfg.name!r} cannot shard over model axis of "
            f"{n_model}: {', '.join(problems)} not divisible")


def param_bytes_total(params) -> int:
    """Logical (replicated-equivalent) parameter bytes."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = leaf.dtype.itemsize
        for d in leaf.shape:
            n *= d
        total += n
    return total


def param_bytes_per_device(params) -> int:
    """Bytes one device actually stores: sums each leaf's addressable-shard
    size (falls back to full size for unsharded/host leaves).  The
    ``bench_serve --tp`` lane reports this next to the replicated total."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params):
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(sharding, "shard_shape"):
            shape = sharding.shard_shape(leaf.shape)
        else:
            shape = leaf.shape
        n = leaf.dtype.itemsize
        for d in shape:
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# Trace-time serve state (mirrors attention.set_serve_mesh)
# ---------------------------------------------------------------------------

_SERVE_TP = {"mesh": None, "reduce_scatter": False}


def set_serve_tp(mesh: Optional[Mesh], reduce_scatter: bool = False) -> None:
    """Engine-only hook: arm (or disarm, with None) weight-TP tracing for
    the serve jits.  Must be reset in a ``finally`` like the paged plan."""
    _SERVE_TP["mesh"] = mesh
    _SERVE_TP["reduce_scatter"] = bool(reduce_scatter)


def serve_tp_active() -> bool:
    return _SERVE_TP["mesh"] is not None


def serve_tp_reduce_scatter() -> bool:
    return _SERVE_TP["mesh"] is not None and _SERVE_TP["reduce_scatter"]


def tp_use(w):
    """Use-site hook for every weight on the serve path.

    Identity mode (knob off): constrain to replicated so XLA all-gathers
    the stored shard and compute is bitwise single-device.  Reduce-scatter
    mode: passthrough — compute follows the stored column/row layout and
    the output projections' partial sums cost one all-reduce per layer."""
    mesh = _SERVE_TP["mesh"]
    if mesh is None or _SERVE_TP["reduce_scatter"]:
        return w
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, PartitionSpec()))


def tp_hidden(h):
    """Pin the MLP hidden activation to the ff-sharded layout in
    reduce-scatter mode (no-op otherwise) so the down-projection consumes
    the column-parallel output in place instead of gathering it."""
    mesh = _SERVE_TP["mesh"]
    if mesh is None or not _SERVE_TP["reduce_scatter"]:
        return h
    n = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    if n <= 1 or h.shape[-1] % n:
        return h
    spec = PartitionSpec(*([None] * (h.ndim - 1) + ["model"]))
    return jax.lax.with_sharding_constraint(h, NamedSharding(mesh, spec))
