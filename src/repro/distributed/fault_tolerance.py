"""Fault tolerance + elasticity for the training runtime.

* ``FaultTolerantLoop`` — catches step failures, restores the latest atomic
  checkpoint, and replays from there (checkpoint/restart).
* ``StragglerDetector`` — EWMA of step durations; flags steps slower than
  ``threshold x`` the running median.  On repeated stragglers the loop calls
  the elastic hook.
* ``elastic_remesh`` — rebuilds a smaller mesh after losing hosts (shrink the
  data axis), letting the caller re-lower the step function: train state is
  resharded by jax.device_put onto the new mesh.  At 1000+ nodes this is the
  drain-and-resume path: the checkpoint is the source of truth, and because
  batches are keyed by (seed, step) the data pipeline replays exactly.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Callable, List, Optional

import jax


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration: float
    median: float


class StragglerDetector:
    def __init__(self, threshold: float = 3.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.durations: List[float] = []
        self.events: List[StragglerEvent] = []

    def record(self, step: int, duration: float) -> bool:
        self.durations.append(duration)
        if len(self.durations) > self.window:
            self.durations.pop(0)
        if len(self.durations) >= 5:
            med = statistics.median(self.durations)
            if duration > self.threshold * med:
                self.events.append(StragglerEvent(step, duration, med))
                return True
        return False


def elastic_remesh(current_mesh, lost_hosts: int = 1):
    """Build the largest valid mesh after losing `lost_hosts` along the data
    axis (model axis is preserved: weights shards must survive)."""
    import numpy as np
    shape = dict(current_mesh.shape)
    axes = tuple(shape.keys())
    data_ax = "data" if "data" in shape else axes[0]
    new_data = shape[data_ax] - lost_hosts
    while new_data > 0:
        try:
            sizes = tuple(new_data if a == data_ax else shape[a] for a in axes)
            n = int(np.prod(sizes))
            devices = jax.devices()[:n]
            if len(devices) < n:
                raise ValueError("not enough devices")
            return jax.make_mesh(sizes, axes, devices=devices)
        except ValueError:
            new_data -= 1
    raise RuntimeError("no viable mesh after failures")


class FaultTolerantLoop:
    """Wraps (step_fn, save_fn, restore_fn) with retry-from-checkpoint."""

    def __init__(self, step_fn: Callable, save_fn: Callable,
                 restore_fn: Callable, max_retries: int = 3,
                 straggler_threshold: float = 3.0,
                 on_straggler: Optional[Callable] = None):
        self.step_fn = step_fn
        self.save_fn = save_fn
        self.restore_fn = restore_fn
        self.max_retries = max_retries
        self.detector = StragglerDetector(straggler_threshold)
        self.on_straggler = on_straggler
        self.failures = 0
        self.restores = 0

    def run(self, state, start_step: int, n_steps: int,
            checkpoint_every: int = 50, batch_fn: Callable = None):
        step = start_step
        retries = 0
        while step < start_step + n_steps:
            batch = batch_fn(step) if batch_fn else None
            t0 = time.monotonic()
            try:
                state = self.step_fn(state, step, batch)
                retries = 0
            except Exception:
                self.failures += 1
                retries += 1
                if retries > self.max_retries:
                    raise
                restored = self.restore_fn(state)
                if restored is not None:
                    state, step = restored
                    self.restores += 1
                continue
            dt = time.monotonic() - t0
            if self.detector.record(step, dt) and self.on_straggler:
                self.on_straggler(step, dt)
            step += 1
            if step % checkpoint_every == 0:
                self.save_fn(state, step)
        return state, step
