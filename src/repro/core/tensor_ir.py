"""A small tensor IR that the e-graph engine rewrites over.

Terms are immutable, hash-consed trees.  Ops mirror the subset of the paper's
IR needed by the three passes:

  input(name, shape, dtype)         leaf tensors
  transpose(x; perm)                Table 1 rules
  unary(x; kind)                    exp / silu / relu2 / neg ...
  binary(x, y; kind)                add / mul / sub ...
  matmul(x, y)                      2-D (M,K)x(K,N)
  pack(x; lanes, axes)              Auto Vectorize blocked layouts
  unpack(x; axes)                   inverse of pack
  packed_matmul / packed_unary ...  hardware-unit variants (§3.1.2)
  box(x; sbp)                       Auto Distribution boxing (§3.1.3)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

Shape = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class Term:
    op: str
    children: Tuple["Term", ...] = ()
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def attr(self, key, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def __repr__(self):
        a = ", ".join(f"{k}={v}" for k, v in self.attrs)
        c = ", ".join(repr(ch) for ch in self.children)
        inner = ", ".join(x for x in (c, a) if x)
        return f"{self.op}({inner})"


def T(op: str, *children: Term, **attrs) -> Term:
    return Term(op, tuple(children), tuple(sorted(attrs.items())))


def inp(name: str, shape: Shape, dtype: str = "bf16") -> Term:
    return T("input", name=name, shape=tuple(shape), dtype=dtype)


def transpose(x: Term, perm: Tuple[int, ...]) -> Term:
    return T("transpose", x, perm=tuple(perm))


def unary(x: Term, kind: str) -> Term:
    return T("unary", x, kind=kind)


def binary(x: Term, y: Term, kind: str) -> Term:
    return T("binary", x, y, kind=kind)


def matmul(x: Term, y: Term) -> Term:
    return T("matmul", x, y)


def pack(x: Term, lanes: Tuple[int, ...], axes: Tuple[int, ...]) -> Term:
    return T("pack", x, lanes=tuple(lanes), axes=tuple(axes))


def unpack(x: Term, lanes: Tuple[int, ...], axes: Tuple[int, ...]) -> Term:
    return T("unpack", x, lanes=tuple(lanes), axes=tuple(axes))


def compose_perms(p1: Tuple[int, ...], p2: Tuple[int, ...]) -> Tuple[int, ...]:
    """transpose(transpose(A, p1), p2) == transpose(A, compose_perms(p1, p2))."""
    return tuple(p1[p2[i]] for i in range(len(p2)))


def invert_perm(p: Tuple[int, ...]) -> Tuple[int, ...]:
    out = [0] * len(p)
    for i, v in enumerate(p):
        out[v] = i
    return tuple(out)


DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "int8": 1}


def infer_shape(op: str, child_shapes: Tuple[Shape, ...],
                attrs: Dict[str, Any]) -> Shape:
    if op == "input":
        return tuple(attrs["shape"])
    if op == "transpose":
        (s,) = child_shapes
        perm = attrs["perm"]
        return tuple(s[p] for p in perm)
    if op in ("unary", "packed_unary"):
        return child_shapes[0]
    if op in ("binary", "packed_binary"):
        a, b = child_shapes
        if a != b:
            raise ValueError(f"binary shape mismatch {a} vs {b}")
        return a
    if op in ("matmul", "packed_matmul"):
        a, b = child_shapes
        if a[-1] != b[-2 if len(b) >= 2 else 0]:
            raise ValueError(f"matmul dim mismatch {a} x {b}")
        return tuple(a[:-1]) + (b[-1],)
    if op == "pack":
        (s,) = child_shapes
        lanes, axes = attrs["lanes"], attrs["axes"]
        out = list(s)
        for lane, ax in zip(lanes, axes):
            if out[ax] % lane != 0:
                raise ValueError(f"pack lane {lane} on dim {out[ax]}")
            out[ax] //= lane
        return tuple(out)  # lanes become the (implicit) register dims
    if op == "unpack":
        (s,) = child_shapes
        lanes, axes = attrs["lanes"], attrs["axes"]
        out = list(s)
        for lane, ax in zip(lanes, axes):
            out[ax] *= lane
        return tuple(out)
    if op == "box":
        return child_shapes[0]
    raise ValueError(f"unknown op {op}")


def term_shape(t: Term, cache: Optional[dict] = None) -> Shape:
    cache = cache if cache is not None else {}
    if t in cache:
        return cache[t]
    child_shapes = tuple(term_shape(c, cache) for c in t.children)
    s = infer_shape(t.op, child_shapes, dict(t.attrs))
    cache[t] = s
    return s
