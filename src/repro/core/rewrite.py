"""Rewrite rules (Table 1: transpose optimization) + the rule protocol.

A Rule inspects one (e-class, e-node) pair and yields MixedTerms (children may
reference existing e-classes by id) that are equal to that e-class.  Rules are
non-destructive: the saturation driver adds the new term and unions it with
the matched class.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.core.egraph import EGraph, ENode, M, MixedTerm
from repro.core.tensor_ir import compose_perms, invert_perm


class Rule:
    name = "rule"

    def apply(self, eg: EGraph, cid: int, node: ENode) -> Iterable[MixedTerm]:
        raise NotImplementedError


def _transpose_nodes(eg: EGraph, cid: int):
    """Yield transpose e-nodes within class `cid`."""
    for n in eg.nodes(cid):
        if n.op == "transpose":
            yield n


class CombineBinaryLeftTrans(Rule):
    """Binary(T_p(A), B) -> T_p(Binary(A, T_p^-1(B)))."""
    name = "combine-binary-left-trans"

    def apply(self, eg, cid, node):
        if node.op != "binary":
            return
        lhs, rhs = node.children
        kind = node.attr("kind")
        for tn in _transpose_nodes(eg, lhs):
            perm = tn.attr("perm")
            inv = invert_perm(perm)
            yield M("transpose",
                    M("binary", tn.children[0],
                      M("transpose", rhs, perm=inv), kind=kind),
                    perm=perm)


class CombineBinaryRightTrans(Rule):
    """Binary(A, T_p(B)) -> T_p(Binary(T_p^-1(A), B))."""
    name = "combine-binary-right-trans"

    def apply(self, eg, cid, node):
        if node.op != "binary":
            return
        lhs, rhs = node.children
        kind = node.attr("kind")
        for tn in _transpose_nodes(eg, rhs):
            perm = tn.attr("perm")
            inv = invert_perm(perm)
            yield M("transpose",
                    M("binary", M("transpose", lhs, perm=inv),
                      tn.children[0], kind=kind),
                    perm=perm)


class CombineUnaryTrans(Rule):
    """Unary(T_p(A)) -> T_p(Unary(A))."""
    name = "combine-unary-trans"

    def apply(self, eg, cid, node):
        if node.op != "unary":
            return
        kind = node.attr("kind")
        for tn in _transpose_nodes(eg, node.children[0]):
            yield M("transpose",
                    M("unary", tn.children[0], kind=kind),
                    perm=tn.attr("perm"))


class FoldTwoTrans(Rule):
    """T_p2(T_p1(A)) -> T_{p1∘p2}(A)."""
    name = "fold-two-trans"

    def apply(self, eg, cid, node):
        if node.op != "transpose":
            return
        p2 = node.attr("perm")
        for tn in _transpose_nodes(eg, node.children[0]):
            p1 = tn.attr("perm")
            yield M("transpose", tn.children[0], perm=compose_perms(p1, p2))


class FoldNopTrans(Rule):
    """T_{0,1,...,n}(A) -> A.  Yields the child e-class id directly, which the
    saturation driver interprets as "union this class with that one"."""
    name = "fold-nop-trans"

    def apply(self, eg, cid, node):
        if node.op != "transpose":
            return
        perm = node.attr("perm")
        if perm == tuple(range(len(perm))):
            yield node.children[0]


TRANSPOSE_RULES: List[Rule] = [
    CombineBinaryLeftTrans(), CombineBinaryRightTrans(),
    CombineUnaryTrans(), FoldTwoTrans(), FoldNopTrans(),
]
