"""Roofline cost model (§3.1.1) + alpha-beta communication model (§3.1.3).

Costs are abstract per-op latencies in seconds on the TPU v5e hardware model;
the e-graph extractor minimizes their sum.  Packed ops run on the matching
compute unit (MXU for packed_matmul, VPU for packed element-wise) at higher
efficiency than their unpacked forms — that asymmetry is what drives the
Auto Vectorize trade-off (§3.1.2).
"""
from __future__ import annotations

from typing import Tuple

from repro.core.egraph import EGraph, ENode

PEAK_FLOPS = 197e12        # MXU bf16
VPU_FLOPS = 197e12 / 16    # vector unit, rough 1/16 of MXU
SCALAR_FLOPS = VPU_FLOPS / 8
HBM_BW = 819e9
ICI_BW = 50e9
ALPHA = 1e-6               # per-collective latency

# efficiency of unpacked (hardware-unfriendly layout) execution
UNPACKED_MXU_EFF = 0.15    # unaligned matmul barely uses the MXU
UNPACKED_VPU_EFF = 0.4


def _elems(shape: Tuple[int, ...]) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


def node_cost(eg: EGraph, node: ENode, dtype_bytes: int = 2) -> float:
    """Roofline latency of one e-node given its children's shapes."""
    out_shape = None
    try:
        child_shapes = tuple(eg.shape(c) for c in node.children)
    except KeyError:
        child_shapes = ()
    op = node.op

    if op == "input":
        return 0.0
    if op == "box":
        return boxing_cost(node, eg)

    from repro.core.tensor_ir import infer_shape
    out_shape = infer_shape(op, child_shapes, dict(node.attrs))
    out_b = _elems(out_shape) * dtype_bytes
    in_b = sum(_elems(s) for s in child_shapes) * dtype_bytes

    if op in ("matmul", "packed_matmul"):
        k = child_shapes[0][-1]
        flops = 2 * _elems(out_shape) * k
        eff = 1.0 if op == "packed_matmul" else UNPACKED_MXU_EFF
        return max(flops / (PEAK_FLOPS * eff), (in_b + out_b) / HBM_BW)
    if op in ("unary", "packed_unary", "binary", "packed_binary"):
        flops = _elems(out_shape) * (4 if "unary" in op else 1)
        eff = 1.0 if op.startswith("packed") else UNPACKED_VPU_EFF
        return max(flops / (VPU_FLOPS * eff), (in_b + out_b) / HBM_BW)
    if op == "transpose":
        # layout permutation: pure data movement, poorly coalesced
        return (in_b + out_b) / (HBM_BW * 0.5)
    if op in ("pack", "unpack"):
        # layout conversion: streaming copy
        return (in_b + out_b) / HBM_BW
    return out_b / HBM_BW


def boxing_cost(node: ENode, eg: EGraph, dtype_bytes: int = 2) -> float:
    """Alpha-beta cost of an SBP Boxing op (attrs carry the transfer kind)."""
    kind = node.attr("comm", "none")
    group = node.attr("group", 1)
    shape = eg.shape(node.children[0]) if node.children else ()
    nbytes = _elems(shape) * dtype_bytes
    if kind == "none" or group <= 1:
        return 0.0
    frac = (group - 1) / group
    factor = {"all-gather": frac, "reduce-scatter": frac,
              "all-reduce": 2 * frac, "all-to-all": frac,
              "split": 0.0, "p2p": 1.0}.get(kind, frac)
    return ALPHA + factor * nbytes / ICI_BW


def collective_bytes(kind: str, nbytes: int, group: int) -> float:
    frac = (group - 1) / max(1, group)
    factor = {"all-gather": frac, "reduce-scatter": frac,
              "all-reduce": 2 * frac, "all-to-all": frac}.get(kind, frac)
    return factor * nbytes
