"""Codegen (§3.3): extracted/scheduled plans -> executable JAX callables.

The paper emits C++ instantiating NTT μkernels; on TPU the "backend compiler"
is XLA and the μkernels are Pallas kernels, so codegen here means:

  * ``compile_term``  — walk an extracted Term (possibly packed) and build a
    jit-able python callable over named inputs.  Packed ops either run
    through the layout-faithful jnp interpretation (reshape to blocked form)
    or dispatch to the Pallas kernels (``use_pallas=True``, TPU/interpret).
  * ``kernel_plan``   — convert an Auto Schedule result into concrete Pallas
    BlockSpec tile sizes (the VMEM-level tiles chosen by the MINLP).
  * buffer planning   — ``repro.core.buffer_schedule`` supplies the offsets;
    XLA owns real allocation, so the plan is used for the §Dry-run memory
    report and for VMEM scratch budgeting inside kernels.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.schedule.minlp import Schedule
from repro.core.tensor_ir import Term


def _pack_array(x, lanes, axes):
    """Blocked layout: dims (.., d*lane, ..) -> (.., d, .., lane0, lane1)."""
    shape = list(x.shape)
    new_shape = []
    lane_dims = []
    for i, d in enumerate(shape):
        if i in axes:
            lane = lanes[axes.index(i)]
            new_shape.extend([d // lane, lane])
            lane_dims.append(len(new_shape) - 1)
        else:
            new_shape.append(d)
    y = x.reshape(new_shape)
    outer = [i for i in range(len(new_shape)) if i not in lane_dims]
    return y.transpose(outer + lane_dims)


def _unpack_array(x, lanes, axes, n_logical):
    nl = n_logical
    outer = list(x.shape[:nl])
    y = x
    # move lane dims back next to their outer dims
    for j, ax in enumerate(sorted(axes)):
        lane_dim = nl + j
        perm = list(range(y.ndim))
        perm.remove(lane_dim)
        perm.insert(ax + 1 + j, lane_dim)
        y = y.transpose(perm)
    shape = []
    i = 0
    dims = list(y.shape)
    k = 0
    while i < len(dims):
        if k in axes:
            shape.append(dims[i] * dims[i + 1])
            i += 2
        else:
            shape.append(dims[i])
            i += 1
        k += 1
    return y.reshape(shape)


_UNARY = {
    "exp": jnp.exp, "silu": jax.nn.silu, "relu": jax.nn.relu,
    "neg": jnp.negative, "gelu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
}
_BINARY = {"add": jnp.add, "mul": jnp.multiply, "sub": jnp.subtract}


def compile_term(term: Term, use_pallas: bool = False) -> Callable:
    """Returns f(**inputs) evaluating the term.  Packed ops use blocked-layout
    jnp (reference semantics) or Pallas kernels when requested."""

    def ev(t: Term, env, cache):
        if t in cache:
            return cache[t]
        ch = [ev(c, env, cache) for c in t.children]
        op = t.op
        if op == "input":
            r = env[t.attr("name")]
        elif op == "matmul":
            if use_pallas:
                from repro.kernels import ops as kops
                r = kops.matmul(ch[0], ch[1])
            else:
                r = ch[0] @ ch[1]
        elif op == "packed_matmul":
            # children are blocked (M', K', lm, lk) x (K', N', lk, ln)
            r = jnp.einsum("mkab,knbc->mnac", ch[0], ch[1])
        elif op == "unary":
            r = _UNARY[t.attr("kind")](ch[0])
        elif op == "packed_unary":
            r = _UNARY[t.attr("kind")](ch[0])
        elif op in ("binary", "packed_binary"):
            r = _BINARY[t.attr("kind")](ch[0], ch[1])
        elif op == "transpose":
            r = ch[0].transpose(t.attr("perm"))
        elif op == "pack":
            r = _pack_array(ch[0], t.attr("lanes"), t.attr("axes"))
        elif op == "unpack":
            from repro.core.tensor_ir import term_shape
            n_logical = len(term_shape(t))
            r = _unpack_array(ch[0], t.attr("lanes"), t.attr("axes"), n_logical)
        else:
            raise ValueError(f"codegen: unknown op {op}")
        cache[t] = r
        return r

    def fn(**inputs):
        return ev(term, inputs, {})

    return fn


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Concrete Pallas tile sizes derived from an Auto Schedule result."""
    block_m: int = 256
    block_n: int = 256
    block_k: int = 512
    block_q: int = 512      # flash attention q tile
    block_kv: int = 1024    # flash attention kv tile
    # paged attention streams whole KV pages, so its kv tile is aligned to
    # the page granularity (8) rather than the lane width — the MINLP's kv
    # tile choice survives at page resolution instead of collapsing to 128
    paged_block_kv: int = 512
    # segmented LoRA (gather-BGMV): output-dim tile per grid step and the
    # rank-slot granularity the adapter slab is padded to.  Rank aligns to
    # the sublane width (8) like the page tile, not the lane width — typical
    # LoRA ranks (8/16/32) would all collapse to one 128 tile otherwise
    lora_block_out: int = 256
    lora_block_rank: int = 16


def kernel_plan(schedule: Schedule, group: int = 0) -> KernelPlan:
    """Map MINLP tiles to BlockSpec sizes (dims aligned down to 128/8)."""
    tiles = schedule.tiles.get(group, {})

    def pick(name, default, align=128):
        v = tiles.get(name, default)
        v = max(align, (v // align) * align)
        return v

    return KernelPlan(
        block_m=pick("i", 256),
        block_n=pick("j", 256),
        block_k=pick("k", 512),
        block_q=pick("i", 512),
        block_kv=pick("l", 1024),
        paged_block_kv=pick("l", 512, align=8),
        lora_block_out=pick("j", 256),
        lora_block_rank=pick("k", 16, align=8),
    )


def paged_pages_per_fetch(plan: KernelPlan, block_size: int,
                          max_blocks_per_seq: int) -> int:
    """Map the schedule's kv-span tile (``paged_block_kv`` tokens) to whole
    KV pages fetched per paged-attention grid step.  This is how the serve
    engine turns the compiler's tiling decision into the kernel's streaming
    granularity instead of hand-picking a constant."""
    if block_size <= 0:
        return 1
    pages = max(1, plan.paged_block_kv // block_size)
    return max(1, min(pages, max_blocks_per_seq))


def lora_tiles(plan: KernelPlan, out_dim: int, max_rank: int
               ) -> "tuple[int, int]":
    """Map the schedule's tiles to the segmented-LoRA kernel's granularity:
    ``(block_out, rank_pad)``.  ``block_out`` is the output-feature tile one
    expand grid step covers (never wider than the projection itself);
    ``rank_pad`` is the rank-slot size adapter slabs are padded to, so a mix
    of ranks shares one slab shape and the MINLP's contraction tile choice
    survives at sublane resolution.  This is how the serve engine turns the
    compiler's tiling decision into the LoRA kernel's shape instead of
    hand-picking constants (the paged-attention analogue is
    ``paged_pages_per_fetch``)."""
    block_out = max(1, min(plan.lora_block_out, out_dim))
    rank_pad = max(8, ((max_rank + plan.lora_block_rank - 1)
                       // plan.lora_block_rank) * plan.lora_block_rank)
    return block_out, rank_pad
