"""DPLL SAT + branch-and-bound Weighted Partial MaxSAT (§3.1.1 extraction).

Self-contained (the paper uses an external SAT solver via OR-Tools; we keep
the whole pipeline in-repo).  Variables are 1-based ints; literals are signed
ints.  Hard clauses must all be satisfied; soft clauses are unit literals with
weights — the solver minimizes the total weight of *violated* soft clauses.

Scale target: e-graphs of a few thousand e-nodes (unit propagation dominates;
the branch-and-bound rarely explores deeply because selection variables are
heavily constrained by the class/child implications).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple


class UNSAT(Exception):
    pass


def _unit_propagate(clauses: List[List[int]], assign: Dict[int, bool]):
    """In-place propagation; returns list of newly assigned vars or raises."""
    trail = []
    changed = True
    while changed:
        changed = False
        for cl in clauses:
            unassigned = None
            n_unassigned = 0
            sat = False
            for lit in cl:
                v, want = abs(lit), lit > 0
                if v in assign:
                    if assign[v] == want:
                        sat = True
                        break
                else:
                    unassigned = lit
                    n_unassigned += 1
            if sat:
                continue
            if n_unassigned == 0:
                raise UNSAT()
            if n_unassigned == 1:
                v, want = abs(unassigned), unassigned > 0
                assign[v] = want
                trail.append(v)
                changed = True
    return trail


def sat_solve(n_vars: int, clauses: Sequence[Sequence[int]],
              assumptions: Sequence[int] = ()) -> Optional[Dict[int, bool]]:
    """Plain DPLL; returns assignment dict or None if UNSAT."""
    clauses = [list(c) for c in clauses]
    assign: Dict[int, bool] = {}
    for lit in assumptions:
        assign[abs(lit)] = lit > 0
    try:
        _unit_propagate(clauses, assign)
    except UNSAT:
        return None

    def rec(assign: Dict[int, bool]) -> Optional[Dict[int, bool]]:
        # pick an unassigned var from the shortest unsatisfied clause
        best_cl, best_len = None, 1 << 30
        for cl in clauses:
            sat, free = False, []
            for lit in cl:
                v = abs(lit)
                if v in assign:
                    if assign[v] == (lit > 0):
                        sat = True
                        break
                else:
                    free.append(lit)
            if not sat and free and len(free) < best_len:
                best_cl, best_len = free, len(free)
        if best_cl is None:
            return assign
        lit = best_cl[0]
        for val in (lit > 0, lit < 0):
            a2 = dict(assign)
            a2[abs(lit)] = val
            try:
                _unit_propagate(clauses, a2)
            except UNSAT:
                continue
            r = rec(a2)
            if r is not None:
                return r
        return None

    return rec(assign)


@dataclasses.dataclass
class WPMaxSATResult:
    assignment: Dict[int, bool]
    cost: float
    optimal: bool = True


def wpmaxsat(n_vars: int, hard: Sequence[Sequence[int]],
             soft: Sequence[Tuple[int, float]],
             time_budget_nodes: int = 200000,
             ub_init: Optional[float] = None,
             lb_extra=None) -> Optional[WPMaxSATResult]:
    """Branch & bound weighted partial MaxSAT.

    `soft` is a list of (literal, weight): satisfying the literal is free,
    violating costs `weight`.  Returns the minimum-cost assignment found
    (optimal=False if the node budget was exhausted first).

    ub_init: known upper bound (e.g. a greedy solution's cost) — branches
    costing >= it are pruned even before any solution is found here.
    lb_extra(assign) -> float: admissible extra lower bound added to the
    violated-soft cost (problem-structure aware, e.g. min cost-to-go).
    """
    hard = [list(c) for c in hard]
    soft_by_var: Dict[int, List[Tuple[int, float]]] = {}
    for lit, w in soft:
        soft_by_var.setdefault(abs(lit), []).append((lit, w))

    best: List[Optional[WPMaxSATResult]] = [None]
    bound: List[float] = [float("inf") if ub_init is None else ub_init]
    nodes_visited = [0]
    truncated = [False]

    def soft_cost(assign: Dict[int, bool]) -> float:
        c = 0.0
        for v, entries in soft_by_var.items():
            if v in assign:
                for lit, w in entries:
                    if assign[v] != (lit > 0):
                        c += w
        return c

    def soft_weight_if_true(v: int) -> float:
        w = 0.0
        for lit, wt in soft_by_var.get(v, ()):
            if lit < 0:
                w += wt
        return w

    def rec(assign: Dict[int, bool]):
        nodes_visited[0] += 1
        if nodes_visited[0] > time_budget_nodes:
            truncated[0] = True
            return
        lb = soft_cost(assign)
        if lb_extra is not None:
            lb += lb_extra(assign)
        if lb >= bound[0] - 1e-15:
            return
        # find branching clause (shortest unsatisfied hard clause first)
        best_cl, best_len = None, 1 << 30
        for cl in hard:
            sat, free = False, []
            for lit in cl:
                v = abs(lit)
                if v in assign:
                    if assign[v] == (lit > 0):
                        sat = True
                        break
                else:
                    free.append(lit)
            if not sat:
                if not free:
                    return  # violated hard clause
                if len(free) < best_len:
                    best_cl, best_len = free, len(free)
        if best_cl is None:
            # all hard satisfied: assign remaining soft vars to their free value
            final = dict(assign)
            for v, entries in soft_by_var.items():
                if v not in final:
                    # choose value that violates nothing
                    lit, _ = entries[0]
                    final[v] = lit > 0
            cost = soft_cost(final)
            if best[0] is None or cost < best[0].cost:
                best[0] = WPMaxSATResult(final, cost)
                bound[0] = min(bound[0], cost)
            return
        # branch on the literal that satisfies the clause at minimum soft
        # cost, SATISFYING polarity first — finds a full solution fast, after
        # which bound pruning takes over.
        lit = min(best_cl,
                  key=lambda l: soft_weight_if_true(abs(l)) if l > 0 else 0.0)
        v = abs(lit)
        for val in (lit > 0, lit < 0):
            a2 = dict(assign)
            a2[v] = val
            try:
                _unit_propagate(hard, a2)
            except UNSAT:
                continue
            rec(a2)

    a0: Dict[int, bool] = {}
    try:
        _unit_propagate(hard, a0)
    except UNSAT:
        return None
    rec(a0)
    if best[0] is not None:
        best[0].optimal = not truncated[0]
    return best[0]
