"""Auto Distribution (§3.1.3): SBP strategy search embedded in the e-graph.

Implements the BuildEGraph algorithm of Fig. 5:

  1. *Input phase*: every graph input gets one Boxing e-node per feasible
     ND-SBP (host -> device split is free).
  2. *Compute phase*: topological walk; for each op, the Cartesian product of
     its inputs' available SBP classes (plus explicit *Resharding Boxing*
     candidates) is filtered through the op's SBP signature; resulting nodes
     with identical output SBP are unioned into one e-class ("same logic +
     same SBP => equivalent").  The per-logical-node dict {ndsbp: eclass} is
     the paper's E-Cluster.
  3. *Output phase*: Unshard Boxing to Broadcast, unioned into a single root.

Extraction = WPMaxSAT with roofline compute costs on *local shard shapes* and
alpha-beta boxing costs, under a hard per-device memory constraint.

The searched logical graphs are 2-D (tokens x features) block graphs — the
paper's Fig. 6 granularity.  ``ndsbp_to_pspec`` bridges the chosen strategy to
``jax.sharding.PartitionSpec``, which is how ``repro.distributed.sharding``'s
policies are derived/validated (see tests).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import HBM_BW, PEAK_FLOPS, VPU_FLOPS
from repro.core.egraph import EGraph, ENode
from repro.core.extraction import greedy_extract, wpmaxsat_extract
from repro.core.sbp import (B, NdSbp, P, Placement, S, boxing_cost,
                            elementwise_axis_signatures, matmul_axis_signatures,
                            memory_bytes, resolve_tag, shard_shape, valid_ndsbps)
from repro.core.tensor_ir import Term


def _tag_of(sbp) -> str:
    if isinstance(sbp, S):
        return f"S{sbp.axis}"
    return "B" if sbp is B else "P"


def _signatures_for(op: str, kind: Optional[str], arity: int):
    if op == "matmul":
        return matmul_axis_signatures()
    linear = kind in ("add", "sub", "neg", None) and op == "binary"
    return elementwise_axis_signatures(arity, linear=linear)


def _apply_signature(op, kind, in_sbps: Tuple[NdSbp, ...], ndim_out: int,
                     pl: Placement) -> Optional[NdSbp]:
    """Per-axis signature check; returns the output ND-SBP or None."""
    sigs = _signatures_for(op, kind, len(in_sbps))
    out = []
    for ax in range(pl.ndim):
        tags = tuple(_tag_of(s[ax]) for s in in_sbps)
        matched = None
        for inputs, result in sigs:
            if inputs == tags:
                matched = result
                break
        if matched is None:
            return None
        r = resolve_tag(matched, ndim_out)
        if r is None:
            return None
        out.append(r)
    return tuple(out)


@dataclasses.dataclass
class DistEGraph:
    eg: EGraph
    root: int
    placement: Placement
    terms: List[Term]
    eclusters: Dict[int, Dict[NdSbp, int]]   # term index -> {ndsbp: eclass}


def build_distributed_egraph(root_term: Term, pl: Placement,
                             max_sbps_per_tensor: int = 24) -> DistEGraph:
    eg = EGraph()
    # collect unique terms in topo order
    topo: List[Term] = []
    seen = {}

    def walk(t: Term):
        if t in seen:
            return
        for c in t.children:
            walk(c)
        seen[t] = len(topo)
        topo.append(t)
    walk(root_term)

    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    for t in topo:
        shape_cache[t] = term_shape(t, shape_cache)

    eclusters: Dict[int, Dict[NdSbp, int]] = {}

    def add_box(src_class: int, tid: int, src: NdSbp, dst: NdSbp,
                shape) -> Optional[int]:
        if boxing_cost(src, dst, shape, pl) is None:
            return None
        node = ENode("box", (src_class,),
                     tuple(sorted({"term_id": tid, "src": src, "sbp": dst,
                                   "comm": "reshard"}.items())))
        return eg.add(node)

    for tid, t in enumerate(topo):
        shape = shape_cache[t]
        cluster: Dict[NdSbp, int] = {}
        if t.op == "input":
            # 1. Input phase: host split boxing, one class per feasible SBP
            for nd in valid_ndsbps(shape, pl)[:max_sbps_per_tensor]:
                leaf = eg.add(ENode("input", (),
                                    t.attrs + (("term_id", tid),)))
                node = ENode("box", (leaf,),
                             tuple(sorted({"term_id": tid, "src": None,
                                           "sbp": nd, "comm": "split"}.items())))
                cluster[nd] = eg.add(node)
        else:
            # 2. Compute phase: reuse + resharding candidates per input
            in_grps: List[List[Tuple[NdSbp, int]]] = []
            for c in t.children:
                cin = eclusters[seen[c]]
                cands: Dict[NdSbp, int] = dict(cin)
                cshape = shape_cache[c]
                targets = valid_ndsbps(cshape, pl,
                                       allow_partial=False)[:max_sbps_per_tensor]
                for dst in targets:
                    if dst in cands:
                        continue
                    # reshard from the (arbitrary) first available source
                    for src, cls in cin.items():
                        bid = add_box(cls, seen[c], src, dst, cshape)
                        if bid is not None:
                            cands[dst] = bid
                            break
                in_grps.append(list(cands.items()))
            kind = t.attr("kind")
            for combo in itertools.product(*in_grps):
                in_sbps = tuple(nd for nd, _ in combo)
                out_sbp = _apply_signature(t.op, kind, in_sbps, len(shape), pl)
                if out_sbp is None:
                    continue
                if shard_shape(shape, out_sbp, pl) is None:
                    continue
                node = ENode(t.op, tuple(cls for _, cls in combo),
                             t.attrs + tuple(sorted(
                                 {"term_id": tid, "sbp": out_sbp}.items())))
                nid = eg.add(node)
                if out_sbp in cluster:
                    cluster[out_sbp] = eg.union(cluster[out_sbp], nid)
                else:
                    cluster[out_sbp] = nid
        eclusters[tid] = cluster

    # 3. Output phase: unshard to full Broadcast
    root_tid = seen[root_term]
    rshape = shape_cache[root_term]
    full_b = tuple(B for _ in range(pl.ndim))
    root_class = None
    for src, cls in eclusters[root_tid].items():
        if src == full_b:
            rid = cls
        else:
            rid = add_box(cls, root_tid, src, full_b, rshape)
        if rid is None:
            continue
        root_class = rid if root_class is None else eg.union(root_class, rid)
    eg.rebuild()
    # re-canonicalize cluster ids
    for tid in eclusters:
        eclusters[tid] = {nd: eg.find(c) for nd, c in eclusters[tid].items()}
    return DistEGraph(eg, eg.find(root_class), pl, topo, eclusters)


# ---------------------------------------------------------------------------
# Costs on shard shapes
# ---------------------------------------------------------------------------

def make_cost_fn(dg: DistEGraph, dtype_bytes: int = 2):
    pl = dg.placement
    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    shapes = [term_shape(t, shape_cache) for t in dg.terms]

    def cost(node: ENode) -> float:
        tid = node.attr("term_id")
        sbp = node.attr("sbp")
        if node.op == "input":
            return 0.0
        shape = shapes[tid]
        if node.op == "box":
            if node.attr("comm") == "split":
                return 0.0
            return boxing_cost(node.attr("src"), sbp, shape, pl,
                               dtype_bytes) or 0.0
        local = shard_shape(shape, sbp, pl)
        if local is None:
            return 1e9
        elems = 1
        for d in local:
            elems *= d
        if node.op == "matmul":
            # contraction dim from child's local shape
            k_local = shape[1]  # fallback
            ch_sbp = None
            for n2 in dg.eg.nodes(node.children[0]):
                ch_sbp = n2.attr("sbp")
                break
            flops = 2 * elems * k_local
            return max(flops / PEAK_FLOPS,
                       3 * elems * dtype_bytes / HBM_BW)
        return max(elems * 4 / VPU_FLOPS, 3 * elems * dtype_bytes / HBM_BW)

    return cost


def make_mem_fn(dg: DistEGraph, dtype_bytes: int = 2):
    pl = dg.placement
    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    shapes = [term_shape(t, shape_cache) for t in dg.terms]

    def mem(node: ENode) -> int:
        tid = node.attr("term_id")
        sbp = node.attr("sbp")
        if node.op == "input" or sbp is None:
            return 0
        return memory_bytes(shapes[tid], sbp, pl, dtype_bytes)

    return mem


@dataclasses.dataclass
class DistributedPlan:
    cost: float
    assignments: Dict[int, NdSbp]        # term index -> chosen ND-SBP
    boxing: List[Tuple[int, NdSbp, NdSbp]]
    peak_memory: int


def auto_distribute(root_term: Term, pl: Placement,
                    mem_capacity: Optional[int] = None,
                    use_sat: bool = True) -> DistributedPlan:
    dg = build_distributed_egraph(root_term, pl)
    cost_fn = make_cost_fn(dg)
    mem_fn = make_mem_fn(dg)
    if mem_capacity is not None:
        # hard per-device memory capacity: the specialized exact B&B prunes
        # over-capacity branches monotonically (see extraction.py)
        from repro.core.extraction import branch_bound_extract
        total, choice = branch_bound_extract(dg.eg, dg.root, cost_fn,
                                             mem_fn=mem_fn, cap=mem_capacity)
    elif use_sat:
        total, choice = wpmaxsat_extract(dg.eg, dg.root, cost_fn)
    else:
        total, choice = greedy_extract(dg.eg, dg.root, cost_fn)
    assignments: Dict[int, NdSbp] = {}
    boxing = []
    peak = 0
    for cid, node in choice.items():
        tid = node.attr("term_id")
        peak += mem_fn(node)
        if node.op == "box":
            if node.attr("comm") == "split":
                # input placement choice = the initial shard boxing target
                assignments[tid] = node.attr("sbp")
            else:
                boxing.append((tid, node.attr("src"), node.attr("sbp")))
        elif node.op != "input":
            assignments[tid] = node.attr("sbp")
    return DistributedPlan(total, assignments, boxing, peak)


def ndsbp_to_pspec(nd: NdSbp, pl: Placement, tensor_ndim: int):
    """Bridge to jax: dim d gets every mesh axis whose SBP is S(d)."""
    from jax.sharding import PartitionSpec
    entries: List[Optional[Tuple[str, ...]]] = [None] * tensor_ndim
    for axis_name, sbp in zip(pl.axes, nd):
        if isinstance(sbp, S):
            cur = entries[sbp.axis] or ()
            entries[sbp.axis] = tuple(cur) + (axis_name,)
    return PartitionSpec(*[e if e is None or len(e) > 1 else e[0]
                           for e in entries])
