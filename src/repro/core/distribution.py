"""Auto Distribution (§3.1.3): SBP strategy search embedded in the e-graph.

Implements the BuildEGraph algorithm of Fig. 5:

  1. *Input phase*: every graph input gets one Boxing e-node per feasible
     ND-SBP (host -> device split is free).
  2. *Compute phase*: topological walk; for each op, the Cartesian product of
     its inputs' available SBP classes (plus explicit *Resharding Boxing*
     candidates) is filtered through the op's SBP signature; resulting nodes
     with identical output SBP are unioned into one e-class ("same logic +
     same SBP => equivalent").  The per-logical-node dict {ndsbp: eclass} is
     the paper's E-Cluster.
  3. *Output phase*: Unshard Boxing to Broadcast, unioned into a single root.

Extraction = WPMaxSAT with roofline compute costs on *local shard shapes* and
alpha-beta boxing costs, under a hard per-device memory constraint.

The searched logical graphs are 2-D (tokens x features) block graphs — the
paper's Fig. 6 granularity.  ``ndsbp_to_pspec`` bridges the chosen strategy to
``jax.sharding.PartitionSpec``, which is how ``repro.distributed.sharding``'s
policies are derived/validated (see tests).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import HBM_BW, PEAK_FLOPS, VPU_FLOPS
from repro.core.egraph import EGraph, ENode
from repro.core.extraction import greedy_extract, wpmaxsat_extract
from repro.core.sbp import (B, NdSbp, P, Placement, S, boxing_cost,
                            elementwise_axis_signatures, matmul_axis_signatures,
                            memory_bytes, resolve_tag, shard_shape, valid_ndsbps)
from repro.core.tensor_ir import Term


def _tag_of(sbp) -> str:
    if isinstance(sbp, S):
        return f"S{sbp.axis}"
    return "B" if sbp is B else "P"


def _signatures_for(op: str, kind: Optional[str], arity: int):
    if op == "matmul":
        return matmul_axis_signatures()
    linear = kind in ("add", "sub", "neg", None) and op == "binary"
    return elementwise_axis_signatures(arity, linear=linear)


def _apply_signature(op, kind, in_sbps: Tuple[NdSbp, ...], ndim_out: int,
                     pl: Placement) -> Optional[NdSbp]:
    """Per-axis signature check; returns the output ND-SBP or None."""
    sigs = _signatures_for(op, kind, len(in_sbps))
    out = []
    for ax in range(pl.ndim):
        tags = tuple(_tag_of(s[ax]) for s in in_sbps)
        matched = None
        for inputs, result in sigs:
            if inputs == tags:
                matched = result
                break
        if matched is None:
            return None
        r = resolve_tag(matched, ndim_out)
        if r is None:
            return None
        out.append(r)
    return tuple(out)


@dataclasses.dataclass
class DistEGraph:
    eg: EGraph
    root: int
    placement: Placement
    terms: List[Term]
    eclusters: Dict[int, Dict[NdSbp, int]]   # term index -> {ndsbp: eclass}


def build_distributed_egraph(root_term: Term, pl: Placement,
                             max_sbps_per_tensor: int = 24) -> DistEGraph:
    """BuildEGraph (Fig. 5): embed every feasible SBP strategy of ``root_term``
    into one e-graph over placement ``pl``.

    Node vocabulary of the result:
      * ``input`` leaves — one per (graph input, feasible SBP) pair, each fed
        through a free ``box comm="split"`` node (host -> device split).  The
        split box *is* the input's placement choice, and its per-device
        memory (``make_mem_fn``) is how weight storage enters the capacity
        constraint: a replicated weight charges full bytes, a sharded one
        bytes/n.
      * compute nodes — one per (op, input-SBP combo) that an SBP signature
        accepts; the chosen input SBPs are recorded in the ``in_sbps`` attr
        (consumed by ``make_cost_fn(input_traffic=True)``) and nodes with the
        same output SBP are unioned into one e-class (the paper's E-Cluster:
        "same logic + same SBP => equivalent").
      * ``box comm="reshard"`` nodes — explicit Resharding Boxing candidates
        (all-gather / all-to-all / all-reduce / reduce-scatter) wherever
        ``boxing_cost`` says the conversion exists.

    The returned ``DistEGraph`` carries the topo-ordered ``terms`` list (term
    index == the ``term_id`` attr on every node) and the per-term
    ``eclusters`` dict mapping each ND-SBP to its e-class."""
    eg = EGraph()
    # collect unique terms in topo order
    topo: List[Term] = []
    seen = {}

    def walk(t: Term):
        if t in seen:
            return
        for c in t.children:
            walk(c)
        seen[t] = len(topo)
        topo.append(t)
    walk(root_term)

    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    for t in topo:
        shape_cache[t] = term_shape(t, shape_cache)

    eclusters: Dict[int, Dict[NdSbp, int]] = {}

    def add_box(src_class: int, tid: int, src: NdSbp, dst: NdSbp,
                shape) -> Optional[int]:
        if boxing_cost(src, dst, shape, pl) is None:
            return None
        node = ENode("box", (src_class,),
                     tuple(sorted({"term_id": tid, "src": src, "sbp": dst,
                                   "comm": "reshard"}.items())))
        return eg.add(node)

    for tid, t in enumerate(topo):
        shape = shape_cache[t]
        cluster: Dict[NdSbp, int] = {}
        if t.op == "input":
            # 1. Input phase: host split boxing, one class per feasible SBP
            for nd in valid_ndsbps(shape, pl)[:max_sbps_per_tensor]:
                leaf = eg.add(ENode("input", (),
                                    t.attrs + (("term_id", tid),)))
                node = ENode("box", (leaf,),
                             tuple(sorted({"term_id": tid, "src": None,
                                           "sbp": nd, "comm": "split"}.items())))
                cluster[nd] = eg.add(node)
        else:
            # 2. Compute phase: reuse + resharding candidates per input
            in_grps: List[List[Tuple[NdSbp, int]]] = []
            for c in t.children:
                cin = eclusters[seen[c]]
                cands: Dict[NdSbp, int] = dict(cin)
                cshape = shape_cache[c]
                targets = valid_ndsbps(cshape, pl,
                                       allow_partial=False)[:max_sbps_per_tensor]
                for dst in targets:
                    if dst in cands:
                        continue
                    # reshard from the (arbitrary) first available source
                    for src, cls in cin.items():
                        bid = add_box(cls, seen[c], src, dst, cshape)
                        if bid is not None:
                            cands[dst] = bid
                            break
                in_grps.append(list(cands.items()))
            kind = t.attr("kind")
            for combo in itertools.product(*in_grps):
                in_sbps = tuple(nd for nd, _ in combo)
                out_sbp = _apply_signature(t.op, kind, in_sbps, len(shape), pl)
                if out_sbp is None:
                    continue
                if shard_shape(shape, out_sbp, pl) is None:
                    continue
                node = ENode(t.op, tuple(cls for _, cls in combo),
                             t.attrs + tuple(sorted(
                                 {"term_id": tid, "sbp": out_sbp,
                                  "in_sbps": in_sbps}.items())))
                nid = eg.add(node)
                if out_sbp in cluster:
                    cluster[out_sbp] = eg.union(cluster[out_sbp], nid)
                else:
                    cluster[out_sbp] = nid
        eclusters[tid] = cluster

    # 3. Output phase: unshard to full Broadcast
    root_tid = seen[root_term]
    rshape = shape_cache[root_term]
    full_b = tuple(B for _ in range(pl.ndim))
    root_class = None
    for src, cls in eclusters[root_tid].items():
        if src == full_b:
            rid = cls
        else:
            rid = add_box(cls, root_tid, src, full_b, rshape)
        if rid is None:
            continue
        root_class = rid if root_class is None else eg.union(root_class, rid)
    eg.rebuild()
    # re-canonicalize cluster ids
    for tid in eclusters:
        eclusters[tid] = {nd: eg.find(c) for nd, c in eclusters[tid].items()}
    return DistEGraph(eg, eg.find(root_class), pl, topo, eclusters)


# ---------------------------------------------------------------------------
# Costs on shard shapes
# ---------------------------------------------------------------------------

def make_cost_fn(dg: DistEGraph, dtype_bytes: int = 2,
                 input_traffic: bool = False):
    """Per-ENode roofline cost on *local shard shapes* (seconds).

    Boxing nodes cost their alpha-beta collective time (``boxing_cost``);
    host-split boxing and raw input leaves are free.  Compute nodes cost
    ``max(flops / PEAK_FLOPS, bytes / HBM_BW)`` over the *local* output
    shard, so a sharded strategy is cheaper exactly when it shrinks the
    per-device working set.

    ``input_traffic=True`` switches the matmul HBM term from the legacy
    ``3 * out_bytes`` approximation to the true local traffic
    ``(lhs_local + rhs_local + out_local) bytes``, using the per-node
    ``in_sbps`` attr to shard the operand shapes.  That makes weight-read
    traffic visible to the search — a column/row-sharded weight streams
    ``1/n`` of its bytes per device — which is what lets
    ``choose_tp_layout`` discriminate tensor-parallel layouts whose output
    shards are identical.  The legacy form stays the default because
    existing extraction tests pin layouts chosen under it.
    """
    pl = dg.placement
    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    shapes = [term_shape(t, shape_cache) for t in dg.terms]
    tmap = {t: i for i, t in enumerate(dg.terms)}

    def cost(node: ENode) -> float:
        tid = node.attr("term_id")
        sbp = node.attr("sbp")
        if node.op == "input":
            return 0.0
        shape = shapes[tid]
        if node.op == "box":
            if node.attr("comm") == "split":
                return 0.0
            return boxing_cost(node.attr("src"), sbp, shape, pl,
                               dtype_bytes) or 0.0
        local = shard_shape(shape, sbp, pl)
        if local is None:
            return 1e9
        elems = 1
        for d in local:
            elems *= d
        if node.op == "matmul":
            in_sbps = node.attr("in_sbps")
            if input_traffic and in_sbps is not None:
                term = dg.terms[tid]
                a_full = shapes[tmap[term.children[0]]]
                b_full = shapes[tmap[term.children[1]]]
                a_local = shard_shape(a_full, in_sbps[0], pl)
                b_local = shard_shape(b_full, in_sbps[1], pl)
                if a_local is None or b_local is None:
                    return 1e9
                k_local = a_local[-1]
                in_elems = 1
                for d in a_local:
                    in_elems *= d
                b_elems = 1
                for d in b_local:
                    b_elems *= d
                in_elems += b_elems
                flops = 2 * elems * k_local
                return max(flops / PEAK_FLOPS,
                           (in_elems + elems) * dtype_bytes / HBM_BW)
            # legacy approximation: full contraction dim, 3x output bytes
            k_local = shape[1]  # fallback
            ch_sbp = None
            for n2 in dg.eg.nodes(node.children[0]):
                ch_sbp = n2.attr("sbp")
                break
            flops = 2 * elems * k_local
            return max(flops / PEAK_FLOPS,
                       3 * elems * dtype_bytes / HBM_BW)
        return max(elems * 4 / VPU_FLOPS, 3 * elems * dtype_bytes / HBM_BW)

    return cost


def make_mem_fn(dg: DistEGraph, dtype_bytes: int = 2):
    """Per-ENode *per-device* memory in bytes (``memory_bytes`` of the local
    shard; Partial tensors charge full size since every device holds an
    unreduced copy).  Input split boxes charge the placed weight/activation,
    so summing over a chosen extraction approximates per-device peak
    residency — the quantity ``auto_distribute(mem_capacity=...)`` caps."""
    pl = dg.placement
    from repro.core.tensor_ir import term_shape
    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    shapes = [term_shape(t, shape_cache) for t in dg.terms]

    def mem(node: ENode) -> int:
        tid = node.attr("term_id")
        sbp = node.attr("sbp")
        if node.op == "input" or sbp is None:
            return 0
        return memory_bytes(shapes[tid], sbp, pl, dtype_bytes)

    return mem


@dataclasses.dataclass
class DistributedPlan:
    """Result of :func:`auto_distribute`.

    Attributes:
      cost: modelled execution time of the chosen strategy (seconds).
      assignments: term index (into the builder's topo order) -> chosen
        ND-SBP.  Input terms map to their host-split placement — for a
        weight input this *is* its tensor-parallel layout.
      boxing: ``(term_id, src, dst)`` resharding collectives the plan
        inserts between producer and consumer.
      peak_memory: summed per-device bytes of every chosen node (the value
        checked against ``mem_capacity``).
    """
    cost: float
    assignments: Dict[int, NdSbp]        # term index -> chosen ND-SBP
    boxing: List[Tuple[int, NdSbp, NdSbp]]
    peak_memory: int


def auto_distribute(root_term: Term, pl: Placement,
                    mem_capacity: Optional[int] = None,
                    use_sat: bool = True,
                    input_traffic: bool = False,
                    dtype_bytes: int = 2) -> DistributedPlan:
    """Search the SBP strategy space of ``root_term`` over placement ``pl``.

    Builds the distributed e-graph (every feasible per-tensor SBP plus
    resharding boxing) and extracts the min-cost strategy:

      * ``mem_capacity`` set -> exact branch-and-bound with a hard
        per-device byte cap (raises ``ValueError`` when no strategy fits);
      * otherwise WPMaxSAT (``use_sat=True``) or greedy extraction.

    ``input_traffic``/``dtype_bytes`` configure :func:`make_cost_fn`; see
    there for why weight-read traffic is opt-in.
    """
    dg = build_distributed_egraph(root_term, pl)
    cost_fn = make_cost_fn(dg, dtype_bytes=dtype_bytes,
                           input_traffic=input_traffic)
    mem_fn = make_mem_fn(dg, dtype_bytes=dtype_bytes)
    if mem_capacity is not None:
        # hard per-device memory capacity: the specialized exact B&B prunes
        # over-capacity branches monotonically (see extraction.py)
        from repro.core.extraction import branch_bound_extract
        total, choice = branch_bound_extract(dg.eg, dg.root, cost_fn,
                                             mem_fn=mem_fn, cap=mem_capacity)
    elif use_sat:
        total, choice = wpmaxsat_extract(dg.eg, dg.root, cost_fn)
    else:
        total, choice = greedy_extract(dg.eg, dg.root, cost_fn)
    assignments: Dict[int, NdSbp] = {}
    boxing = []
    peak = 0
    for cid, node in choice.items():
        tid = node.attr("term_id")
        peak += mem_fn(node)
        if node.op == "box":
            if node.attr("comm") == "split":
                # input placement choice = the initial shard boxing target
                assignments[tid] = node.attr("sbp")
            else:
                boxing.append((tid, node.attr("src"), node.attr("sbp")))
        elif node.op != "input":
            assignments[tid] = node.attr("sbp")
    return DistributedPlan(total, assignments, boxing, peak)


def ndsbp_to_pspec(nd: NdSbp, pl: Placement, tensor_ndim: int):
    """Bridge to jax: dim d gets every mesh axis whose SBP is S(d)."""
    from jax.sharding import PartitionSpec
    entries: List[Optional[Tuple[str, ...]]] = [None] * tensor_ndim
    for axis_name, sbp in zip(pl.axes, nd):
        if isinstance(sbp, S):
            cur = entries[sbp.axis] or ()
            entries[sbp.axis] = tuple(cur) + (axis_name,)
    return PartitionSpec(*[e if e is None or len(e) > 1 else e[0]
                           for e in entries])


# ---------------------------------------------------------------------------
# Tensor-parallel layout choice for serving (consumed by
# repro.distributed.param_sharding)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WeightChoice:
    """Layout the search picked for one weight matrix.

    ``kind`` classifies the single-mesh-axis SBP of a 2-D ``(in, out)``
    weight: ``"column"`` = S(1) (output features sharded, no collective on
    this matmul), ``"row"`` = S(0) (contraction sharded, produces Partial
    output that costs one all-reduce), ``"replicated"`` = B.
    """
    name: str
    sbp: object
    kind: str


@dataclasses.dataclass(frozen=True)
class TPPlan:
    """Per-weight tensor-parallel layout emitted by :func:`choose_tp_layout`.

    ``choices`` maps weight name -> :class:`WeightChoice`; ``fallback``
    lists blocks where branch-and-bound found no strategy under the memory
    cap (non-divisible dims) and replicated layouts were substituted.
    ``cost``/``peak_memory`` aggregate the per-block plans for reporting.
    """
    n_model: int
    choices: Dict[str, WeightChoice]
    cost: float
    peak_memory: int
    fallback: Tuple[str, ...]


def _weight_kind(sbp) -> str:
    if isinstance(sbp, S):
        return "column" if sbp.axis == 1 else "row"
    return "replicated"


def choose_tp_layout(*, d_model: int, q_dim: int, d_ff: int, vocab: int,
                     n_model: int, tokens: int = 8,
                     dtype_bytes: int = 2) -> TPPlan:
    """Have Auto Distribution pick the tensor-parallel weight layout.

    Models a decode step as three block graphs at the paper's Fig. 6
    granularity — attention projections ``x @ wq -> silu -> @ wo``, the MLP
    ``x @ wi -> silu -> @ wdown``, and the LM head ``x @ wu`` — and runs
    each through :func:`auto_distribute` over a 1-D ``('model',)`` placement
    of ``n_model`` devices with:

      * a per-device memory cap that admits only ``1/n``-sharded weight
        storage (full activations allowed), so replicating any weight is
        infeasible by construction, and
      * ``input_traffic=True`` compute costs plus alpha-beta boxing costs,
        so among the feasible sharded layouts the one with the fewest /
        cheapest collectives wins (canonically: column-parallel wq/wi,
        row-parallel wo/wdown — exactly one all-reduce per block).

    The chosen per-weight ND-SBPs come back as :class:`WeightChoice`
    entries; blocks whose dims don't divide ``n_model`` fall back to
    replicated and are recorded in ``TPPlan.fallback``.  This is the sole
    source of the serving partition rules — ``param_sharding`` translates
    these kinds to ``PartitionSpec``s but never hard-codes a layout.
    """
    from repro.core.tensor_ir import inp, matmul, term_shape, unary

    pl = Placement(("model",), (n_model,))

    def chain(weights):
        t = inp("x", (tokens, d_model))
        for i, (name, shape) in enumerate(weights):
            t = matmul(t, inp(name, shape))
            if i < len(weights) - 1:
                t = unary(t, "silu")
        return t

    blocks = [
        ("attn", chain([("wq", (d_model, q_dim)), ("wo", (q_dim, d_model))])),
        ("mlp", chain([("wi", (d_model, d_ff)), ("wdown", (d_ff, d_model))])),
        ("head", chain([("wu", (d_model, vocab))])),
    ]
    weight_names = {
        "attn": ("wq", "wo"),
        "mlp": ("wi", "wdown"),
        "head": ("wu",),
    }

    choices: Dict[str, WeightChoice] = {}
    total_cost = 0.0
    peak = 0
    fallback: List[str] = []
    for bname, root in blocks:
        wnames = weight_names[bname]
        dg = build_distributed_egraph(root, pl)
        shape_cache: Dict[Term, Tuple[int, ...]] = {}
        w_bytes = 0
        other_bytes = 0
        for t in dg.terms:
            nb = dtype_bytes
            for d in term_shape(t, shape_cache):
                nb *= d
            if t.op == "input" and t.attr("name") in wnames:
                w_bytes += nb
            else:
                other_bytes += nb
        root_nb = dtype_bytes
        for d in term_shape(root, shape_cache):
            root_nb *= d
        # weights must fit 1/n-sharded; activations may stay full; the root
        # unshard box charges one extra full copy of the output
        cap = w_bytes // n_model + other_bytes + root_nb
        weight_terms = [(tid, t) for tid, t in enumerate(dg.terms)
                        if t.op == "input" and t.attr("name") in wnames]
        try:
            plan = auto_distribute(root, pl, mem_capacity=cap,
                                   input_traffic=True,
                                   dtype_bytes=dtype_bytes)
        except ValueError:
            fallback.append(bname)
            for _, t in weight_terms:
                name = t.attr("name")
                choices[name] = WeightChoice(name, (B,), "replicated")
            continue
        total_cost += plan.cost
        peak = max(peak, plan.peak_memory)
        for tid, t in weight_terms:
            name = t.attr("name")
            nd = plan.assignments.get(tid, (B,))
            choices[name] = WeightChoice(name, nd, _weight_kind(nd[0]))
    return TPPlan(n_model, choices, total_cost, peak, tuple(fallback))
