"""NTT (nncase Tensor Template) library, TPU edition (§3.3.2).

The paper's NTT is a C++20 header library of register-level μkernels; our
TPU-native equivalent is the set of Pallas kernels in ``repro.kernels``.
This module is the *registry + analytical timing model* used by the
Auto Schedule MINLP (Eq. 15): each μkernel has a linear latency model
``t(n) = alpha + n / throughput`` fitted to the hardware model
(MXU 128x128x128 macs/cycle-block, VPU 8x128 lanes @ 940 MHz).

μkernels are the *atomic scheduling units*: MCTS/MINLP never schedule below
the μkernel tile (the paper's fix for the scalar-granularity mismatch).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

CLOCK_HZ = 1.5e9           # v5e core clock
N_MXU = 4
N_VPU = 4
MXU_MACS_PER_CYCLE = N_MXU * 128 * 128   # 4x 128x128 systolic arrays
# 4 * 16384 MACs/cycle * 2 flop/MAC * 1.5 GHz = 196.6 TFLOP/s  (v5e bf16 peak)
VPU_LANES = N_VPU * 8 * 128


@dataclasses.dataclass(frozen=True)
class MicroKernel:
    name: str
    unit: str                # "mxu" | "vpu"
    tile: Tuple[int, ...]    # minimal hardware tile
    alpha_cycles: float      # fixed issue overhead
    throughput: float        # elements (or MACs) per cycle
    pallas_impl: str         # dotted path of the Pallas kernel backing it


MICRO_KERNELS: Dict[str, MicroKernel] = {
    "matmul": MicroKernel("matmul", "mxu", (128, 128, 128), 20.0,
                          MXU_MACS_PER_CYCLE,
                          "repro.kernels.matmul"),
    "exp": MicroKernel("exp", "vpu", (8, 128), 8.0, VPU_LANES / 4,
                       "repro.kernels.unary"),
    "silu": MicroKernel("silu", "vpu", (8, 128), 8.0, VPU_LANES / 6,
                        "repro.kernels.unary"),
    "add": MicroKernel("add", "vpu", (8, 128), 4.0, VPU_LANES,
                       "repro.kernels.binary"),
    "mul": MicroKernel("mul", "vpu", (8, 128), 4.0, VPU_LANES,
                       "repro.kernels.binary"),
    "rmsnorm": MicroKernel("rmsnorm", "vpu", (8, 128), 16.0, VPU_LANES / 3,
                           "repro.kernels.rmsnorm"),
    "softmax_row": MicroKernel("softmax_row", "vpu", (8, 128), 24.0,
                               VPU_LANES / 8, "repro.kernels.flash_attention"),
    "ssm_step": MicroKernel("ssm_step", "vpu", (8, 128), 12.0, VPU_LANES / 4,
                            "repro.kernels.ssm_scan"),
}


def ukernel_time(name: str, work_elems: int) -> float:
    """μKernelTime (Eq. 15): linear model, seconds for `work_elems` units
    (MACs for mxu kernels, elements for vpu kernels)."""
    k = MICRO_KERNELS[name]
    cycles = k.alpha_cycles + work_elems / k.throughput
    return cycles / CLOCK_HZ


def op_ukernel(op: str, kind: str = None) -> str:
    if op in ("matmul", "packed_matmul"):
        return "matmul"
    if kind in MICRO_KERNELS:
        return kind
    return "add"
