"""MCTS structural search (§3.2.1).

Nodes are TileGraph states; edges are merge/reorder actions; the *Simulation*
phase is NOT a random rollout — per the paper it calls the MINLP parametric
solver as a deterministic evaluator, and the reward is 1/latency.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

from repro.core.schedule.minlp import MINLPSolver, Schedule
from repro.core.schedule.tile_graph import TileGraph


def enumerate_actions(tg: TileGraph) -> List[Tuple[str, tuple]]:
    acts: List[Tuple[str, tuple]] = []
    ng = len(tg.groups)
    for src in range(ng):
        for dst in range(ng):
            if src != dst and tg.merge(src, dst) is not None:
                acts.append(("merge", (src, dst)))
    for gi, g in enumerate(tg.groups):
        n = len(g.order)
        if n <= 1:
            continue
        # adjacent swaps + full reversal keep branching factor sane
        for a in range(n - 1):
            perm = list(range(n))
            perm[a], perm[a + 1] = perm[a + 1], perm[a]
            if tg.reorder(gi, tuple(perm)) is not None:
                acts.append(("reorder", (gi, tuple(perm))))
    return acts


def apply_action(tg: TileGraph, act) -> Optional[TileGraph]:
    kind, args = act
    return tg.merge(*args) if kind == "merge" else tg.reorder(*args)


@dataclasses.dataclass
class Node:
    state: TileGraph
    parent: Optional["Node"]
    action: Optional[tuple]
    children: List["Node"] = dataclasses.field(default_factory=list)
    untried: Optional[List[tuple]] = None
    visits: int = 0
    value: float = 0.0          # sum of rewards
    reward: float = 0.0         # this state's own evaluation


class MCTS:
    def __init__(self, solver: Optional[MINLPSolver] = None,
                 c_uct: float = 0.7, seed: int = 0):
        self.solver = solver or MINLPSolver()
        self.c = c_uct
        self.rng = random.Random(seed)
        self.eval_cache: Dict[TileGraph, Schedule] = {}

    def evaluate(self, tg: TileGraph) -> Schedule:
        if tg not in self.eval_cache:
            self.eval_cache[tg] = self.solver.solve(tg)
        return self.eval_cache[tg]

    def search(self, root_state: TileGraph, iterations: int = 40
               ) -> Tuple[TileGraph, Schedule]:
        root = Node(root_state, None, None)
        root.untried = enumerate_actions(root_state)
        best: Tuple[float, TileGraph, Schedule] = (
            self.evaluate(root_state).latency, root_state,
            self.evaluate(root_state))

        for _ in range(iterations):
            node = root
            # 1. Selection
            while not node.untried and node.children:
                node = max(node.children, key=lambda ch: (
                    ch.value / max(1, ch.visits)
                    + self.c * math.sqrt(math.log(node.visits + 1)
                                         / max(1, ch.visits))))
            # 2. Expansion
            if node.untried:
                act = node.untried.pop(
                    self.rng.randrange(len(node.untried)))
                child_state = apply_action(node.state, act)
                if child_state is None:
                    continue
                child = Node(child_state, node, act)
                child.untried = enumerate_actions(child_state)
                node.children.append(child)
                node = child
            # 3. Simulation = deterministic MINLP evaluation
            sched = self.evaluate(node.state)
            reward = 0.0 if not sched.feasible else 1.0 / (sched.latency + 1e-12)
            node.reward = reward
            if sched.feasible and sched.latency < best[0]:
                best = (sched.latency, node.state, sched)
            # 4. Backpropagation
            while node is not None:
                node.visits += 1
                node.value += reward
                node = node.parent
        return best[1], best[2]


def auto_schedule(tg: TileGraph, iterations: int = 40,
                  seed: int = 0) -> Tuple[TileGraph, Schedule, Schedule]:
    """Returns (best structure, its schedule, the unfused baseline schedule)."""
    mcts = MCTS(seed=seed)
    baseline = mcts.evaluate(tg)
    state, sched = mcts.search(tg, iterations=iterations)
    return state, sched, baseline
