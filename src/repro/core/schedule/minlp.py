"""Parametric optimization via MINLP (§3.2.2).

Given a structural state (TileGraph), solve for VMEM tile sizes and buffer
placement minimizing  max(T_mem, T_comp)  (Eq. 16) subject to:

  * domain coverage — tiles divide loop extents (Eq. 10),
  * VMEM capacity    — sum of resident (double-buffered) tiles + fused
    intermediates <= 16 MB (Eq. 14),
  * fusion           — intermediates of fused groups live in VMEM (Eq. 13).

T_comp uses the NTT μkernel linear timing model x trip counts (Eq. 15);
T_mem is the HBM<->VMEM traffic under the loop-order-aware reuse model:
a buffer is re-streamed by every loop outside its residency scope that does
not index it (this is where ``reorder`` earns its keep).

Solver: branch & bound over divisor-constrained integer tiles — integer
variables + nonlinear objective + hard capacity constraints, i.e. a small
special-purpose MINLP (the paper uses OR-Tools; we stay self-contained).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.schedule.ntt import ukernel_time
from repro.core.schedule.tile_graph import TileGraph

VMEM_BYTES = 16 * 2**20
HBM_BW = 819e9
DOUBLE_BUFFER = 2


def _divisors(n: int, cap: int = 4096) -> List[int]:
    out = [d for d in range(1, min(n, cap) + 1) if n % d == 0]
    # keep the search tractable: powers of two + hw-aligned + extremes
    keep = sorted({d for d in out
                   if d in (1, n) or d % 128 == 0 or (d & (d - 1)) == 0})
    return keep


@dataclasses.dataclass
class Schedule:
    tiles: Dict[int, Dict[str, int]]        # group -> loop -> tile
    latency: float
    t_mem: float
    t_comp: float
    vmem_peak: int
    feasible: bool = True


def _group_eval(tg: TileGraph, gi: int, tiles: Dict[str, int]
                ) -> Optional[Tuple[float, float, int]]:
    """(t_mem, t_comp, vmem_bytes) for one group under `tiles`, or None if
    the tiling violates VMEM capacity."""
    g = tg.groups[gi]
    order = g.order
    trips = {l: tg.extent(l) // tiles[l] for l in order}
    pos = {l: i for i, l in enumerate(order)}
    hbm, inter = tg.group_buffers(gi)

    def tile_elems(buf) -> int:
        n = 1
        for l in buf.index:
            n *= tiles[l] if l in tiles else 1
        return n

    # VMEM residency: tiles of every buffer touched + fused intermediates
    vmem = 0
    for b in hbm:
        vmem += DOUBLE_BUFFER * tile_elems(b) * b.elem_bytes
    for b in inter:
        vmem += tile_elems(b) * b.elem_bytes
    if vmem > VMEM_BYTES:
        return None

    # HBM traffic with reuse model
    t_bytes = 0
    for b in hbm:
        reload_loops = 1
        idx = set(b.index)
        max_idx_pos = max((pos[l] for l in b.index if l in pos), default=-1)
        for l in order:
            if l in idx:
                reload_loops *= trips[l]
            elif pos[l] < max_idx_pos:
                # an outer loop not indexing b forces re-streaming
                reload_loops *= trips[l]
        t_bytes += tile_elems(b) * b.elem_bytes * reload_loops
    t_mem = t_bytes / HBM_BW

    # compute time: μkernel model per op x its trip count
    t_comp = 0.0
    for opname in g.ops:
        op = tg.op(opname)
        trip = 1
        for l in order:
            if l in op.loops:
                trip *= trips[l]
        tile_work = 1
        for l in op.loops:
            tile_work *= tiles.get(l, 1)
        t_comp += trip * ukernel_time(op.ukernel, tile_work)
    return t_mem, t_comp, vmem


class MINLPSolver:
    """Branch & bound over per-group divisor-constrained tiles."""

    def __init__(self, max_candidates_per_loop: int = 12,
                 beam: int = 64):
        self.max_cands = max_candidates_per_loop
        self.beam = beam

    def solve_group(self, tg: TileGraph, gi: int):
        g = tg.groups[gi]
        loops = list(g.order)
        cands = {}
        for l in loops:
            ds = _divisors(tg.extent(l))
            # hardware alignment: prefer >= μkernel tile on matmul dims
            if len(ds) > self.max_cands:
                step = len(ds) / self.max_cands
                ds = sorted({ds[int(i * step)] for i in range(self.max_cands)}
                            | {ds[-1]})
            cands[l] = ds

        best: Optional[Tuple[float, Dict[str, int], Tuple]] = None
        # beam over loops: partial assignment keeps optimistic bound
        partials: List[Dict[str, int]] = [{}]
        for l in loops:
            nxt = []
            for p in partials:
                for d in cands[l]:
                    q = dict(p)
                    q[l] = d
                    nxt.append(q)
            # score partials optimistically: fill remaining loops with full
            # extent (max reuse) ignoring capacity; keep the best `beam`
            scored = []
            for q in nxt:
                full = dict(q)
                for l2 in loops:
                    full.setdefault(l2, tg.extent(l2))
                ev = _group_eval(tg, gi, full)
                opt = max(ev[0], ev[1]) if ev else float("inf")
                scored.append((opt if ev else 1e30, q))
            scored.sort(key=lambda x: x[0])
            partials = [q for _, q in scored[:self.beam]]
        for q in partials:
            ev = _group_eval(tg, gi, q)
            if ev is None:
                continue
            lat = max(ev[0], ev[1])
            if best is None or lat < best[0]:
                best = (lat, q, ev)
        if best is None:
            return None
        lat, tiles, (tm, tc, vm) = best
        return lat, tiles, tm, tc, vm

    def solve(self, tg: TileGraph) -> Schedule:
        total_lat = t_mem = t_comp = 0.0
        peak = 0
        all_tiles: Dict[int, Dict[str, int]] = {}
        for gi in range(len(tg.groups)):
            r = self.solve_group(tg, gi)
            if r is None:
                return Schedule({}, float("inf"), float("inf"), float("inf"),
                                0, feasible=False)
            lat, tiles, tm, tc, vm = r
            all_tiles[gi] = tiles
            total_lat += lat
            t_mem += tm
            t_comp += tc
            peak = max(peak, vm)
        return Schedule(all_tiles, total_lat, t_mem, t_comp, peak)
