"""Tiered Tile Graphs (§3.2): the structural half of the schedule space.

A schedule state is a list of *groups*; each group is one VMEM-level loop
nest executing one or more fused ops (Eq. 3's Op^n nesting, flattened to the
three TPU memory tiers HBM -> VMEM -> VREG).  Group loop ORDER is explicit —
it drives the buffer-reuse traffic model in the MINLP.

Actions (MCTS edges, §3.2.1):
  * merge(src, dst)      — operator fusion at the VMEM level: the producer
    group's ops join the consumer group; the intermediate buffer stops
    touching HBM (Fig. 7's green dashed box).
  * reorder(group, perm) — loop permutation within a group's nest.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class Buffer:
    name: str
    index: Tuple[str, ...]          # which loops address this buffer
    elem_bytes: int = 2


@dataclasses.dataclass(frozen=True)
class OpSpec:
    name: str
    ukernel: str
    loops: Tuple[str, ...]          # iteration dims of this op
    reads: Tuple[Buffer, ...]
    write: Buffer


@dataclasses.dataclass(frozen=True)
class Group:
    ops: Tuple[str, ...]            # op names, producer -> consumer order
    order: Tuple[str, ...]          # loop order, outermost first


@dataclasses.dataclass(frozen=True)
class TileGraph:
    ops: Tuple[OpSpec, ...]
    extents: Tuple[Tuple[str, int], ...]   # loop name -> extent
    groups: Tuple[Group, ...]

    def extent(self, loop: str) -> int:
        for k, v in self.extents:
            if k == loop:
                return v
        raise KeyError(loop)

    def op(self, name: str) -> OpSpec:
        for o in self.ops:
            if o.name == name:
                return o
        raise KeyError(name)

    # -- structural actions --------------------------------------------------
    def merge(self, src: int, dst: int) -> Optional["TileGraph"]:
        """Fuse group src into group dst (src's last op must feed dst's first)."""
        if src == dst or src >= len(self.groups) or dst >= len(self.groups):
            return None
        gs, gd = self.groups[src], self.groups[dst]
        prod = self.op(gs.ops[-1])
        cons = self.op(gd.ops[0])
        if prod.write.name not in [b.name for b in cons.reads]:
            return None
        loops = list(gs.order) + [l for l in gd.order if l not in gs.order]
        merged = Group(gs.ops + gd.ops, tuple(loops))
        groups = [g for i, g in enumerate(self.groups) if i not in (src, dst)]
        groups.insert(min(src, dst), merged)
        return dataclasses.replace(self, groups=tuple(groups))

    def reorder(self, gi: int, perm: Tuple[int, ...]) -> Optional["TileGraph"]:
        if gi >= len(self.groups):
            return None
        g = self.groups[gi]
        if sorted(perm) != list(range(len(g.order))):
            return None
        new_order = tuple(g.order[p] for p in perm)
        if new_order == g.order:
            return None
        groups = list(self.groups)
        groups[gi] = Group(g.ops, new_order)
        return dataclasses.replace(self, groups=tuple(groups))

    # -- group-level buffer classification ------------------------------------
    def group_buffers(self, gi: int):
        """Returns (hbm_buffers, intermediate_buffers) for group gi.
        Intermediates are produced AND consumed inside the group (stay in
        VMEM); everything else moves through HBM."""
        g = self.groups[gi]
        produced = {self.op(o).write.name: self.op(o).write for o in g.ops}
        consumed = {}
        for o in g.ops:
            for b in self.op(o).reads:
                consumed[b.name] = b
        inter, hbm = [], []
        for name, b in produced.items():
            (inter if name in consumed else hbm).append(b)
        for name, b in consumed.items():
            if name not in produced:
                hbm.append(b)
        return hbm, inter


# ---------------------------------------------------------------------------
# Builders for the paper's running examples
# ---------------------------------------------------------------------------

def matmul_tile_graph(M: int, N: int, K: int, dtype_bytes: int = 2) -> TileGraph:
    A = Buffer("A", ("i", "k"), dtype_bytes)
    B = Buffer("B", ("k", "j"), dtype_bytes)
    C = Buffer("C", ("i", "j"), dtype_bytes)
    op = OpSpec("mm", "matmul", ("i", "j", "k"), (A, B), C)
    return TileGraph((op,), (("i", M), ("j", N), ("k", K)),
                     (Group(("mm",), ("i", "j", "k")),))


def attention_tile_graph(S: int, D: int, dtype_bytes: int = 2) -> TileGraph:
    """Fig. 7: O = MatMul(Exp(MatMul(Q, K)), V); loops i (q rows), l (kv rows),
    k (head dim), j (head dim out)."""
    Q = Buffer("Q", ("i", "k"), dtype_bytes)
    K = Buffer("K", ("k", "l"), dtype_bytes)
    Sb = Buffer("S", ("i", "l"), dtype_bytes)
    E = Buffer("E", ("i", "l"), dtype_bytes)
    V = Buffer("V", ("l", "j"), dtype_bytes)
    O = Buffer("O", ("i", "j"), dtype_bytes)
    mm1 = OpSpec("mm1", "matmul", ("i", "l", "k"), (Q, K), Sb)
    ex = OpSpec("exp", "exp", ("i", "l"), (Sb,), E)
    mm2 = OpSpec("mm2", "matmul", ("i", "j", "l"), (E, V), O)
    return TileGraph(
        (mm1, ex, mm2),
        (("i", S), ("l", S), ("k", D), ("j", D)),
        (Group(("mm1",), ("i", "l", "k")),
         Group(("exp",), ("i", "l")),
         Group(("mm2",), ("i", "j", "l"))),
    )


def mlp_tile_graph(T: int, D: int, F: int, dtype_bytes: int = 2) -> TileGraph:
    """h = silu(x @ w1); y = h @ w2."""
    X = Buffer("X", ("i", "k"), dtype_bytes)
    W1 = Buffer("W1", ("k", "f"), dtype_bytes)
    H0 = Buffer("H0", ("i", "f"), dtype_bytes)
    H = Buffer("H", ("i", "f"), dtype_bytes)
    W2 = Buffer("W2", ("f", "j"), dtype_bytes)
    Y = Buffer("Y", ("i", "j"), dtype_bytes)
    mm1 = OpSpec("mm1", "matmul", ("i", "f", "k"), (X, W1), H0)
    act = OpSpec("silu", "silu", ("i", "f"), (H0,), H)
    mm2 = OpSpec("mm2", "matmul", ("i", "j", "f"), (H, W2), Y)
    return TileGraph(
        (mm1, act, mm2),
        (("i", T), ("f", F), ("k", D), ("j", D)),
        (Group(("mm1",), ("i", "f", "k")),
         Group(("silu",), ("i", "f")),
         Group(("mm2",), ("i", "j", "f"))),
    )
