from repro.core.schedule.tile_graph import (  # noqa: F401
    Buffer, Group, OpSpec, TileGraph,
    attention_tile_graph, matmul_tile_graph, mlp_tile_graph,
)
from repro.core.schedule.minlp import MINLPSolver, Schedule  # noqa: F401
from repro.core.schedule.mcts import MCTS, auto_schedule  # noqa: F401
from repro.core.schedule.ntt import MICRO_KERNELS, ukernel_time  # noqa: F401
