"""Buffer Schedule (§3.3.1): bufferization + alias analysis + memory planning.

* **Alias analysis** — view-semantics ops (reshape/slice/squeeze/unpack-of-
  pack metadata views) share their input's storage: zero-copy.
* **Liveness** — intervals over a linearized (topological) op order.
* **Memory planning** — offset assignment is the classic interval bin-packing:
  a greedy best-fit planner for production sizes, plus an exact
  branch-and-bound planner (the paper's SAT-optimal arrangement) for small
  problem sizes, used to measure the greedy gap in tests.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Tuple

from repro.core.tensor_ir import Term, term_shape

VIEW_OPS = ("reshape", "squeeze", "slice_view")


@dataclasses.dataclass
class BufferSpec:
    name: str
    size: int
    start: int            # first def (topo index)
    end: int              # last use
    alias_of: Optional[str] = None


def liveness_from_term(root: Term, dtype_bytes: int = 2) -> List[BufferSpec]:
    """Linearize a term DAG and build liveness intervals; view ops alias."""
    topo: List[Term] = []
    seen: Dict[Term, int] = {}

    def walk(t: Term):
        if t in seen:
            return
        for c in t.children:
            walk(c)
        seen[t] = len(topo)
        topo.append(t)
    walk(root)

    last_use = {i: i for i in range(len(topo))}
    for i, t in enumerate(topo):
        for c in t.children:
            last_use[seen[c]] = max(last_use[seen[c]], i)
    last_use[seen[root]] = len(topo)

    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    buffers = []
    for i, t in enumerate(topo):
        shape = term_shape(t, shape_cache)
        n = dtype_bytes
        for d in shape:
            n *= d
        alias = None
        if t.op in VIEW_OPS and t.children:
            alias = f"b{seen[t.children[0]]}"
        buffers.append(BufferSpec(f"b{i}", 0 if alias else n, i,
                                  last_use[i], alias))
    return buffers


def _overlaps(a: BufferSpec, b: BufferSpec) -> bool:
    return not (a.end <= b.start or b.end <= a.start)


def plan_greedy(buffers: List[BufferSpec]) -> Tuple[Dict[str, int], int]:
    """Best-fit decreasing offset assignment.  Returns ({name: offset}, peak)."""
    real = [b for b in buffers if b.alias_of is None and b.size > 0]
    placed: List[Tuple[BufferSpec, int]] = []
    offsets: Dict[str, int] = {}
    for b in sorted(real, key=lambda x: -x.size):
        conflicts = sorted(
            [(off, off + p.size) for p, off in placed if _overlaps(p, b)])
        off = 0
        for lo, hi in conflicts:
            if off + b.size <= lo:
                break
            off = max(off, hi)
        offsets[b.name] = off
        placed.append((b, off))
    peak = max((off + b.size for b, off in placed), default=0)
    for b in buffers:
        if b.alias_of is not None:
            offsets[b.name] = offsets.get(b.alias_of, 0)
        elif b.size == 0:
            offsets.setdefault(b.name, 0)
    return offsets, peak


def plan_optimal(buffers: List[BufferSpec], node_budget: int = 200000
                 ) -> Tuple[Dict[str, int], int]:
    """Exact branch & bound over placement order (small inputs only)."""
    real = [b for b in buffers if b.alias_of is None and b.size > 0]
    if len(real) > 12:
        return plan_greedy(buffers)
    best: List[Tuple[int, Dict[str, int]]] = [plan_greedy(buffers)[::-1]]
    best_peak = best[0][0] if isinstance(best[0][0], int) else None
    g_off, g_peak = plan_greedy(buffers)
    best_sol = (g_peak, g_off)
    visited = [0]

    def place(order_left: List[BufferSpec], placed: List[Tuple[BufferSpec, int]],
              peak: int):
        visited[0] += 1
        if visited[0] > node_budget:
            return
        nonlocal best_sol
        if peak >= best_sol[0]:
            return
        if not order_left:
            off = {b.name: o for b, o in placed}
            best_sol = (peak, off)
            return
        for i, b in enumerate(order_left):
            conflicts = sorted(
                [(o, o + p.size) for p, o in placed if _overlaps(p, b)])
            # candidate offsets: 0 and each conflict end
            cands = [0] + [hi for _, hi in conflicts]
            for off in cands:
                ok = all(off + b.size <= lo or off >= hi
                         for lo, hi in conflicts)
                if not ok:
                    continue
                place(order_left[:i] + order_left[i + 1:],
                      placed + [(b, off)], max(peak, off + b.size))
                break  # first-fit per buffer within this order branch

    place(sorted(real, key=lambda x: -x.size), [], 0)
    peak, offsets = best_sol
    for b in buffers:
        if b.alias_of is not None:
            offsets[b.name] = offsets.get(b.alias_of, 0)
        elif b.size == 0:
            offsets.setdefault(b.name, 0)
    return offsets, peak


def naive_peak(buffers: List[BufferSpec]) -> int:
    """No-reuse allocation (sum of all sizes) — the baseline the planner beats."""
    return sum(b.size for b in buffers if b.alias_of is None)


def validate_plan(buffers: List[BufferSpec], offsets: Dict[str, int]) -> bool:
    real = [b for b in buffers if b.alias_of is None and b.size > 0]
    for a, b in itertools.combinations(real, 2):
        if _overlaps(a, b):
            ao, bo = offsets[a.name], offsets[b.name]
            if not (ao + a.size <= bo or bo + b.size <= ao):
                return False
    return True
