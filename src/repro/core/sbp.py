"""SBP (Split / Broadcast / Partial) abstraction (§3.1.3), after OneFlow.

An ND-SBP assigns one SBP per mesh axis; axes act orthogonally.  Boxing
converts between ND-SBPs; its cost is the alpha-beta collective model.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

from repro.core.cost_model import ALPHA, ICI_BW


@dataclasses.dataclass(frozen=True)
class S:
    axis: int

    def __repr__(self):
        return f"S({self.axis})"


class _B:
    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "B"


class _P:
    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "P"


B = _B()
P = _P()
NdSbp = Tuple[object, ...]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Logical device topology: named mesh axes with sizes."""
    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]

    @property
    def ndim(self):
        return len(self.axes)

    @property
    def n_devices(self):
        n = 1
        for s in self.sizes:
            n *= s
        return n


def shard_shape(shape: Tuple[int, ...], nd: NdSbp, pl: Placement):
    """Per-device local shape under an ND-SBP (None if not divisible)."""
    local = list(shape)
    for sbp, size in zip(nd, pl.sizes):
        if isinstance(sbp, S):
            if sbp.axis >= len(local) or local[sbp.axis] % size != 0:
                return None
            local[sbp.axis] //= size
    return tuple(local)


def valid_ndsbps(shape: Tuple[int, ...], pl: Placement,
                 allow_partial: bool = False) -> List[NdSbp]:
    """All ND-SBPs whose splits divide `shape` evenly."""
    per_axis: List[List[object]] = []
    for size in pl.sizes:
        cands: List[object] = [B]
        cands += [S(d) for d in range(len(shape)) if shape[d] % size == 0]
        if allow_partial:
            cands.append(P)
        per_axis.append(cands)
    out = []
    for combo in itertools.product(*per_axis):
        if shard_shape(shape, combo, pl) is not None:
            out.append(tuple(combo))
    return out


def memory_bytes(shape, nd: NdSbp, pl: Placement, dtype_bytes: int = 2) -> int:
    """Per-device bytes of a tensor stored with this ND-SBP."""
    local = shard_shape(shape, nd, pl)
    if local is None:
        return 1 << 60
    n = dtype_bytes
    for d in local:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Boxing: per-axis SBP transitions and their collective cost
# ---------------------------------------------------------------------------

_TRANSITION = {
    # (src, dst) -> collective kind; None = impossible, "" = free
    ("B", "B"): "",
    ("B", "S"): "slice",          # local slicing, free
    ("S", "S"): "all-to-all",     # different split axes
    ("S", "B"): "all-gather",
    ("P", "B"): "all-reduce",
    ("P", "S"): "reduce-scatter",
    # P sources can also stay partial (free) — handled by equality below
}


def _kindof(sbp) -> str:
    if isinstance(sbp, S):
        return "S"
    return "B" if sbp is B else "P"


def boxing_ops(src: NdSbp, dst: NdSbp, shape, pl: Placement,
               dtype_bytes: int = 2):
    """List of (collective kind, payload bytes, group size) per mesh axis for
    converting src -> dst.  Returns None if the conversion is impossible."""
    ops = []
    for i, (a, b, size) in enumerate(zip(src, dst, pl.sizes)):
        if a == b or size == 1:
            continue
        ka, kb = _kindof(a), _kindof(b)
        if ka == "S" and kb == "S" and a.axis == b.axis:
            continue
        kind = _TRANSITION.get((ka, kb))
        if kind is None:
            return None
        if kind in ("", "slice"):
            ops.append(("slice", 0, size))
            continue
        # payload = the local tensor being exchanged on this axis: use the
        # destination-local size for gathers, source-local for scatters.
        local_src = shard_shape(shape, src, pl)
        if local_src is None:
            return None
        nbytes = dtype_bytes
        for d in local_src:
            nbytes *= d
        if kind == "all-gather":
            nbytes *= size  # gathered result
        ops.append((kind, nbytes, size))
    return ops


def boxing_cost(src: NdSbp, dst: NdSbp, shape, pl: Placement,
                dtype_bytes: int = 2) -> Optional[float]:
    ops = boxing_ops(src, dst, shape, pl, dtype_bytes)
    if ops is None:
        return None
    t = 0.0
    for kind, nbytes, g in ops:
        if kind == "slice" or g <= 1:
            continue
        frac = (g - 1) / g
        factor = {"all-gather": frac, "reduce-scatter": frac,
                  "all-reduce": 2 * frac, "all-to-all": frac}[kind]
        t += ALPHA + factor * nbytes / ICI_BW
    return t


# ---------------------------------------------------------------------------
# SBP signatures (per mesh axis; ND composition is orthogonal)
# ---------------------------------------------------------------------------

def matmul_axis_signatures() -> List[Tuple[Tuple[str, ...], str]]:
    """1-axis signatures for C[M,N] = A[M,K] @ B[K,N], encoded symbolically:
    entries are 'S0'/'S1'/'B'/'P' per operand and the output."""
    return [
        (("S0", "B"), "S0"),    # split rows (data parallel)
        (("B", "S1"), "S1"),    # split cols (tensor parallel out-dim)
        (("S1", "S0"), "P"),    # split contraction -> partial
        (("B", "B"), "B"),
        (("P", "B"), "P"),
        (("B", "P"), "P"),
    ]


def elementwise_axis_signatures(arity: int, linear: bool
                                ) -> List[Tuple[Tuple[str, ...], str]]:
    sigs = []
    for tag in ("S0", "S1", "B"):
        sigs.append((tuple(tag for _ in range(arity)), tag))
    if linear:  # add-like ops propagate partial values
        sigs.append((tuple("P" for _ in range(arity)), "P"))
        if arity == 2:
            sigs.append((("P", "B"), "P"))
            sigs.append((("B", "P"), "P"))
    return sigs


def resolve_tag(tag: str, ndim: int):
    if tag == "B":
        return B
    if tag == "P":
        return P
    d = int(tag[1:])
    return S(d) if d < ndim else None
