"""SBP (Split / Broadcast / Partial) abstraction (§3.1.3), after OneFlow.

The three per-mesh-axis placement states of a logical tensor:

  * ``S(d)`` — *Split*: sliced evenly along tensor dim ``d`` across the
    devices of that mesh axis.  ``S(1)`` on a 2-D ``(in, out)`` weight is
    column-parallel, ``S(0)`` is row-parallel.
  * ``B`` — *Broadcast*: every device holds the full tensor.
  * ``P`` — *Partial*: every device holds a same-shaped unreduced partial
    sum; the true value is their elementwise sum.  This is what a matmul
    over a split contraction dim produces, and an all-reduce (``P -> B``)
    or reduce-scatter (``P -> S``) materializes it.

An *ND-SBP* is a tuple assigning one SBP per mesh axis; axes compose
orthogonally (a ``(S(0), B)`` over a 2-D mesh shards dim 0 on the first
axis and replicates over the second).  *Boxing* converts between ND-SBPs
via collectives; :func:`boxing_cost` prices each transition with the
alpha-beta model (``ALPHA`` latency + payload / ``ICI_BW``).

Op semantics live in *signatures* (:func:`matmul_axis_signatures`,
:func:`elementwise_axis_signatures`): per-axis rules mapping input SBP
tags to the output tag, e.g. ``(B, S1) -> S1`` ("replicated activations
times a column-sharded weight yield column-sharded output, no comm") and
``(S1, S0) -> P`` ("split contraction yields partials").  Auto
Distribution (``repro.core.distribution``) enumerates these per tensor and
extracts the cheapest consistent assignment; ``ndsbp_to_pspec`` bridges
the result to ``jax.sharding.PartitionSpec``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Tuple

from repro.core.cost_model import ALPHA, ICI_BW


@dataclasses.dataclass(frozen=True)
class S:
    """Split along tensor dim ``axis``; hashable and interned by value so
    ND-SBP tuples can key e-cluster dicts."""
    axis: int

    def __repr__(self):
        return f"S({self.axis})"


class _B:
    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "B"


class _P:
    _inst = None

    def __new__(cls):
        if cls._inst is None:
            cls._inst = super().__new__(cls)
        return cls._inst

    def __repr__(self):
        return "P"


B = _B()
P = _P()
NdSbp = Tuple[object, ...]


@dataclasses.dataclass(frozen=True)
class Placement:
    """Logical device topology: named mesh axes with sizes."""
    axes: Tuple[str, ...]
    sizes: Tuple[int, ...]

    @property
    def ndim(self):
        return len(self.axes)

    @property
    def n_devices(self):
        n = 1
        for s in self.sizes:
            n *= s
        return n


def shard_shape(shape: Tuple[int, ...], nd: NdSbp, pl: Placement):
    """Per-device local shape under an ND-SBP (None if not divisible)."""
    local = list(shape)
    for sbp, size in zip(nd, pl.sizes):
        if isinstance(sbp, S):
            if sbp.axis >= len(local) or local[sbp.axis] % size != 0:
                return None
            local[sbp.axis] //= size
    return tuple(local)


def valid_ndsbps(shape: Tuple[int, ...], pl: Placement,
                 allow_partial: bool = False) -> List[NdSbp]:
    """All ND-SBPs whose splits divide `shape` evenly.

    This is the per-tensor strategy-enumeration primitive: Auto
    Distribution calls it for every graph input (and for resharding
    targets, with ``allow_partial=False`` since nothing *stores* a tensor
    as Partial on purpose).  Non-divisible splits are excluded here, which
    is why a config whose head or FF dims don't divide the mesh axis
    degrades to replicated instead of crashing.
    """
    per_axis: List[List[object]] = []
    for size in pl.sizes:
        cands: List[object] = [B]
        cands += [S(d) for d in range(len(shape)) if shape[d] % size == 0]
        if allow_partial:
            cands.append(P)
        per_axis.append(cands)
    out = []
    for combo in itertools.product(*per_axis):
        if shard_shape(shape, combo, pl) is not None:
            out.append(tuple(combo))
    return out


def memory_bytes(shape, nd: NdSbp, pl: Placement, dtype_bytes: int = 2) -> int:
    """Per-device bytes of a tensor stored with this ND-SBP.

    A Broadcast or Partial axis charges the full extent (each device holds
    a complete copy or a complete partial sum); a Split axis charges
    ``1/size``.  An invalid (non-divisible) placement returns 2**60 so it
    can never win under a memory cap."""
    local = shard_shape(shape, nd, pl)
    if local is None:
        return 1 << 60
    n = dtype_bytes
    for d in local:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Boxing: per-axis SBP transitions and their collective cost
# ---------------------------------------------------------------------------

_TRANSITION = {
    # (src, dst) -> collective kind; None = impossible, "" = free
    ("B", "B"): "",
    ("B", "S"): "slice",          # local slicing, free
    ("S", "S"): "all-to-all",     # different split axes
    ("S", "B"): "all-gather",
    ("P", "B"): "all-reduce",
    ("P", "S"): "reduce-scatter",
    # P sources can also stay partial (free) — handled by equality below
}


def _kindof(sbp) -> str:
    if isinstance(sbp, S):
        return "S"
    return "B" if sbp is B else "P"


def boxing_ops(src: NdSbp, dst: NdSbp, shape, pl: Placement,
               dtype_bytes: int = 2):
    """List of (collective kind, payload bytes, group size) per mesh axis for
    converting src -> dst.  Returns None if the conversion is impossible."""
    ops = []
    for i, (a, b, size) in enumerate(zip(src, dst, pl.sizes)):
        if a == b or size == 1:
            continue
        ka, kb = _kindof(a), _kindof(b)
        if ka == "S" and kb == "S" and a.axis == b.axis:
            continue
        kind = _TRANSITION.get((ka, kb))
        if kind is None:
            return None
        if kind in ("", "slice"):
            ops.append(("slice", 0, size))
            continue
        # payload = the local tensor being exchanged on this axis: use the
        # destination-local size for gathers, source-local for scatters.
        local_src = shard_shape(shape, src, pl)
        if local_src is None:
            return None
        nbytes = dtype_bytes
        for d in local_src:
            nbytes *= d
        if kind == "all-gather":
            nbytes *= size  # gathered result
        ops.append((kind, nbytes, size))
    return ops


def boxing_cost(src: NdSbp, dst: NdSbp, shape, pl: Placement,
                dtype_bytes: int = 2) -> Optional[float]:
    """Alpha-beta time (seconds) to convert ``src -> dst``, or None if no
    collective implements the transition (e.g. ``B -> P``).

    Per ring-collective convention, each device moves ``(g-1)/g`` of the
    payload once for all-gather / reduce-scatter / all-to-all and twice for
    all-reduce (reduce-scatter + all-gather), plus an ``ALPHA`` launch
    latency per collective.  This is the term that makes one row-parallel
    all-reduce beat two column-parallel all-gathers in the TP layout
    search."""
    ops = boxing_ops(src, dst, shape, pl, dtype_bytes)
    if ops is None:
        return None
    t = 0.0
    for kind, nbytes, g in ops:
        if kind == "slice" or g <= 1:
            continue
        frac = (g - 1) / g
        factor = {"all-gather": frac, "reduce-scatter": frac,
                  "all-reduce": 2 * frac, "all-to-all": frac}[kind]
        t += ALPHA + factor * nbytes / ICI_BW
    return t


# ---------------------------------------------------------------------------
# SBP signatures (per mesh axis; ND composition is orthogonal)
# ---------------------------------------------------------------------------

def matmul_axis_signatures() -> List[Tuple[Tuple[str, ...], str]]:
    """1-axis signatures for C[M,N] = A[M,K] @ B[K,N], encoded symbolically:
    entries are 'S0'/'S1'/'B'/'P' per operand and the output."""
    return [
        (("S0", "B"), "S0"),    # split rows (data parallel)
        (("B", "S1"), "S1"),    # split cols (tensor parallel out-dim)
        (("S1", "S0"), "P"),    # split contraction -> partial
        (("B", "B"), "B"),
        (("P", "B"), "P"),
        (("B", "P"), "P"),
    ]


def elementwise_axis_signatures(arity: int, linear: bool
                                ) -> List[Tuple[Tuple[str, ...], str]]:
    """1-axis signatures for elementwise ops: any split or broadcast state
    passes through unchanged.  Only *linear* ops (add-like) may consume
    Partial inputs — a nonlinearity applied to unreduced partial sums would
    compute ``f(a) + f(b) != f(a + b)``, so P must be boxed to B first."""
    sigs = []
    for tag in ("S0", "S1", "B"):
        sigs.append((tuple(tag for _ in range(arity)), tag))
    if linear:  # add-like ops propagate partial values
        sigs.append((tuple("P" for _ in range(arity)), "P"))
        if arity == 2:
            sigs.append((("P", "B"), "P"))
            sigs.append((("B", "P"), "P"))
    return sigs


def resolve_tag(tag: str, ndim: int):
    """Symbolic signature tag ('B'/'P'/'S<d>') -> SBP object, or None when
    the split dim doesn't exist on an ``ndim``-dimensional output."""
    if tag == "B":
        return B
    if tag == "P":
        return P
    d = int(tag[1:])
    return S(d) if d < ndim else None
