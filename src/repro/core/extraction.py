"""Optimal-program extraction from a saturated e-graph (§3.1.1).

Two extractors:

  * ``greedy_extract`` — fixpoint DP over e-classes (egg-style); fast, optimal
    for tree costs, used as cross-check and as the WPMaxSAT warm start.
  * ``wpmaxsat_extract`` — Weighted Partial MaxSAT formulation: one selection
    variable per e-node, hard clauses encode "an active class selects >= 1
    node" + "selected node activates child classes", soft clauses charge each
    node's roofline cost.  Cycles (created by saturation) are eliminated
    CEGAR-style: if the chosen subgraph is cyclic, a blocking clause is added
    and the solver re-runs.

Both return (total_cost, {eclass_id: chosen ENode}).
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

from repro.core.cost_model import node_cost
from repro.core.egraph import EGraph, ENode
from repro.core.sat import wpmaxsat


def greedy_extract(eg: EGraph, root: int,
                   cost_fn: Optional[Callable] = None):
    cost_fn = cost_fn or (lambda n: node_cost(eg, n))
    root = eg.find(root)
    best: Dict[int, Tuple[float, ENode]] = {}
    changed = True
    it = 0
    while changed and it < 10 * len(eg.classes) + 10:
        changed = False
        it += 1
        for cid in eg.eclasses():
            for node in eg.nodes(cid):
                c = cost_fn(node)
                ok = True
                for ch in node.children:
                    ch = eg.find(ch)
                    if ch not in best:
                        ok = False
                        break
                    c += best[ch][0]
                if ok and (cid not in best or c < best[cid][0] - 1e-15):
                    best[cid] = (c, node)
                    changed = True
    if root not in best:
        raise ValueError("root not extractable")
    choice = {}

    def walk(cid):
        cid = eg.find(cid)
        if cid in choice:
            return
        _, node = best[cid]
        choice[cid] = node
        for ch in node.children:
            walk(ch)
    walk(root)
    # DAG cost: each selected class counted once
    total = sum(cost_fn(n) for n in choice.values())
    return total, choice


def _has_cycle(eg: EGraph, choice: Dict[int, ENode], root: int):
    """Return a cycle (list of class ids) in the selected subgraph, or None."""
    WHITE, GRAY, BLACK = 0, 1, 2
    color = {}
    stack_path = []

    def dfs(cid):
        cid = eg.find(cid)
        c = color.get(cid, WHITE)
        if c == GRAY:
            i = stack_path.index(cid)
            return stack_path[i:]
        if c == BLACK or cid not in choice:
            return None
        color[cid] = GRAY
        stack_path.append(cid)
        for ch in choice[cid].children:
            cyc = dfs(ch)
            if cyc:
                return cyc
        stack_path.pop()
        color[cid] = BLACK
        return None

    return dfs(root)


def wpmaxsat_extract(eg: EGraph, root: int,
                     cost_fn: Optional[Callable] = None,
                     memory_limit: Optional[Tuple[Callable, float]] = None,
                     max_cegar_rounds: int = 20):
    """WPMaxSAT extraction with optional hard memory constraint.

    memory_limit: (mem_fn(node) -> bytes, capacity) — enforced CEGAR-style:
    oversized selections are blocked and the solver re-runs (§3.1.3's hard
    memory-capacity constraint).
    """
    cost_fn = cost_fn or (lambda n: node_cost(eg, n))
    root = eg.find(root)

    # variable numbering
    node_var: Dict[Tuple[int, ENode], int] = {}
    class_var: Dict[int, int] = {}
    v = 0
    for cid in eg.eclasses():
        v += 1
        class_var[cid] = v
        for n in eg.nodes(cid):
            v += 1
            node_var[(cid, n)] = v
    n_vars = v

    hard = []
    # root class is active
    hard.append([class_var[root]])
    for cid in eg.eclasses():
        nodes = list(eg.nodes(cid))
        # class active -> one of its nodes selected
        hard.append([-class_var[cid]] + [node_var[(cid, n)] for n in nodes])
        for n in nodes:
            nv = node_var[(cid, n)]
            # node selected -> its class active
            hard.append([-nv, class_var[cid]])
            # node selected -> child classes active
            for ch in n.children:
                hard.append([-nv, class_var[eg.find(ch)]])

    # soft: each node selection costs its roofline latency (scaled to ints-ish)
    soft = []
    for (cid, n), nv in node_var.items():
        w = max(cost_fn(n), 0.0)
        if w > 0:
            soft.append((-nv, w))

    # warm start upper bound from greedy (only usable when no memory cap:
    # the cap may force strictly costlier solutions than the greedy optimum)
    greedy_sol = None
    try:
        greedy_sol = greedy_extract(eg, root, cost_fn)
    except ValueError:
        pass
    ub = greedy_sol[0] + 1e-9 if (greedy_sol and memory_limit is None) else None

    # admissible extra lower bound: every active class with no node selected
    # yet must eventually pay at least its cheapest not-yet-excluded node.
    class_nodes = {cid: [(cost_fn(n), node_var[(cid, n)])
                         for n in eg.nodes(cid)] for cid in eg.eclasses()}
    for v_ in class_nodes.values():
        v_.sort()

    def lb_extra(assign):
        extra = 0.0
        for cid, entries in class_nodes.items():
            if not assign.get(class_var[cid]):
                continue
            picked = False
            cheapest = None
            for c, nv in entries:
                st = assign.get(nv)
                if st is True:
                    picked = True
                    break
                if st is None and cheapest is None:
                    cheapest = c
            if not picked and cheapest:
                extra += cheapest
        return extra

    for _ in range(max_cegar_rounds):
        res = wpmaxsat(n_vars, hard, soft, ub_init=ub, lb_extra=lb_extra)
        if res is None:
            if greedy_sol is not None and memory_limit is None:
                # SAT search found nothing better than the greedy warm start
                total, choice = greedy_sol
                cyc = _has_cycle(eg, choice, root)
                if cyc is None:
                    return total, choice
            raise ValueError("extraction UNSAT (or infeasible under memory cap)")
        choice: Dict[int, ENode] = {}
        for (cid, n), nv in node_var.items():
            if res.assignment.get(nv):
                # keep the cheapest selected node per class
                if cid not in choice or cost_fn(n) < cost_fn(choice[cid]):
                    choice[cid] = n
        cyc = _has_cycle(eg, choice, root)
        if cyc is not None:
            # block this cyclic combination
            hard.append([-node_var[(c, choice[c])] for c in cyc])
            continue
        if memory_limit is not None:
            mem_fn, cap = memory_limit
            reach = _reachable(eg, choice, root)
            used = sum(mem_fn(choice[c]) for c in reach)
            if used > cap:
                # block the MINIMAL over-capacity prefix (strongest clause):
                # the largest-memory selected nodes that together exceed cap
                by_mem = sorted(reach, key=lambda c: -mem_fn(choice[c]))
                prefix, s = [], 0
                for c in by_mem:
                    prefix.append(c)
                    s += mem_fn(choice[c])
                    if s > cap:
                        break
                hard.append([-node_var[(c, choice[c])] for c in prefix])
                continue
        reach = _reachable(eg, choice, root)
        total = sum(cost_fn(choice[c]) for c in reach)
        return total, {c: choice[c] for c in reach}
    raise ValueError("CEGAR rounds exhausted")


def _reachable(eg, choice, root):
    seen = set()

    def walk(cid):
        cid = eg.find(cid)
        if cid in seen or cid not in choice:
            return
        seen.add(cid)
        for ch in choice[cid].children:
            walk(ch)
    walk(root)
    return seen


def branch_bound_extract(eg: EGraph, root: int,
                         cost_fn: Optional[Callable] = None,
                         mem_fn: Optional[Callable] = None,
                         cap: Optional[float] = None,
                         node_budget: int = 500000):
    """Exact branch & bound extraction specialized to e-graphs.

    Explores only classes reachable from the root, selecting one e-node per
    class in DFS order.  Monotone accumulation of cost and memory makes both
    the cost bound and the hard memory cap ({mem_fn, cap}) strong pruners —
    this is what makes the §3.1.3 memory-constrained extraction practical at
    distribution-search sizes (the generic WPMaxSAT handles the
    unconstrained case).  Returns (cost, {class: node}).
    """
    cost_fn = cost_fn or (lambda n: node_cost(eg, n))
    root = eg.find(root)

    # admissible per-class lower bound from the greedy DP (tree-cost)
    dp: Dict[int, float] = {}
    changed = True
    while changed:
        changed = False
        for cid in eg.eclasses():
            for n in eg.nodes(cid):
                c = cost_fn(n)
                ok = True
                for ch in n.children:
                    ch = eg.find(ch)
                    if ch not in dp:
                        ok = False
                        break
                    c += dp[ch]
                if ok and (cid not in dp or c < dp[cid] - 1e-18):
                    dp[cid] = c
                    changed = True
    if root not in dp:
        raise ValueError("root not extractable")

    best: List = [None, float("inf")]
    visited = [0]

    def bb(pending: List[int], chosen: Dict[int, ENode], cost: float,
           mem: float):
        visited[0] += 1
        if visited[0] > node_budget:
            return
        if cap is not None and mem > cap:
            return
        # admissible bound: the most expensive unresolved class must be paid
        lb = cost + max((dp.get(c, 0.0) for c in pending if c not in chosen),
                        default=0.0)
        if lb >= best[1]:
            return
        while pending and eg.find(pending[-1]) in chosen:
            pending = pending[:-1]
        if not pending:
            if _has_cycle(eg, chosen, root) is None:
                best[0], best[1] = dict(chosen), cost
            return
        cid = eg.find(pending[-1])
        rest = pending[:-1]
        nodes = sorted(eg.nodes(cid),
                       key=lambda n: cost_fn(n) + sum(
                           dp.get(eg.find(c), 0.0) for c in n.children))
        for n in nodes:
            ok = all(eg.find(c) in dp for c in n.children)
            if not ok:
                continue
            chosen[cid] = n
            new_pending = rest + [eg.find(c) for c in n.children
                                  if eg.find(c) not in chosen]
            m = mem_fn(n) if mem_fn else 0.0
            bb(new_pending, chosen, cost + cost_fn(n), mem + m)
            del chosen[cid]

    bb([root], {}, 0.0, 0.0)
    if best[0] is None:
        raise ValueError("branch-bound extraction found no feasible solution")
    reach = _reachable(eg, best[0], root)
    return best[1], {c: best[0][c] for c in reach}


def extract_term(eg: EGraph, root: int, choice: Dict[int, ENode]):
    """Materialize the chosen subgraph back into a Term tree."""
    from repro.core.tensor_ir import Term

    memo = {}

    def build(cid):
        cid = eg.find(cid)
        if cid in memo:
            return memo[cid]
        n = choice[cid]
        t = Term(n.op, tuple(build(c) for c in n.children), n.attrs)
        memo[cid] = t
        return t

    return build(root)
