"""E-graph with equality saturation (§3.1.1).

egg-style implementation: union-find over e-class ids, hash-consed e-nodes,
congruence closure via rebuild(), and a saturation driver.  An e-class
analysis carries (shape, dtype) — rewrites must be shape-preserving, and the
analysis is checked on every union.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Set, Tuple

from repro.core.tensor_ir import Term, infer_shape


@dataclasses.dataclass(frozen=True)
class ENode:
    op: str
    children: Tuple[int, ...]      # e-class ids
    attrs: Tuple[Tuple[str, Any], ...] = ()

    def attr(self, key, default=None):
        for k, v in self.attrs:
            if k == key:
                return v
        return default

    def canonicalize(self, find) -> "ENode":
        return ENode(self.op, tuple(find(c) for c in self.children), self.attrs)


class EGraph:
    def __init__(self):
        self._parent: List[int] = []
        self.hashcons: Dict[ENode, int] = {}
        self.classes: Dict[int, Set[ENode]] = {}
        self.analysis: Dict[int, Tuple[Tuple[int, ...], str]] = {}
        self.worklist: List[int] = []
        self.n_unions = 0

    # -- union find --------------------------------------------------------
    def find(self, a: int) -> int:
        while self._parent[a] != a:
            self._parent[a] = self._parent[self._parent[a]]
            a = self._parent[a]
        return a

    def _new_class(self, node: ENode, shape, dtype) -> int:
        cid = len(self._parent)
        self._parent.append(cid)
        self.classes[cid] = {node}
        self.analysis[cid] = (shape, dtype)
        return cid

    # -- add / union -------------------------------------------------------
    def add(self, node: ENode) -> int:
        node = node.canonicalize(self.find)
        if node in self.hashcons:
            return self.find(self.hashcons[node])
        child_shapes = tuple(self.analysis[c][0] for c in node.children)
        dtype = (self.analysis[node.children[0]][1]
                 if node.children else node.attr("dtype", "bf16"))
        shape = infer_shape(node.op, child_shapes, dict(node.attrs))
        cid = self._new_class(node, shape, dtype)
        self.hashcons[node] = cid
        return cid

    def add_term(self, t: Term) -> int:
        ids = tuple(self.add_term(c) for c in t.children)
        return self.add(ENode(t.op, ids, t.attrs))

    def union(self, a: int, b: int) -> int:
        a, b = self.find(a), self.find(b)
        if a == b:
            return a
        sa, sb = self.analysis[a], self.analysis[b]
        if sa[0] != sb[0]:
            raise ValueError(
                f"union of classes with different shapes: {sa[0]} vs {sb[0]}")
        # merge smaller into larger
        if len(self.classes[a]) < len(self.classes[b]):
            a, b = b, a
        self._parent[b] = a
        self.classes[a] |= self.classes[b]
        del self.classes[b]
        del self.analysis[b]
        self.worklist.append(a)
        self.n_unions += 1
        return a

    # -- congruence closure --------------------------------------------------
    def rebuild(self):
        while self.worklist:
            todo, self.worklist = self.worklist, []
            # re-canonicalize the hashcons; union congruent nodes
            new_hashcons: Dict[ENode, int] = {}
            pending: List[Tuple[int, int]] = []
            for node, cid in self.hashcons.items():
                nn = node.canonicalize(self.find)
                nc = self.find(cid)
                if nn in new_hashcons and new_hashcons[nn] != nc:
                    pending.append((new_hashcons[nn], nc))
                new_hashcons[nn] = self.find(new_hashcons.get(nn, nc))
            self.hashcons = new_hashcons
            for x, y in pending:
                self.union(x, y)
            # rebuild class node sets
            new_classes: Dict[int, Set[ENode]] = {}
            for node, cid in self.hashcons.items():
                new_classes.setdefault(self.find(cid), set()).add(node)
            for cid in list(self.classes):
                root = self.find(cid)
                if root not in new_classes:
                    new_classes[root] = {n.canonicalize(self.find)
                                         for n in self.classes[cid]}
            stale = [c for c in self.classes if c != self.find(c)]
            for cid, nodes in new_classes.items():
                self.classes[cid] = nodes
            for c in stale:
                self.classes.pop(c, None)

    # -- queries -------------------------------------------------------------
    def eclasses(self) -> Iterable[int]:
        return list(self.classes.keys())

    def nodes(self, cid: int) -> Iterable[ENode]:
        return list(self.classes[self.find(cid)])

    def shape(self, cid: int) -> Tuple[int, ...]:
        return self.analysis[self.find(cid)][0]

    def size(self) -> int:
        return sum(len(v) for v in self.classes.values())

    # -- saturation ----------------------------------------------------------
    def saturate(self, rules: List["Rule"], max_iters: int = 12,
                 node_limit: int = 20000) -> Dict[str, int]:
        """Apply all rules to all (class, node) pairs until fixpoint/limits."""
        stats = {"iters": 0, "applications": 0}
        for it in range(max_iters):
            stats["iters"] = it + 1
            matches = []
            for rule in rules:
                for cid in self.eclasses():
                    for node in self.nodes(cid):
                        for new_term in rule.apply(self, cid, node):
                            matches.append((cid, new_term))
            before = self.n_unions
            for cid, term in matches:
                if self.size() > node_limit:
                    break
                new_id = self.add_term_from_ids(term)
                self.union(self.find(cid), new_id)
                stats["applications"] += 1
            self.rebuild()
            if self.n_unions == before or self.size() > node_limit:
                break
        return stats

    def add_term_from_ids(self, t) -> int:
        """Add a 'mixed term': children may be Terms, ints (e-class ids), or
        nested mixed terms — the form rewrite rules produce."""
        if isinstance(t, int):
            return self.find(t)
        ids = tuple(self.add_term_from_ids(c) for c in t.children)
        return self.add(ENode(t.op, ids, t.attrs))


@dataclasses.dataclass(frozen=True)
class MixedTerm:
    """Term whose children can be e-class ids (ints) or MixedTerms."""
    op: str
    children: tuple = ()
    attrs: Tuple[Tuple[str, Any], ...] = ()


def M(op: str, *children, **attrs) -> MixedTerm:
    return MixedTerm(op, tuple(children), tuple(sorted(attrs.items())))
