"""Auto Vectorize (§3.1.2): MetaPackOperation + FoldNopPack.

MetaPackOperation injects, for every logical op, hardware-specific packed
variants wrapped in pack/unpack:

  * MXU blocked layout: lanes (128, 128) — feeds ``packed_matmul``
  * VPU flat layout:    lanes (8, 128)   — feeds ``packed_unary/binary``
  * MXU-block elementwise: element-wise ops can also run directly on the
    (128,128) blocked layout by treating each block as a contiguous vector —
    the "pass-through layout" of Fig. 3.

FoldNopPack cancels pack(unpack(x)) pairs, which is what lets a blocked
layout flow through MatMul -> Exp -> MatMul without round-tripping to the
logical layout.  Extraction (roofline-weighted WPMaxSAT) then picks the best
variant mix globally.

On TPU the extracted packed graph maps onto the Pallas kernels in
``repro.kernels`` (packed_matmul -> matmul kernel block tiles; packed chains
-> fused flash attention); see ``repro.core.codegen``.
"""
from __future__ import annotations

from typing import Iterable, List

from repro.core.egraph import EGraph, ENode, M, MixedTerm
from repro.core.extraction import extract_term, greedy_extract, wpmaxsat_extract
from repro.core.rewrite import Rule, TRANSPOSE_RULES
from repro.core.tensor_ir import Term

MXU_LANES = (128, 128)
VPU_LANES = (8, 128)


def _divisible(shape, lanes, axes) -> bool:
    return all(ax < len(shape) and shape[ax] % lane == 0
               for lane, ax in zip(lanes, axes))


class MetaPackOperation(Rule):
    """Op() -> Unpack(PackedOp(Pack(arg_i, lanes_i, axes_i)...), lanes, axes)."""
    name = "meta-pack-operation"

    def apply(self, eg: EGraph, cid: int, node: ENode) -> Iterable[MixedTerm]:
        shape = eg.shape(cid)
        if len(shape) != 2:
            return
        if node.op == "matmul":
            a, b = node.children
            sa, sb = eg.shape(a), eg.shape(b)
            lm, lk = MXU_LANES
            ln = MXU_LANES[1]
            if (_divisible(sa, (lm, lk), (0, 1))
                    and _divisible(sb, (lk, ln), (0, 1))):
                yield M("unpack",
                        M("packed_matmul",
                          M("pack", a, lanes=(lm, lk), axes=(0, 1)),
                          M("pack", b, lanes=(lk, ln), axes=(0, 1))),
                        lanes=(lm, ln), axes=(0, 1))
        elif node.op in ("unary", "binary"):
            kind = node.attr("kind")
            for lanes in (VPU_LANES, MXU_LANES):
                if not _divisible(shape, lanes, (0, 1)):
                    continue
                packed_children = [M("pack", c, lanes=lanes, axes=(0, 1))
                                   for c in node.children]
                yield M("unpack",
                        M(f"packed_{node.op}", *packed_children, kind=kind),
                        lanes=lanes, axes=(0, 1))


class FoldNopPack(Rule):
    """Pack(Unpack(arg, lanes, axes), lanes, axes) -> arg."""
    name = "fold-nop-pack"

    def apply(self, eg: EGraph, cid: int, node: ENode):
        if node.op != "pack":
            return
        lanes, axes = node.attr("lanes"), node.attr("axes")
        for inner in eg.nodes(node.children[0]):
            if (inner.op == "unpack" and inner.attr("lanes") == lanes
                    and inner.attr("axes") == axes):
                yield inner.children[0]


VECTORIZE_RULES: List[Rule] = [MetaPackOperation(), FoldNopPack()]


def auto_vectorize(term: Term, use_sat: bool = True, max_iters: int = 8,
                   node_limit: int = 8000):
    """Saturate with vectorization (+ transpose) rules and extract the best
    packed program.  Returns (cost, packed Term, stats)."""
    eg = EGraph()
    root = eg.add_term(term)
    baseline, _ = greedy_extract(eg, root)
    stats = eg.saturate(VECTORIZE_RULES + TRANSPOSE_RULES,
                        max_iters=max_iters, node_limit=node_limit)
    if use_sat:
        cost, choice = wpmaxsat_extract(eg, root)
    else:
        cost, choice = greedy_extract(eg, root)
    stats["baseline_cost"] = baseline
    stats["optimized_cost"] = cost
    stats["egraph_size"] = eg.size()
    return cost, extract_term(eg, root, choice), stats


def count_ops(t: Term, *ops: str) -> int:
    return (t.op in ops) + sum(count_ops(c, *ops) for c in t.children)
