"""Flash attention Pallas kernel: the Fig. 3 "pass-through layout" chain
MatMul -> Exp -> MatMul fused in VMEM with an online softmax.

Grid: (batch*heads, q_blocks, kv_blocks) with kv innermost (sequential);
running (row-max, row-sum, accumulator) live in VMEM scratch across kv steps.
Causal masking skips fully-masked kv blocks via pl.when — for causal
attention, roughly half the grid does no work.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, causal: bool, q_offset: int,
                  block_q: int, block_kv: int, nkv: int):
    kv_idx = pl.program_id(2)
    q_idx = pl.program_id(1)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = q_offset + q_idx * block_q
    kv_start = kv_idx * block_kv
    # causal skip: block is live unless its first kv row is past the last q row
    live = (not causal) or (kv_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0]                      # (bq, hd)
        k = k_ref[0]                      # (bkv, hd)
        v = v_ref[0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kv_idx == nkv - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, q_offset: int = 0,
                           block_q: int = 512, block_kv: int = 1024,
                           interpret: bool = False) -> jax.Array:
    """q (BH, Sq, hd), k/v (BH, Skv, hd) -> (BH, Sq, hd)."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_kv = min(block_kv, skv)
    assert sq % block_q == 0 and skv % block_kv == 0
    scale = 1.0 / math.sqrt(hd)
    nkv = skv // block_kv
    grid = (bh, sq // block_q, nkv)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, block_q=block_q,
                          block_kv=block_kv, nkv=nkv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
