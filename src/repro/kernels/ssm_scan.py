"""Selective-scan (Mamba1) Pallas kernel: the SSM recurrence fused in VMEM.

h_t = a_t * h_{t-1} + b_t ;  y_t = <h_t, c_t>

The jnp reference materializes (T, D, N) state products in HBM; the kernel
keeps h resident in VMEM across the sequential time loop — the memory-bound
hot spot of the falcon-mamba arch (see §Roofline: mamba train is the most
memory-dominated cell).  Grid tiles the d_inner dim; time stays in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(a_ref, b_ref, c_ref, h0_ref, y_ref, hl_ref, h_ref, *, t_len: int):
    h_ref[...] = h0_ref[...]

    def step(t, _):
        h = a_ref[t] * h_ref[...] + b_ref[t]       # (bd, N)
        h_ref[...] = h
        y_ref[t] = jnp.sum(h * c_ref[t][None, :], axis=-1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, t_len, step, 0)
    hl_ref[...] = h_ref[...]


def ssm_scan_kernel(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array,
                    block_d: int = 512, interpret: bool = False):
    """a,b (T,D,N) f32; c (T,N) f32; h0 (D,N) f32 -> (y (T,D) f32, h_last (D,N)).

    Single-sequence chunk form: callers vmap over batch and lax.scan over
    chunks (mirrors the hierarchical scan in repro.models.mamba).
    """
    t_len, d, n = a.shape
    block_d = min(block_d, d)
    assert d % block_d == 0
    grid = (d // block_d,)
    y, hl = pl.pallas_call(
        functools.partial(_ssm_kernel, t_len=t_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((t_len, block_d, n), lambda i: (0, i, 0)),
            pl.BlockSpec((t_len, block_d, n), lambda i: (0, i, 0)),
            pl.BlockSpec((t_len, n), lambda i: (0, 0)),
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_len, block_d), lambda i: (0, i)),
            pl.BlockSpec((block_d, n), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, d), jnp.float32),
            jax.ShapeDtypeStruct((d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(a, b, c, h0)
    return y, hl


def ssm_scan_chunked(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array,
                     chunk: int, block_d: int = 512, interpret: bool = False):
    """Chunked-prefill entry: the full scan as a ``lax.scan`` of fused-kernel
    chunks with the recurrent state carried across chunk boundaries.

    a,b (T,D,N) f32; c (T,N) f32; h0 (D,N) f32 -> (y (T,D) f32, h_last).
    This is the serving shape: a prompt arrives in engine-sized chunks and
    each chunk's kernel launch resumes from the previous chunk's ``h_last``.
    A ragged tail is padded with the scan identity (a=1, b=0) — exact, not
    approximate: ``1*h + 0`` is bitwise ``h``, so ``h_last`` and the valid
    rows of ``y`` match the unchunked kernel.
    """
    t_len, d, n = a.shape
    assert chunk >= 1
    pad = (-t_len) % chunk
    if pad:
        a = jnp.concatenate([a, jnp.ones((pad, d, n), a.dtype)], axis=0)
        b = jnp.concatenate([b, jnp.zeros((pad, d, n), b.dtype)], axis=0)
        c = jnp.concatenate([c, jnp.zeros((pad, n), c.dtype)], axis=0)
    n_chunks = (t_len + pad) // chunk

    def step(h, xs):
        at, bt, ct = xs
        y, hl = ssm_scan_kernel(at, bt, ct, h, block_d=block_d,
                                interpret=interpret)
        return hl, y

    h_last, ys = jax.lax.scan(
        step, h0, (a.reshape(n_chunks, chunk, d, n),
                   b.reshape(n_chunks, chunk, d, n),
                   c.reshape(n_chunks, chunk, n)))
    return ys.reshape(-1, d)[:t_len], h_last
