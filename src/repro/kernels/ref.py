"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q (BH,Sq,hd), k/v (BH,Skv,hd): exact softmax attention in f32."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def _paged_masked_attention(q, k_pages, v_pages, block_tables, qpos, kv_lens):
    """Dense-gather oracle core: q (B,KV,R,hd) with R query rows grouped
    under each KV head, per-row causal bound qpos (B,R), span length
    kv_lens (B,) -> (B,KV,R,hd) in f32."""
    b, kv, r, hd = q.shape
    bs = k_pages.shape[1]
    m = block_tables.shape[1]
    kg = k_pages[block_tables].reshape(b, m * bs, kv, hd).astype(jnp.float32)
    vg = v_pages[block_tables].reshape(b, m * bs, kv, hd).astype(jnp.float32)
    s = jnp.einsum("bkrd,bskd->bkrs", q.astype(jnp.float32), kg) \
        / math.sqrt(hd)
    kpos = jnp.arange(m * bs)[None, None, None, :]
    live = (kpos <= qpos[:, None, :, None]) & \
           (kpos < kv_lens[:, None, None, None])
    p = jax.nn.softmax(jnp.where(live, s, -1e30), axis=-1)
    return jnp.einsum("bkrs,bskd->bkrd", p, vg)


def paged_attention_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                        block_tables: jax.Array, seq_lens: jax.Array
                        ) -> jax.Array:
    """Decode oracle for ``ops.paged_attention``: gather the full span and
    run exact masked softmax in f32.  q (B,1,H,hd) -> (B,1,H,hd)."""
    b, _, h, hd = q.shape
    kv = k_pages.shape[2]
    group = h // kv
    qg = q.reshape(b, kv, group, hd)
    qpos = jnp.broadcast_to((seq_lens - 1)[:, None], (b, group))
    o = _paged_masked_attention(qg, k_pages, v_pages, block_tables,
                                qpos, seq_lens)
    return o.reshape(b, 1, h, hd).astype(q.dtype)


def paged_attention_chunk_ref(q: jax.Array, k_pages: jax.Array,
                              v_pages: jax.Array, block_tables: jax.Array,
                              chunk_pos: jax.Array, kv_lens: jax.Array
                              ) -> jax.Array:
    """Chunked-prefill oracle for ``ops.paged_attention_chunk``:
    q (B,C,H,hd), per-token absolute positions chunk_pos (C,)."""
    b, c, h, hd = q.shape
    kv = k_pages.shape[2]
    group = h // kv
    qg = q.transpose(0, 2, 1, 3).reshape(b, kv, group * c, hd)
    qpos = jnp.broadcast_to(jnp.tile(chunk_pos, (group,))[None, :],
                            (b, group * c))
    o = _paged_masked_attention(qg, k_pages, v_pages, block_tables,
                                qpos, kv_lens)
    return o.reshape(b, kv, group, c, hd).transpose(0, 3, 1, 2, 4
                                                    ).reshape(b, c, h, hd
                                                              ).astype(q.dtype)


def lora_shrink_ref(x: jax.Array, a_slab: jax.Array, idx: jax.Array
                    ) -> jax.Array:
    """Dense-gather oracle for ``ops.lora_shrink``: x (T,d), a_slab (S,d,R),
    idx (T,) int32 (-1 = no adapter) -> (T,R) f32.  Gathers each row's full
    adapter matrix and masks no-adapter rows to exact zero."""
    a = a_slab[jnp.maximum(idx, 0)].astype(jnp.float32)       # (T, d, R)
    h = jnp.einsum("td,tdr->tr", x.astype(jnp.float32), a)
    return jnp.where((idx >= 0)[:, None], h, 0.0)


def lora_expand_ref(h: jax.Array, b_slab: jax.Array, idx: jax.Array,
                    out_dtype=None) -> jax.Array:
    """Dense-gather oracle for ``ops.lora_expand``: h (T,R) f32,
    b_slab (S,R,O), idx (T,) -> (T,O)."""
    bm = b_slab[jnp.maximum(idx, 0)].astype(jnp.float32)      # (T, R, O)
    y = jnp.einsum("tr,tro->to", h.astype(jnp.float32), bm)
    y = jnp.where((idx >= 0)[:, None], y, 0.0)
    return y.astype(out_dtype or h.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def ssm_scan_ref(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array):
    """Sequential reference of the selective scan."""
    def step(h, xs):
        at, bt, ct = xs
        h = at * h + bt
        return h, jnp.sum(h * ct[None, :], axis=-1)
    h_last, y = jax.lax.scan(step, h0, (a, b, c))
    return y, h_last


def ssm_scan_chunked_ref(a: jax.Array, b: jax.Array, c: jax.Array,
                         h0: jax.Array, chunk: int):
    """Oracle for ``ops.ssm_scan_chunked``: a python loop of sequential
    scans over ``chunk``-sized slices, each resuming from the previous
    slice's final state — the chunked-prefill carry contract spelled out."""
    t_len = a.shape[0]
    ys, h = [], h0
    for s in range(0, t_len, chunk):
        y, h = ssm_scan_ref(a[s:s + chunk], b[s:s + chunk], c[s:s + chunk], h)
        ys.append(y)
    return jnp.concatenate(ys, axis=0), h
