"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, q_offset: int = 0) -> jax.Array:
    """q (BH,Sq,hd), k/v (BH,Skv,hd): exact softmax attention in f32."""
    bh, sq, hd = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(qpos >= kpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def ssm_scan_ref(a: jax.Array, b: jax.Array, c: jax.Array, h0: jax.Array):
    """Sequential reference of the selective scan."""
    def step(h, xs):
        at, bt, ct = xs
        h = at * h + bt
        return h, jnp.sum(h * ct[None, :], axis=-1)
    h_last, y = jax.lax.scan(step, h0, (a, b, c))
    return y, h_last
