"""Pallas TPU kernels (NTT μkernel layer): pl.pallas_call + BlockSpec VMEM
tiling, validated against the pure-jnp oracles in ref.py (interpret mode on
CPU).  Kernels: matmul, flash_attention, paged_attention, rmsnorm,
ssm_scan."""
from repro.kernels import ops, ref  # noqa: F401
