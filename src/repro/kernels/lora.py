"""Segmented gather-BGMV LoRA Pallas kernels: every row of a batch applies
its *own* low-rank adapter in one call.

Multi-tenant decode batches mix requests served by different LoRA adapters
(and base-only rows).  The dense approach gathers each row's ``(A, B)`` pair
out of the adapter slab into per-row matrices and runs a batched matmul —
O(rows * d * r) HBM traffic for the gather alone, repeated every step.
Punica's insight (SGMV/BGMV) is that the gather belongs *inside* the kernel:
the grid walks batch rows, and each grid step DMAs exactly one adapter's
weight tile straight from the slab into VMEM, selected by a scalar-prefetched
per-row adapter index — the same trick ``paged_attention.py`` uses to walk
block tables.

Two kernels factor the delta ``y = (x @ A) @ B``:

* ``lora_shrink``: ``x (T, d)`` against slab ``A (S, d, R)`` with per-row
  slot indices ``idx (T,)`` -> ``h (T, R)`` in f32.  Rows with ``idx < 0``
  (no adapter) produce exact zeros.
* ``lora_expand``: ``h (T, R)`` against slab ``B (S, R, O)`` -> ``y (T, O)``
  in the requested dtype, tiled over the output features by ``block_out``
  (chosen by Auto Schedule, see ``repro.core.codegen.lora_tiles``).

Ragged ranks cost nothing: the slab pads every adapter to the shared rank
slot ``R`` with zeros, so a rank-8 adapter in a rank-16 slot contributes
zero through the padding — and a rank-0 adapter is all padding, making its
delta exactly zero (the token-identity contract for rank 0).

TPU tiling note: one grid step touches a ``(d, R)`` or ``(R, block_out)``
weight tile; R is sublane-padded (multiple of 8) by the AdapterStore, and
``block_out`` is lane-aligned by the plan, so Mosaic pads at most the tiny
rank axis.  CPU runs in interpret mode like every other kernel here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Auto Schedule's tile choice for the expand kernel, set at trace time by the
# serve engine (repro.core.codegen.lora_tiles -> set_lora_plan) exactly like
# attention.set_paged_plan routes pages_per_fetch.  Direct callers (tests,
# one-off scripts) get the default.
_LORA_PLAN = {"block_out": 256}


def set_lora_plan(block_out: int) -> None:
    _LORA_PLAN["block_out"] = max(1, int(block_out))


def lora_plan_block_out() -> int:
    return _LORA_PLAN["block_out"]


def _shrink_kernel(idx_ref, x_ref, a_ref, o_ref):
    t = pl.program_id(0)
    valid = idx_ref[t] >= 0
    h = jnp.dot(x_ref[...].astype(jnp.float32),
                a_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(valid, h, 0.0)


def lora_shrink_kernel(x: jax.Array, a_slab: jax.Array, idx: jax.Array,
                       interpret: bool = False) -> jax.Array:
    """x (T, d); a_slab (S, d, R); idx (T,) int32 slot per row, -1 = no
    adapter -> (T, R) f32.  Each grid step DMAs one row's adapter tile
    ``A[idx[t]]`` (rows with idx < 0 read slot 0 and mask to zero)."""
    t, d = x.shape
    _, d2, r = a_slab.shape
    assert d == d2, f"x feature dim {d} != slab {d2}"
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,      # idx
        grid=(t,),
        in_specs=[
            pl.BlockSpec((1, d), lambda t, idx: (t, 0)),
            pl.BlockSpec((1, d, r),
                         lambda t, idx: (jnp.maximum(idx[t], 0), 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, r), lambda t, idx: (t, 0)),
    )
    return pl.pallas_call(
        _shrink_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, r), jnp.float32),
        interpret=interpret,
    )(idx.astype(jnp.int32), x, a_slab)


def _expand_kernel(idx_ref, h_ref, b_ref, o_ref):
    t = pl.program_id(0)
    valid = idx_ref[t] >= 0
    y = jnp.dot(h_ref[...], b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(valid, y, 0.0).astype(o_ref.dtype)


def lora_expand_kernel(h: jax.Array, b_slab: jax.Array, idx: jax.Array,
                       out_dtype, block_out: int = 256,
                       interpret: bool = False) -> jax.Array:
    """h (T, R) f32; b_slab (S, R, O); idx (T,) int32 -> (T, O) out_dtype.
    The grid tiles the output features by ``block_out`` so one step's
    weight tile is ``(R, block_out)`` regardless of projection width."""
    t, r = h.shape
    _, r2, o = b_slab.shape
    assert r == r2, f"h rank {r} != slab {r2}"
    bo = max(1, min(block_out, o))
    pad = (-o) % bo
    if pad:
        b_slab = jnp.pad(b_slab, ((0, 0), (0, 0), (0, pad)))
    steps = (o + pad) // bo
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,      # idx
        grid=(t, steps),
        in_specs=[
            pl.BlockSpec((1, r), lambda t, j, idx: (t, 0)),
            pl.BlockSpec((1, r, bo),
                         lambda t, j, idx: (jnp.maximum(idx[t], 0), 0, j)),
        ],
        out_specs=pl.BlockSpec((1, bo), lambda t, j, idx: (t, j)),
    )
    y = pl.pallas_call(
        _expand_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, o + pad), out_dtype),
        interpret=interpret,
    )(idx.astype(jnp.int32), h, b_slab)
    return y[:, :o] if pad else y
