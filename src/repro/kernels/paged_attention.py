"""Paged flash-attention Pallas kernel: stream KV pages through the block
table instead of materializing the gathered span.

The dense fallback (``paged_gather`` in ``repro.models.attention``) copies the
*entire* ``pages[tables]`` span into a ``(B, M*bs, KV, hd)`` tensor on every
decode step — O(max_len) HBM traffic per token.  This kernel walks each
request's block table in SMEM (``PrefetchScalarGridSpec`` scalar prefetch, so
the table is resident before the first tile DMA is issued), streams K/V one
page at a time straight from the pool into VMEM, and folds each page into a
``flash_attention.py``-style online softmax (running row-max / row-sum /
accumulator living in VMEM scratch across grid steps).  Pages past a
request's length — including null-padded table entries, which sit at the tail
by construction — are skipped entirely via ``pl.when``, so per-token traffic
is O(resident pages), not O(table capacity).

Layout:  pages stay in their native pool layout ``(N, bs, KV, hd)``; a grid
step fetches the ``(bs, KV, hd)`` slab of one page (all KV heads of one
block, contiguous in HBM).  Queries arrive grouped by KV head as
``(B, KV, R, hd)`` where ``R = group * C`` rows share one KV head (``group``
= GQA ratio, ``C`` = query tokens: 1 for decode, the chunk length for chunked
prefill).  Per-row causal bounds ``q_pos`` unify both callers: decode rows
all carry ``seq_len - 1``; prefill rows carry their absolute position.

``pages_per_fetch`` (chosen by the Auto Schedule cost model, see
``repro.core.codegen.paged_pages_per_fetch``) issues that many independent
page DMAs per grid step — on TPU the pipelined fetches hide each other's
latency; the online softmax folds them sequentially either way.

TPU tiling note: the per-page tile is ``(bs, KV, hd)`` with ``hd`` typically
64–128; Mosaic pads sub-(8,128) tiles, which wastes some VMEM at small block
sizes but keeps the pool layout untouched (no transpose of the whole pool
per step — that would reintroduce the O(pool) traffic this kernel removes).

Mesh-sharded serving note: when the serve engine shards the KV pool on the
kv-heads axis (``repro.models.attention.set_serve_mesh``), this kernel is
invoked *inside* shard_map with the per-shard page slab ``(N, bs, KV/n,
hd)`` and the query heads grouped under those KV heads.  Nothing here
changes: the grid is already per KV head, so each shard simply runs a
narrower grid over its own heads — the head axis partitions the kernel
cleanly, which is exactly why the pool shards on it.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, qpos_ref, q_ref, *refs,
                  scale: float, block_size: int, kv_heads: int,
                  pages_per_fetch: int, steps: int):
    """One grid step: fold ``pages_per_fetch`` pages of one batch row into
    the running softmax.  refs = P k_refs + P v_refs + o_ref + 3 scratch."""
    p_f = pages_per_fetch
    k_refs = refs[:p_f]
    v_refs = refs[p_f:2 * p_f]
    o_ref = refs[2 * p_f]
    m_ref, l_ref, acc_ref = refs[2 * p_f + 1:]
    b = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_len = lens_ref[b]          # scalar read from SMEM
    qpos = qpos_ref[0]            # (R,) per-row causal bound, VMEM

    for p in range(p_f):
        page_no = j * p_f + p

        # page live iff its first slot is inside the row's KV span; null-padded
        # table entries sit past ceil(kv_len/bs) so this skips those too
        @pl.when(page_no * block_size < kv_len)
        def _fold(k_ref=k_refs[p], v_ref=v_refs[p], page_no=page_no):
            k = k_ref[0]          # (bs, KV, hd)
            v = v_ref[0]
            for h in range(kv_heads):
                q = q_ref[0, h]   # (R, hd)
                s = jnp.dot(q, k[:, h, :].T,
                            preferred_element_type=jnp.float32) * scale
                kpos = page_no * block_size + jax.lax.broadcasted_iota(
                    jnp.int32, s.shape, 1)
                live = (kpos <= qpos[:, None]) & (kpos < kv_len)
                s = jnp.where(live, s, NEG_INF)
                # rows fully masked in this page contribute at m == NEG_INF;
                # the first real score's alpha rescale annihilates them, and
                # every row with qpos >= 0 sees page 0 — so nothing survives
                m_prev = m_ref[h]
                m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
                pexp = jnp.exp(s - m_new[:, None])
                alpha = jnp.exp(m_prev - m_new)
                l_ref[h] = l_ref[h] * alpha + jnp.sum(pexp, axis=1)
                acc_ref[h] = (acc_ref[h] * alpha[:, None]
                              + jnp.dot(pexp.astype(v.dtype), v[:, h, :],
                                        preferred_element_type=jnp.float32))
                m_ref[h] = m_new

    @pl.when(j == steps - 1)
    def _store():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[..., None]).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           q_pos: jax.Array, kv_lens: jax.Array,
                           pages_per_fetch: int = 1,
                           interpret: bool = False) -> jax.Array:
    """q (B,KV,R,hd); pages (N,bs,KV,hd); block_tables (B,M) int32;
    q_pos (B,R) int32 per-row causal bound (row attends to kpos <= q_pos);
    kv_lens (B,) int32 valid KV entries per row (must be >= 1)
    -> (B,KV,R,hd).

    Each row's softmax runs over positions {kpos : kpos <= q_pos[row] and
    kpos < kv_lens[batch]} of the table-ordered span.  The table is padded
    with null (0) entries past ceil(kv_lens/bs) — those pages are skipped.
    """
    b, kv_heads, r, hd = q.shape
    _, bs, kv2, hd2 = k_pages.shape
    assert (kv_heads, hd) == (kv2, hd2), "q / pages head layout mismatch"
    assert v_pages.shape == k_pages.shape
    m = block_tables.shape[1]
    p_f = max(1, min(pages_per_fetch, m))
    pad = (-m) % p_f
    if pad:
        # pad with null blocks: past every row's length, skipped by pl.when
        block_tables = jnp.pad(block_tables, ((0, 0), (0, pad)))
        m += pad
    steps = m // p_f
    scale = 1.0 / math.sqrt(hd)

    page_spec = [
        pl.BlockSpec((1, bs, kv_heads, hd),
                     lambda b, j, tables, lens, p=p: (tables[b, j * p_f + p],
                                                      0, 0, 0))
        for p in range(p_f)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # block_tables, kv_lens
        grid=(b, steps),
        in_specs=[
            pl.BlockSpec((1, r), lambda b, j, tables, lens: (b, 0)),
            pl.BlockSpec((1, kv_heads, r, hd),
                         lambda b, j, tables, lens: (b, 0, 0, 0)),
        ] + page_spec + page_spec,
        out_specs=pl.BlockSpec((1, kv_heads, r, hd),
                               lambda b, j, tables, lens: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv_heads, r), jnp.float32),
            pltpu.VMEM((kv_heads, r), jnp.float32),
            pltpu.VMEM((kv_heads, r, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, block_size=bs,
                          kv_heads=kv_heads, pages_per_fetch=p_f,
                          steps=steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kv_heads, r, hd), q.dtype),
        interpret=interpret,
    )(block_tables.astype(jnp.int32), kv_lens.astype(jnp.int32),
      q_pos.astype(jnp.int32), q,
      *([k_pages] * p_f), *([v_pages] * p_f))
