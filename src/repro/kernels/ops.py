"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode automatically; on TPU
they compile through Mosaic.  Block sizes default to the Auto Schedule
MINLP's choices for the attention-like subgraph (see
``repro.core.codegen.kernel_plan``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.lora import lora_expand_kernel, lora_shrink_kernel
from repro.kernels.matmul import matmul_kernel
from repro.kernels.paged_attention import paged_attention_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel
from repro.kernels.ssm_scan import ssm_scan_chunked as _ssm_scan_chunked_kernel


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(a, b, block_m: int = 256, block_n: int = 256, block_k: int = 512):
    return matmul_kernel(a, b, block_m, block_n, block_k,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                              "block_q", "block_kv"))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_q: int = 512, block_kv: int = 1024):
    """Model-facing signature: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd).
    GQA is handled by repeating KV heads before the kernel."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    o = flash_attention_kernel(qf, kf, vf, causal=causal, q_offset=q_offset,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("pages_per_fetch",))
def paged_attention(q, k_pages, v_pages, block_tables, seq_lens,
                    pages_per_fetch: int = 1):
    """Paged decode attention: q (B,1,H,hd), pages (N,bs,KV,hd),
    block_tables (B,M) int32, seq_lens (B,) int32 valid KV entries per row
    (>= 1) -> (B,1,H,hd).

    Streams pages through the block table (``paged_attention_kernel``)
    instead of gathering the span; GQA is handled by grouping the H query
    heads under their KV head (head h serves KV head h // (H//KV), matching
    ``_repeat_kv``'s layout) — KV is never repeated or copied.
    """
    b, _, h, hd = q.shape
    kv = k_pages.shape[2]
    group = h // kv
    qg = q.reshape(b, kv, group, hd)        # head = kv_i * group + g_i
    qpos = jnp.broadcast_to((seq_lens - 1)[:, None], (b, group))
    o = paged_attention_kernel(qg, k_pages, v_pages, block_tables, qpos,
                               seq_lens, pages_per_fetch=pages_per_fetch,
                               interpret=_interpret())
    return o.reshape(b, 1, h, hd)


@functools.partial(jax.jit, static_argnames=("pages_per_fetch",))
def paged_attention_chunk(q, k_pages, v_pages, block_tables, chunk_pos,
                          kv_lens, pages_per_fetch: int = 1):
    """Paged chunked-prefill attention: q (B,C,H,hd) — C query tokens at
    absolute positions chunk_pos (C,) int32 (shared across rows; the engine
    prefills one request at a time), attending causally to the first
    kv_lens (B,) entries of the paged span -> (B,C,H,hd)."""
    b, c, h, hd = q.shape
    kv = k_pages.shape[2]
    group = h // kv
    # rows grouped per KV head: r = g_i * C + c_i
    qg = q.transpose(0, 2, 1, 3).reshape(b, kv, group * c, hd)
    qpos = jnp.broadcast_to(jnp.tile(chunk_pos, (group,))[None, :],
                            (b, group * c))
    o = paged_attention_kernel(qg, k_pages, v_pages, block_tables, qpos,
                               kv_lens, pages_per_fetch=pages_per_fetch,
                               interpret=_interpret())
    return o.reshape(b, kv, group, c, hd).transpose(0, 3, 1, 2, 4
                                                    ).reshape(b, c, h, hd)


@jax.jit
def lora_shrink(x, a_slab, idx):
    """Segmented LoRA down-projection: x (T,d) rows each contract against
    their *own* adapter's A matrix, selected from slab (S,d,R) by
    idx (T,) int32 (-1 = base-only row, exact-zero output) -> (T,R) f32.
    The gather happens inside the kernel (scalar-prefetched indices drive
    the weight-tile DMA), never materializing per-row (d,R) copies."""
    return lora_shrink_kernel(x, a_slab, idx, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_out",))
def lora_expand(h, b_slab, idx, block_out: int = 256):
    """Segmented LoRA up-projection: h (T,R) f32 against slab (S,R,O) by
    per-row idx (T,) -> (T,O) in the slab dtype.  ``block_out`` tiles the
    output features (Auto Schedule's choice via codegen.lora_tiles)."""
    return lora_expand_kernel(h, b_slab, idx, out_dtype=b_slab.dtype,
                              block_out=block_out, interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = 256):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rows = x2.shape[0]
    br = block_rows
    while rows % br:
        br //= 2
    out = rmsnorm_kernel(x2, w, eps=eps, block_rows=max(1, br),
                         interpret=_interpret())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block_d",))
def ssm_scan(a, b, c, h0, block_d: int = 512):
    """Batched: a,b (B,T,D,N), c (B,T,N), h0 (B,D,N) -> (y (B,T,D), h (B,D,N))."""
    bd = min(block_d, a.shape[2])
    while a.shape[2] % bd:
        bd //= 2
    fn = functools.partial(ssm_scan_kernel, block_d=max(1, bd),
                           interpret=_interpret())
    return jax.vmap(fn)(a, b, c, h0)


@functools.partial(jax.jit, static_argnames=("chunk", "block_d"))
def ssm_scan_chunked(a, b, c, h0, chunk: int, block_d: int = 512):
    """Batched chunked-prefill scan: same shapes as ``ssm_scan``, computed
    ``chunk`` timesteps per kernel launch with the state carried across
    chunk boundaries (the paged engine's prompt-streaming shape)."""
    bd = min(block_d, a.shape[2])
    while a.shape[2] % bd:
        bd //= 2
    fn = functools.partial(_ssm_scan_chunked_kernel, chunk=chunk,
                           block_d=max(1, bd), interpret=_interpret())
    return jax.vmap(fn)(a, b, c, h0)
