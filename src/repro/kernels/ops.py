"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run in interpret mode automatically; on TPU
they compile through Mosaic.  Block sizes default to the Auto Schedule
MINLP's choices for the attention-like subgraph (see
``repro.core.codegen.kernel_plan``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.matmul import matmul_kernel
from repro.kernels.rmsnorm import rmsnorm_kernel
from repro.kernels.ssm_scan import ssm_scan_kernel


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def matmul(a, b, block_m: int = 256, block_n: int = 256, block_k: int = 512):
    return matmul_kernel(a, b, block_m, block_n, block_k,
                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("causal", "q_offset",
                                              "block_q", "block_kv"))
def flash_attention(q, k, v, causal: bool = True, q_offset: int = 0,
                    block_q: int = 512, block_kv: int = 1024):
    """Model-facing signature: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd).
    GQA is handled by repeating KV heads before the kernel."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    if kvh != h:
        rep = h // kvh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, -1, hd)
    o = flash_attention_kernel(qf, kf, vf, causal=causal, q_offset=q_offset,
                               block_q=block_q, block_kv=block_kv,
                               interpret=_interpret())
    return o.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, w, eps: float = 1e-5, block_rows: int = 256):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    rows = x2.shape[0]
    br = block_rows
    while rows % br:
        br //= 2
    out = rmsnorm_kernel(x2, w, eps=eps, block_rows=max(1, br),
                         interpret=_interpret())
    return out.reshape(shape)


@functools.partial(jax.jit, static_argnames=("block_d",))
def ssm_scan(a, b, c, h0, block_d: int = 512):
    """Batched: a,b (B,T,D,N), c (B,T,N), h0 (B,D,N) -> (y (B,T,D), h (B,D,N))."""
    bd = min(block_d, a.shape[2])
    while a.shape[2] % bd:
        bd //= 2
    fn = functools.partial(ssm_scan_kernel, block_d=max(1, bd),
                           interpret=_interpret())
    return jax.vmap(fn)(a, b, c, h0)
