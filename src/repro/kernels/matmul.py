"""Packed/blocked matmul Pallas kernel — the NTT matmul μkernel (§3.3.2).

MXU-aligned VMEM tiling: grid (M/bm, N/bn, K/bk) with a float32 VMEM
accumulator; K is the innermost (sequential) grid dim so the accumulator
lives across K steps.  Default tile sizes come from the Auto Schedule MINLP
(see ``repro.core.codegen.kernel_plan``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_kernel(a: jax.Array, b: jax.Array,
                  block_m: int = 256, block_n: int = 256, block_k: int = 512,
                  interpret: bool = False) -> jax.Array:
    """a (M,K) @ b (K,N) -> (M,N); dims must divide by the block sizes."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    block_m = min(block_m, m)
    block_n = min(block_n, n)
    block_k = min(block_k, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    nk = k // block_k
    grid = (m // block_m, n // block_n, nk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b)
