"""Fused RMSNorm Pallas kernel (NTT rmsnorm μkernel): one pass over rows,
f32 reduction in VMEM, fused scale."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, w: jax.Array, eps: float = 1e-5,
                   block_rows: int = 256, interpret: bool = False) -> jax.Array:
    """x (R, D), w (D,) -> (R, D)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
