"""OpenAI-compatible HTTP gateway over ``AsyncServeEngine``.

The Ray-Serve-LLM split, stdlib-only: an ``LLMServer``-shaped per-model
handle (``GatewayModel`` — one engine, one tokenizer, one stepper thread)
behind an ``LLMRouter``-shaped ingress (``Router`` + ``Gateway`` — one
asyncio socket server multiplexing every model in the process).  Endpoints:

  ``GET  /v1/models``            list the router's models
  ``GET  /v1/models/{id}``       one model's card
  ``POST /v1/completions``       text completion; ``"stream": true`` for SSE
  ``POST /v1/chat/completions``  chat; same streaming contract
  ``GET  /health``               readiness + per-model stats (CI polls this)

Streaming is Server-Sent Events: one ``data: {json}`` chunk per emitted
text piece (each carries the raw ``token_ids`` it covers, an extension the
CI oracle-identity gate consumes), a final chunk bearing ``finish_reason``
and an OpenAI ``usage`` block, then the ``data: [DONE]`` terminator.  Every
response carries an ``x-request-id`` header.  Stop sequences are honoured
mid-stream: matched text is never emitted and the engine request is
**cancelled** the same moment, returning its KV blocks to the pool — the
same path a client disconnect takes.

The HTTP layer is deliberately minimal (asyncio streams, one request per
connection, ``Connection: close``): no framework dependency, and every
byte on the wire is visible in this one file.

Tokenization: the repro has no trained tokenizer, so the default
``ByteTokenizer`` maps latin-1 bytes onto the model's vocab (reversible for
ids the encoder can produce).  ``prompt`` may also be a raw token-id list —
benchmarks and the CI gate use that form to bypass text entirely.
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import sys
import time
import uuid
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.perf import perf
from repro.serve.async_engine import AsyncServeEngine, TokenStream
from repro.serve.engine import SamplingParams


# ---------------------------------------------------------------------------
# Tokenization
# ---------------------------------------------------------------------------

class ByteTokenizer:
    """Latin-1 bytes <-> token ids, offset by 1 so id 0 (the engine's pad)
    is never produced by text.  Bytes beyond ``vocab - 2`` clamp (lossy only
    when the vocab is smaller than the byte range); decoding clamps back
    into latin-1 so any generated id renders as exactly one char."""

    def __init__(self, vocab: int):
        assert vocab >= 2, "vocab too small to carry any byte"
        self.vocab = vocab

    def encode(self, text: str) -> List[int]:
        data = text.encode("latin-1", errors="replace")
        return [1 + min(b, self.vocab - 2) for b in data]

    def decode(self, ids: Sequence[int]) -> str:
        return "".join(chr(min(max(int(t) - 1, 0), 255)) for t in ids)


class StopDetector:
    """Incremental stop-sequence scanner over streamed text.

    ``feed`` returns the text that is now safe to emit; it holds back up to
    ``max(len(stop)) - 1`` trailing chars so a stop sequence split across
    token boundaries is still caught before any of it escapes to the
    client.  Once ``stopped`` flips, the held text up to the match was
    returned and everything from the stop sequence on is discarded.
    """

    def __init__(self, stops: Sequence[str]):
        self.stops = [s for s in stops if s]
        self.hold = max((len(s) for s in self.stops), default=1) - 1
        self.pending = ""
        self.stopped = False

    def feed(self, piece: str) -> str:
        self.pending += piece
        for s in self.stops:
            i = self.pending.find(s)
            if i >= 0:
                self.stopped = True
                out, self.pending = self.pending[:i], ""
                return out
        if len(self.pending) > self.hold:
            cut = len(self.pending) - self.hold
            out, self.pending = self.pending[:cut], self.pending[cut:]
            return out
        return ""

    def flush(self) -> str:
        out, self.pending = self.pending, ""
        return out


# ---------------------------------------------------------------------------
# Router: multiplex several models/engines in one process
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GatewayModel:
    """One served model: the async engine plus everything the HTTP layer
    needs to speak text about it.

    ``adapters`` declares the LoRA tenants this deployment serves: clients
    address them as ``model="{model_id}:{adapter}"``, each gets its own
    ``/v1/models`` card, and the first request for one lazily loads it into
    the engine's ``AdapterStore`` (bounded by REPRO_LORA_MAX_ADAPTERS;
    undeclared adapters 404 rather than materializing arbitrary tenants)."""
    model_id: str
    async_engine: AsyncServeEngine
    tokenizer: ByteTokenizer
    adapters: List[str] = dataclasses.field(default_factory=list)
    created: int = dataclasses.field(default_factory=lambda: int(time.time()))

    @property
    def engine(self):
        return self.async_engine.engine

    def card(self) -> Dict:
        # family-agnostic: clients see which serving substrate backs the
        # model (dense/moe attention KV, ssm state slab, hybrid mixed layout)
        return {"id": self.model_id, "object": "model",
                "created": self.created, "owned_by": "repro",
                "family": self.engine.cfg.family,
                "max_model_len": self.engine.max_len,
                "adapters": list(self.adapters)}

    def adapter_card(self, name: str) -> Dict:
        return {"id": f"{self.model_id}:{name}", "object": "model",
                "created": self.created, "owned_by": "repro",
                "parent": self.model_id, "adapter": name,
                "max_model_len": self.engine.max_len,
                "loaded": self.engine.adapters.is_loaded(name)}

    def serves_adapter(self, name: str) -> bool:
        """Declared on this deployment, or already in the engine's store
        (loaded programmatically via ``ServeEngine.load_adapter``)."""
        return name in self.adapters or self.engine.adapters.known(name)


class Router:
    """Model-id -> ``GatewayModel``; the single-process stand-in for the
    Ray Serve ``LLMRouter`` deployment."""

    def __init__(self, models: Sequence[GatewayModel] = ()):
        self._models: Dict[str, GatewayModel] = {}
        for m in models:
            self.add(m)

    def add(self, model: GatewayModel) -> None:
        if model.model_id in self._models:
            raise ValueError(f"duplicate model id {model.model_id!r}")
        self._models[model.model_id] = model

    def get(self, model_id: str) -> Optional[GatewayModel]:
        return self._models.get(model_id)

    def resolve(self, model_id: Optional[str]) -> Optional[GatewayModel]:
        """Missing/empty model falls through to a sole deployed model —
        single-model gateways shouldn't force clients to know the id."""
        if model_id:
            return self.get(model_id)
        if len(self._models) == 1:
            return next(iter(self._models.values()))
        return None

    def split_adapter(self, model_id: Optional[str]
                      ) -> Tuple[Optional[str], Optional[str]]:
        """``"base:adapter"`` -> (base, adapter); plain ids pass through as
        (id, None).  An empty base (``":tenant"``) keeps the sole-model
        fallback working for adapter asks too."""
        if not model_id or ":" not in model_id:
            return model_id, None
        base, _, adapter = model_id.partition(":")
        return base or None, adapter or None

    def models(self) -> List[GatewayModel]:
        return list(self._models.values())

    async def start(self) -> None:
        for m in self.models():
            if not m.async_engine.running:
                await m.async_engine.start()

    async def stop(self) -> None:
        for m in self.models():
            await m.async_engine.stop()


# ---------------------------------------------------------------------------
# HTTP plumbing (stdlib asyncio streams; one request per connection)
# ---------------------------------------------------------------------------

class _BadRequest(Exception):
    def __init__(self, message: str, status: int = 400,
                 retry_after: Optional[int] = None):
        super().__init__(message)
        self.status = status
        # seconds for a Retry-After header (load-shed 429/503 responses)
        self.retry_after = retry_after


_STATUS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 429: "Too Many Requests",
           500: "Internal Server Error", 503: "Service Unavailable"}


async def _read_request(reader: asyncio.StreamReader
                        ) -> Optional[Tuple[str, str, Dict[str, str], bytes]]:
    line = await reader.readline()
    if not line:
        return None
    parts = line.decode("latin-1").split()
    if len(parts) < 3:
        raise _BadRequest("malformed request line")
    method, target = parts[0].upper(), parts[1]
    headers: Dict[str, str] = {}
    while True:
        h = await reader.readline()
        if h in (b"\r\n", b"\n", b""):
            break
        key, _, val = h.decode("latin-1").partition(":")
        headers[key.strip().lower()] = val.strip()
    try:
        n = int(headers.get("content-length", "0") or "0")
    except ValueError as e:
        raise _BadRequest("bad content-length") from e
    body = await reader.readexactly(n) if n else b""
    return method, target.split("?", 1)[0], headers, body


def _headers(status: int, req_id: str, content_type: str,
             length: Optional[int] = None,
             extra: Optional[Dict[str, str]] = None) -> bytes:
    lines = [f"HTTP/1.1 {status} {_STATUS.get(status, 'OK')}",
             f"Content-Type: {content_type}",
             f"x-request-id: {req_id}",
             "Cache-Control: no-cache",
             "Connection: close"]
    for k, v in (extra or {}).items():
        lines.append(f"{k}: {v}")
    if length is not None:
        lines.append(f"Content-Length: {length}")
    return ("\r\n".join(lines) + "\r\n\r\n").encode("latin-1")


async def _send_json(writer: asyncio.StreamWriter, status: int, obj: Dict,
                     req_id: str,
                     extra: Optional[Dict[str, str]] = None) -> None:
    body = json.dumps(obj).encode("utf-8")
    writer.write(_headers(status, req_id, "application/json", len(body),
                          extra=extra))
    writer.write(body)
    await writer.drain()


def _error(message: str, err_type: str = "invalid_request_error") -> Dict:
    return {"error": {"message": message, "type": err_type,
                      "param": None, "code": None}}


async def _sse_open(writer: asyncio.StreamWriter, req_id: str) -> None:
    writer.write(_headers(200, req_id, "text/event-stream"))
    await writer.drain()


async def _sse_event(writer: asyncio.StreamWriter, obj: Union[Dict, str]
                     ) -> None:
    data = obj if isinstance(obj, str) else json.dumps(obj)
    writer.write(f"data: {data}\n\n".encode("utf-8"))
    await writer.drain()


# ---------------------------------------------------------------------------
# OpenAI request/response shaping
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Completion:
    """A parsed, validated completion ask (shared by both endpoints)."""
    model: GatewayModel
    prompt_ids: List[int]
    max_tokens: int
    sampling: SamplingParams
    stream: bool
    stops: List[str]
    echo_text: str = ""       # prompt text, for completions' echo=true
    deadline_ms: Optional[float] = None   # request "timeout" (body field,
    #                                       seconds) -> engine deadline
    adapter_id: Optional[str] = None      # LoRA tenant ("base:adapter" asks)

    @property
    def served_id(self) -> str:
        """The model id responses echo back — adapter asks keep their tag
        so a client can verify which tenant actually answered."""
        return self.model.model_id + (f":{self.adapter_id}"
                                      if self.adapter_id else "")


def _parse_prompt(model: GatewayModel, prompt) -> Tuple[List[int], str]:
    tok = model.tokenizer
    if isinstance(prompt, str):
        return tok.encode(prompt), prompt
    if isinstance(prompt, list) and prompt and \
            all(isinstance(t, int) and not isinstance(t, bool) for t in prompt):
        vocab = model.engine.cfg.vocab
        bad = [t for t in prompt if not 0 <= t < vocab]
        if bad:
            raise _BadRequest(f"prompt token id(s) {bad[:3]} outside "
                              f"vocab [0, {vocab})")
        return list(prompt), tok.decode(prompt)
    raise _BadRequest("prompt must be a string or a flat list of token ids")


def _parse_body(router: Router, body: bytes, chat: bool) -> _Completion:
    try:
        d = json.loads(body.decode("utf-8") or "{}")
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise _BadRequest(f"body is not valid JSON: {e}") from e
    if not isinstance(d, dict):
        raise _BadRequest("body must be a JSON object")
    base_id, adapter_id = router.split_adapter(d.get("model"))
    model = router.resolve(base_id)
    if model is None:
        known = ", ".join(m.model_id for m in router.models()) or "none"
        raise _BadRequest(f"model {d.get('model')!r} not found "
                          f"(deployed: {known})", status=404)
    if adapter_id is not None and not model.serves_adapter(adapter_id):
        declared = ", ".join(model.adapters) or "none"
        raise _BadRequest(
            f"adapter {adapter_id!r} not found on model "
            f"{model.model_id!r} (declared: {declared})", status=404)
    if int(d.get("n", 1)) != 1:
        raise _BadRequest("n > 1 is not supported")

    if chat:
        messages = d.get("messages")
        if not isinstance(messages, list) or not messages:
            raise _BadRequest("messages must be a non-empty list")
        lines = [f"{m.get('role', 'user')}: {m.get('content', '')}"
                 for m in messages]
        text = "\n".join(lines) + "\nassistant:"
        prompt_ids, echo = model.tokenizer.encode(text), text
    else:
        if "prompt" not in d:
            raise _BadRequest("prompt is required")
        prompt_ids, echo = _parse_prompt(model, d["prompt"])
    if not prompt_ids:
        raise _BadRequest("prompt is empty")

    eng = model.engine
    room = eng.max_len - len(prompt_ids)
    if room < 1:
        raise _BadRequest(f"prompt of {len(prompt_ids)} tokens leaves no "
                          f"room under max_model_len {eng.max_len}")
    asked = d.get("max_tokens", 16)
    try:
        asked = int(asked)
    except (TypeError, ValueError) as e:
        raise _BadRequest("max_tokens must be an integer") from e
    if asked < 1:
        raise _BadRequest("max_tokens must be >= 1")
    max_tokens = min(asked, perf().gateway_max_new, room)

    stops = d.get("stop") or []
    if isinstance(stops, str):
        stops = [stops]
    if not isinstance(stops, list) or \
            not all(isinstance(s, str) for s in stops):
        raise _BadRequest("stop must be a string or list of strings")

    # per-request deadline: OpenAI clients pass "timeout" in seconds; the
    # engine-wide REPRO_SERVE_DEADLINE_MS default applies when absent
    deadline_ms: Optional[float] = None
    if "timeout" in d and d["timeout"] is not None:
        try:
            timeout_s = float(d["timeout"])
        except (TypeError, ValueError) as e:
            raise _BadRequest("timeout must be a number (seconds)") from e
        if timeout_s <= 0:
            raise _BadRequest("timeout must be > 0 seconds")
        deadline_ms = timeout_s * 1e3

    sampling = SamplingParams(
        temperature=float(d.get("temperature", 0.0)),
        top_k=int(d.get("top_k", 0)),
        seed=int(d.get("seed", 0)))
    return _Completion(model=model, prompt_ids=prompt_ids,
                       max_tokens=max_tokens, sampling=sampling,
                       stream=bool(d.get("stream", False)), stops=stops,
                       echo_text=echo, deadline_ms=deadline_ms,
                       adapter_id=adapter_id)


def _usage(prompt_tokens: int, completion_tokens: int) -> Dict:
    return {"prompt_tokens": prompt_tokens,
            "completion_tokens": completion_tokens,
            "total_tokens": prompt_tokens + completion_tokens}


def _finish_reason(engine_reason: str, stopped: bool) -> str:
    if stopped:
        return "stop"
    return "length" if engine_reason in ("", "length") else engine_reason


def _completion_chunk(req_id: str, model_id: str, created: int, text: str,
                      token_ids: Optional[List[int]],
                      finish_reason: Optional[str] = None,
                      usage: Optional[Dict] = None, chat: bool = False,
                      first: bool = False) -> Dict:
    if chat:
        delta: Dict = {}
        if first:
            delta["role"] = "assistant"
        if text:
            delta["content"] = text
        choice: Dict = {"index": 0, "delta": delta,
                        "finish_reason": finish_reason}
    else:
        choice = {"index": 0, "text": text, "logprobs": None,
                  "finish_reason": finish_reason}
    if token_ids is not None:
        choice["token_ids"] = token_ids
    out = {"id": req_id, "created": created, "model": model_id,
           "object": "chat.completion.chunk" if chat else "text_completion",
           "choices": [choice]}
    if usage is not None:
        out["usage"] = usage
    return out


# ---------------------------------------------------------------------------
# Gateway server
# ---------------------------------------------------------------------------

class Gateway:
    """The asyncio socket server fronting a ``Router``.

    ``await start()`` binds (port 0 picks an ephemeral port, read it back
    from ``.port``) and starts every model's stepper; ``await stop()``
    closes the listener and stops the steppers.  Use as an async context
    manager in tests.
    """

    def __init__(self, router: Router, host: str = "127.0.0.1",
                 port: int = 8000):
        self.router = router
        self.host = host
        self.port = port
        self._server: Optional[asyncio.AbstractServer] = None

    async def start(self) -> "Gateway":
        await self.router.start()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        await self.router.stop()

    async def __aenter__(self) -> "Gateway":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.stop()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    # -- connection handling ----------------------------------------------
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        req_id = f"req-{uuid.uuid4().hex[:24]}"
        try:
            parsed = await _read_request(reader)
            if parsed is None:
                return
            method, path, headers, body = parsed
            await self._route(method, path, body, writer, req_id)
        except _BadRequest as e:
            extra = {"Retry-After": str(e.retry_after)} \
                if e.retry_after is not None else None
            err_type = "overloaded_error" if e.status in (429, 503) \
                else "invalid_request_error"
            try:
                await _send_json(writer, e.status, _error(str(e), err_type),
                                 req_id, extra=extra)
            except (ConnectionError, RuntimeError):
                pass
        except (asyncio.IncompleteReadError, ConnectionError):
            pass  # client went away mid-request; stream handlers cancelled
        except Exception as e:  # noqa: BLE001 — one bad conn must not kill the server
            # never swallowed silently: the operator sees what the client got
            print(f"gateway: unhandled {type(e).__name__} serving {req_id}: "
                  f"{e}", file=sys.stderr)
            try:
                await _send_json(writer, 500,
                                 _error(f"{type(e).__name__}: {e}",
                                        "internal_error"), req_id)
            except (ConnectionError, RuntimeError):
                pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass

    async def _route(self, method: str, path: str, body: bytes,
                     writer: asyncio.StreamWriter, req_id: str) -> None:
        if path == "/health" and method == "GET":
            stats = [m.async_engine.stats() for m in self.router.models()]
            # non-200 when any stepper is dead or its engine crossed the
            # consecutive-crash threshold — orchestrators key restarts on this
            healthy = all(s["running"] and not s["degraded"] for s in stats)
            status = "ok" if healthy else "degraded"
            await _send_json(writer, 200 if healthy else 503,
                             {"status": status, "models": stats}, req_id)
        elif path == "/v1/models" and method == "GET":
            cards = []
            for m in self.router.models():
                cards.append(m.card())
                # one card per tenant: declared adapters plus any loaded
                # programmatically straight into the engine's store
                names = list(dict.fromkeys(
                    list(m.adapters) + m.engine.adapters.loaded()))
                cards.extend(m.adapter_card(n) for n in names)
            await _send_json(writer, 200,
                             {"object": "list", "data": cards}, req_id)
        elif path.startswith("/v1/models/") and method == "GET":
            asked = path[len("/v1/models/"):]
            base_id, adapter_id = self.router.split_adapter(asked)
            m = self.router.get(base_id) if base_id else None
            if m is None:
                raise _BadRequest("model not found", status=404)
            if adapter_id is not None:
                if not m.serves_adapter(adapter_id):
                    raise _BadRequest("adapter not found", status=404)
                await _send_json(writer, 200, m.adapter_card(adapter_id),
                                 req_id)
            else:
                await _send_json(writer, 200, m.card(), req_id)
        elif path == "/v1/completions" and method == "POST":
            await self._completion(body, writer, req_id, chat=False)
        elif path == "/v1/chat/completions" and method == "POST":
            await self._completion(body, writer, req_id, chat=True)
        elif path in ("/v1/completions", "/v1/chat/completions", "/health",
                      "/v1/models"):
            raise _BadRequest(f"method {method} not allowed here", status=405)
        else:
            raise _BadRequest(f"no route for {path}", status=404)

    # -- the two completion endpoints -------------------------------------
    async def _completion(self, body: bytes, writer: asyncio.StreamWriter,
                          req_id: str, chat: bool) -> None:
        ask = _parse_body(self.router, body, chat=chat)
        aeng = ask.model.async_engine
        if not aeng.running:
            raise _BadRequest("engine is not running", status=503,
                              retry_after=1)
        # load shedding: refuse at the door (429 + Retry-After) while the
        # submit queue is full or the block pool is past the pressure
        # threshold — cheaper for everyone than queueing work that will
        # miss its deadline anyway
        reason = aeng.engine.overload_reason()
        if reason:
            aeng.engine.note_gateway_shed()
            raise _BadRequest(f"overloaded: {reason}", status=429,
                              retry_after=1)
        if ask.adapter_id is not None \
                and not aeng.engine.adapters.known(ask.adapter_id):
            # first ask for a declared tenant: lazy-load its adapter.  Safe
            # from this (event-loop) thread: the slab write only touches a
            # slot no in-flight row references (in-flight rows hold refs, and
            # only refcount-0 slots are evicted/overwritten).
            from repro.serve.adapters import AdapterStoreFull
            try:
                aeng.engine.load_adapter(ask.adapter_id)
            except AdapterStoreFull as e:
                raise _BadRequest(f"adapter store full: {e}", status=429,
                                  retry_after=1) from e
            except NotImplementedError as e:
                raise _BadRequest(str(e)) from e
        req_id = ("chatcmpl-" if chat else "cmpl-") + uuid.uuid4().hex[:24]
        created = int(time.time())
        stream = aeng.submit(
            ask.prompt_ids, max_new=ask.max_tokens, sampling=ask.sampling,
            deadline_ms=ask.deadline_ms, adapter_id=ask.adapter_id)
        if ask.stream:
            await self._stream_response(ask, stream, writer, req_id, created,
                                        chat)
        else:
            await self._full_response(ask, stream, writer, req_id, created,
                                      chat)

    async def _consume(self, ask: _Completion, stream: TokenStream,
                       detector: StopDetector):
        """Drive one generation to its end (stop sequence, length, or
        engine-side termination), yielding (text, token_ids) pieces.  When a
        stop sequence lands the engine request is cancelled immediately —
        its KV blocks go back to the pool without waiting for max_tokens."""
        tok = ask.model.tokenizer
        pending_ids: List[int] = []
        async for t in stream:
            pending_ids.append(t)
            piece = detector.feed(tok.decode([t]))
            if piece:
                ids, pending_ids = pending_ids, []
                yield piece, ids
            if detector.stopped:
                ask.model.async_engine.cancel(stream.rid)
                return
        piece = detector.flush()
        if piece:
            yield piece, pending_ids

    async def _full_response(self, ask: _Completion, stream: TokenStream,
                             writer: asyncio.StreamWriter, req_id: str,
                             created: int, chat: bool) -> None:
        detector = StopDetector(ask.stops)
        texts: List[str] = []
        all_ids: List[int] = []
        async for piece, ids in self._consume(ask, stream, detector):
            texts.append(piece)
            all_ids.extend(ids)
        if stream.finish_reason.startswith("rejected"):
            raise _BadRequest(stream.finish_reason)
        reason = "stop" if detector.stopped \
            else _finish_reason(stream.finish_reason, False)
        text = "".join(texts)
        usage = _usage(len(ask.prompt_ids), len(all_ids))
        if chat:
            choice: Dict = {"index": 0,
                            "message": {"role": "assistant", "content": text},
                            "finish_reason": reason}
        else:
            choice = {"index": 0, "text": text, "logprobs": None,
                      "finish_reason": reason, "token_ids": all_ids}
        obj = {"id": req_id,
               "object": "chat.completion" if chat else "text_completion",
               "created": created, "model": ask.served_id,
               "choices": [choice], "usage": usage}
        await _send_json(writer, 200, obj, req_id)

    async def _stream_response(self, ask: _Completion, stream: TokenStream,
                               writer: asyncio.StreamWriter, req_id: str,
                               created: int, chat: bool) -> None:
        mid = ask.served_id
        detector = StopDetector(ask.stops)
        await _sse_open(writer, req_id)
        n_tokens = 0
        first = True
        try:
            async for piece, ids in self._consume(ask, stream, detector):
                n_tokens += len(ids)
                await _sse_event(writer, _completion_chunk(
                    req_id, mid, created, piece, ids, chat=chat,
                    first=first))
                first = False
            if stream.finish_reason.startswith("rejected"):
                await _sse_event(writer, _error(stream.finish_reason))
                await _sse_event(writer, "[DONE]")
                return
            reason = "stop" if detector.stopped \
                else _finish_reason(stream.finish_reason, False)
            await _sse_event(writer, _completion_chunk(
                req_id, mid, created, "", None, finish_reason=reason,
                usage=_usage(len(ask.prompt_ids), n_tokens),
                chat=chat, first=first))
            await _sse_event(writer, "[DONE]")
        except (ConnectionError, RuntimeError):
            # client went away mid-stream: free the request's KV now
            if not stream.finish_reason:
                ask.model.async_engine.cancel(stream.rid)
            raise


# ---------------------------------------------------------------------------
# Construction helpers
# ---------------------------------------------------------------------------

def build_model(cfg, params, model_id: Optional[str] = None,
                adapters: Sequence[str] = (),
                **engine_kwargs) -> GatewayModel:
    """One ``GatewayModel`` from a config + params: builds the
    ``ServeEngine`` and wraps it (the stepper starts with the router).
    ``adapters`` declares the LoRA tenants clients may address as
    ``model="{id}:{adapter}"`` — loaded lazily on first use."""
    from repro.serve.engine import ServeEngine
    eng = ServeEngine(cfg, params, **engine_kwargs)
    mid = model_id or cfg.name
    return GatewayModel(model_id=mid,
                        async_engine=AsyncServeEngine(eng, model_id=mid),
                        tokenizer=ByteTokenizer(cfg.vocab),
                        adapters=list(adapters))
