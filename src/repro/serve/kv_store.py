"""Tiered KV storage: refcounted copy-on-write blocks across named tiers.

This module is the engine<->cache boundary the serve stack speaks: a
``KVStore`` owns refcounted ``Block`` handles living in named storage tiers —
``DeviceTier`` wraps the jax block slab (``repro.serve.paged_cache.BlockPool``
is its allocator), ``HostTier`` is a pinned-numpy stand-in for host DRAM —
and moves KV between them:

  * ``fork(blocks)``   — copy-on-write prefix sharing: a second request maps
    the *same* physical blocks (refcount bumped); writes to a shared block go
    through ``cow_into`` first, so sharers never observe each other's tokens.
  * ``swap_out/swap_in`` — preemption parks a request's cold blocks on the
    host tier instead of discarding them; re-admission restores them and the
    request resumes mid-generation (the paper's heterogeneous-storage angle
    applied to serving; block-wise management after MNN-LLM, arXiv
    2506.10443).
  * a budgeted prefix registry — completed prompt prefixes stay mapped (LRU,
    capped at ``prefix_cache_blocks``) so identical prefixes across requests
    prefill exactly once.

Only the *data plane* touches jax: tier read/copy/write callbacks come from
the model family (``ModelFns.paged_block_*``), so the store itself stays
family-agnostic and the bookkeeping is plain Python — unit-testable in
milliseconds with stub tiers.

The device tier may be **mesh-sharded** (multi-device serving): pass
``DeviceTier(shardings=...)`` and the slab is distributed on the kv-heads
axis while every handle, refcount, and table keeps speaking global block
ids — sharding is invisible to the store's bookkeeping.  See
``docs/architecture.md`` for the full storage-tier picture.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.paged_cache import (NULL_BLOCK, BlockPool, PoolExhausted,
                                     blocks_for_tokens)

DEVICE = "device"
HOST = "host"


@dataclasses.dataclass(eq=False)
class Block:
    """A refcounted handle on one physical KV block in some tier.

    Identity semantics (``eq=False``): two handles are the same block only if
    they are the same object.  ``idx`` is the physical slot in ``tier``;
    refcounts are managed exclusively by the owning ``KVStore``.
    """
    tier: str
    idx: int
    refcount: int = 1

    @property
    def shared(self) -> bool:
        return self.refcount > 1


class DeviceTier:
    """The jax block slab behind a ``BlockPool`` allocator.

    ``cache`` is the functional pytree threaded through the jitted model fns
    (shape per leaf: ``(n_layers, num_blocks, block_size, n_kv, head_dim)``);
    the engine reads it for every dispatch and writes the updated pytree
    back, so the tier holds the *current* reference between dispatches.
    Data-plane ops (copy/read/write of one block) are injected by the model
    family so the tier never assumes a leaf layout.

    ``shardings`` (optional, a pytree of ``jax.sharding.NamedSharding``
    mirroring ``cache``) makes the slab **mesh-sharded**: each device owns a
    slice of the kv-heads axis of every block (see
    ``repro.distributed.sharding.paged_cache_specs``).  Block *identity* is
    unchanged — the allocator, block tables, refcounts, and copy-on-write
    all still speak global block ids; only the bytes of each block are
    distributed.  ``read``/``write`` therefore move whole logical blocks:
    a ``read`` gathers the per-shard slices into one host array (the host
    tier stays replicated-on-host), a ``write`` scatters the host block
    back across the shards.  ``_pin`` re-asserts the slab's sharding after
    data-plane updates in case the compiler drifted it.
    """

    name = DEVICE

    def __init__(self, cache, pool: BlockPool,
                 copy_block: Callable, read_block: Callable,
                 write_block: Callable, shardings=None):
        self.shardings = shardings
        self.cache = self._pin(cache)
        self.pool = pool
        self._copy = copy_block
        self._read = read_block
        self._write = write_block

    def _pin(self, cache):
        """Re-apply the slab's NamedSharding to any leaf that lost it (a
        no-op — pointer-equality fast path — when nothing drifted)."""
        if self.shardings is None:
            return cache
        import jax
        return jax.tree.map(
            lambda x, s: x if getattr(x, "sharding", None) == s
            else jax.device_put(x, s), cache, self.shardings)

    @property
    def block_size(self) -> int:
        return self.pool.block_size

    def alloc(self, reserved: bool = False) -> int:
        """Pop one free physical block id (``reserved=True`` draws it out of
        an admission reservation).  Raises ``PoolExhausted`` under pressure."""
        return self.pool.alloc(reserved=reserved)

    def free(self, idx: int) -> None:
        """Return physical block ``idx`` to the pool's free list."""
        self.pool.free([idx])

    def copy(self, src: int, dst: int) -> None:
        """Device-side block copy (the CoW data plane).  On a sharded slab
        each device copies its own kv-head slice — no cross-device traffic."""
        self.cache = self._pin(self._copy(self.cache, src, dst))

    def read(self, idx: int):
        """Block ``idx`` -> host numpy pytree (device -> host swap traffic).
        On a sharded slab this gathers the per-shard slices into one full
        block, so the host tier holds whole blocks regardless of the mesh."""
        return self._read(self.cache, idx)

    def write(self, idx: int, data) -> None:
        """Host numpy pytree -> block ``idx`` (host -> device swap traffic).
        On a sharded slab the block is re-split: each device receives its
        kv-head slice of the restored data."""
        self.cache = self._pin(self._write(self.cache, idx, data))


class HostTier:
    """Host-DRAM tier: per-block numpy slabs (stand-in for pinned memory).

    Blocks are stored block-major — ``slab[leaf][i]`` is block ``i``'s data —
    so a swap moves one contiguous chunk per leaf.  There is no null block:
    host blocks are never indexed by device-side tables.
    """

    name = HOST

    def __init__(self, num_blocks: int):
        if num_blocks < 0:
            raise ValueError("host tier size must be >= 0")
        self.num_blocks = num_blocks
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._data: Dict[int, object] = {}

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.num_blocks - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise PoolExhausted("host tier full")
        return self._free.pop()

    def free(self, idx: int) -> None:
        if not (0 <= idx < self.num_blocks):
            raise ValueError(f"host block {idx} out of range")
        if idx in self._free:
            raise ValueError(f"double free of host block {idx}")
        self._data.pop(idx, None)
        self._free.append(idx)

    def write(self, idx: int, data) -> None:
        # keep our own copy so a later device-side overwrite can't alias it
        self._data[idx] = {k: np.array(v) for k, v in data.items()} \
            if isinstance(data, dict) else np.array(data)

    def read(self, idx: int):
        return self._data[idx]


@dataclasses.dataclass
class _PrefixEntry:
    tokens: Tuple[int, ...]
    blocks: List[Block]
    # tenant namespace (the request's adapter id): entries only ever match
    # requests in the same namespace, so tenant A's KV blocks are never
    # served to tenant B even for bit-identical prompts.  None = the shared
    # base namespace (pre-multi-LoRA behavior).
    namespace: Optional[str] = None


class KVStore:
    """Refcounted block handles across named tiers + the prefix registry.

    The engine allocates through the store (``alloc`` returns a handle with
    refcount 1), shares through ``fork``, privatizes shared blocks through
    ``cow_into`` before writing, and parks/restores KV through
    ``swap_out``/``swap_in``.  ``decref`` returns a block to its tier's
    allocator when the last reference drops — blocks are never freed behind a
    live holder's back.
    """

    # chaos sites (repro.serve.faults): class attributes so derived stores
    # (the recurrent-state slab) fault under their own REPRO_FAULT sites
    SITE_SWAP_OUT = "swap_out"
    SITE_SWAP_IN = "swap_in"

    def __init__(self, device: DeviceTier, host: Optional[HostTier] = None,
                 prefix_cache_blocks: int = 0):
        self.device = device
        self.host = host or HostTier(0)
        self.tiers: Dict[str, object] = {DEVICE: self.device, HOST: self.host}
        self.prefix_cache_blocks = prefix_cache_blocks
        self._prefixes: List[_PrefixEntry] = []   # oldest first (LRU order)
        # optional chaos hook (repro.serve.faults.FaultInjector): checked at
        # swap entry, before any tier state moves, so an injected swap fault
        # leaves both tiers consistent (the engine downgrades or quarantines)
        self.fault_injector = None
        # traffic counters (engine folds these into ServeMetrics)
        self.shared_blocks = 0
        self.cow_copies = 0
        self.swapped_out = 0
        self.swapped_in = 0

    # -- refcounting -------------------------------------------------------
    def alloc(self, reserved: bool = False) -> Block:
        """One fresh device block (refcount 1).  Raises PoolExhausted under
        pressure — callers evict prefix-cache entries and/or preempt."""
        return Block(DEVICE, self.device.alloc(reserved=reserved))

    def incref(self, block: Block) -> Block:
        if block.refcount < 1:
            raise ValueError("incref on a freed block")
        block.refcount += 1
        return block

    def decref(self, block: Block) -> None:
        if block.refcount < 1:
            raise ValueError("decref on a freed block")
        block.refcount -= 1
        if block.refcount == 0:
            self.tiers[block.tier].free(block.idx)

    def fork(self, blocks: Sequence[Block]) -> List[Block]:
        """Map the same physical blocks into another holder (CoW sharing):
        refcounts bump, no data moves.  Writers must go through
        ``cow_into`` first."""
        out = [self.incref(b) for b in blocks]
        self.shared_blocks += len(out)
        return out

    def cow_into(self, block: Block, dst: Block) -> Block:
        """Privatize a shared device block before a write: device-copy its
        contents into ``dst`` (a fresh block the caller allocated) and drop
        our reference on the original.  Returns ``dst``."""
        assert block.tier == DEVICE and dst.tier == DEVICE
        if not block.shared:
            raise ValueError("cow_into on an exclusive block — write in place")
        self.device.copy(block.idx, dst.idx)
        self.decref(block)
        self.cow_copies += 1
        return dst

    # -- tier movement -----------------------------------------------------
    def swap_out(self, block: Block) -> Block:
        """Move one device block to the host tier.

        Shared blocks are NOT copied: other holders (prefix registry, other
        requests) pin them on-device anyway, so the handle is returned
        unchanged and the caller keeps its reference — a restore finds the
        block already resident.  Exclusive blocks move: data is read back to
        host, the device slot is freed, and a host-tier handle comes back.
        """
        assert block.tier == DEVICE
        if block.shared:
            return block
        if self.fault_injector is not None:
            self.fault_injector.check(self.SITE_SWAP_OUT)
        hidx = self.host.alloc()
        self.host.write(hidx, self.device.read(block.idx))
        self.decref(block)
        self.swapped_out += 1
        return Block(HOST, hidx)

    def swap_in(self, block: Block, dst: Block) -> Block:
        """Restore one host block into ``dst`` (a fresh device block the
        caller allocated under its reservation).  The host slot is freed."""
        if block.tier == DEVICE:
            return block                      # was never swapped (shared)
        assert dst.tier == DEVICE
        if self.fault_injector is not None:
            self.fault_injector.check(self.SITE_SWAP_IN)
        self.device.write(dst.idx, self.host.read(block.idx))
        self.decref(block)
        self.swapped_in += 1
        return dst

    def can_swap_out(self, blocks: Sequence[Block]) -> bool:
        need = sum(1 for b in blocks if b.tier == DEVICE and not b.shared)
        return need <= self.host.num_free

    # -- prefix registry ---------------------------------------------------
    def match_prefix(self, tokens: Sequence[int],
                     namespace: Optional[str] = None
                     ) -> Tuple[int, List[Block]]:
        """Longest registered prefix of ``tokens`` within ``namespace``
        (the request's adapter id; None = base): (shared token count, the
        registry's blocks covering it).  Entries from other namespaces never
        match — prefix KV encodes the adapter that wrote it, so a
        cross-tenant hit would replay tenant A's activations for tenant B.
        Blocks are NOT incref'd — adopt them with ``fork``.  A hit refreshes
        the entry's LRU position."""
        best_len, best = 0, None
        for e in self._prefixes:
            if e.namespace != namespace:
                continue
            lim = min(len(tokens), len(e.tokens), len(e.blocks) * self.block_size)
            n = 0
            while n < lim and tokens[n] == e.tokens[n]:
                n += 1
            if n > best_len:
                best_len, best = n, e
        if best is None:
            return 0, []
        self._prefixes.remove(best)
        self._prefixes.append(best)           # LRU touch
        return best_len, best.blocks[:blocks_for_tokens(best_len,
                                                        self.block_size)]

    def register_prefix(self, tokens: Sequence[int],
                        blocks: Sequence[Block],
                        namespace: Optional[str] = None) -> bool:
        """Retain a completed prompt's blocks for future sharers *in the
        same namespace*.  The registry holds its own references (truncated
        to the block budget, evicting LRU entries to make room); False if
        the budget is 0 or the prefix is already covered."""
        if self.prefix_cache_blocks <= 0 or not blocks:
            return False
        covered, _ = self.match_prefix(tokens, namespace=namespace)
        if covered >= len(tokens):
            return False
        keep = list(blocks[:self.prefix_cache_blocks])
        while (self._registry_blocks() + len(keep) > self.prefix_cache_blocks
               and self._prefixes):
            self._evict_one()
        entry = _PrefixEntry(tuple(tokens), [self.incref(b) for b in keep],
                             namespace=namespace)
        self._prefixes.append(entry)
        return True

    def _registry_blocks(self) -> int:
        return sum(len(e.blocks) for e in self._prefixes)

    def _evict_one(self) -> int:
        e = self._prefixes.pop(0)
        freed = 0
        for b in e.blocks:
            was = b.refcount
            self.decref(b)
            freed += int(was == 1)
        return freed

    def evict_prefixes(self, min_blocks: int = 1) -> int:
        """Drop LRU registry entries until >= ``min_blocks`` device blocks
        came free (or the registry drains).  Returns blocks actually freed —
        0 means eviction can't help the caller's allocation failure."""
        freed = 0
        while freed < min_blocks and self._prefixes:
            freed += self._evict_one()
        return freed

    def drop_prefixes(self) -> int:
        """Release the whole prefix cache (benchmarks call this between
        measured windows; tests call it to assert the pool drains to 0)."""
        n = 0
        while self._prefixes:
            n += self._evict_one()
        return n

    @property
    def num_prefixes(self) -> int:
        return len(self._prefixes)

    @property
    def block_size(self) -> int:
        return self.device.block_size

    def reset_counters(self) -> None:
        self.shared_blocks = 0
        self.cow_copies = 0
        self.swapped_out = 0
        self.swapped_in = 0


@dataclasses.dataclass
class BlockTable:
    """A request's ordered block-handle list: token position p lives at
    ``blocks[p // block_size]`` offset ``p % block_size``.  Handles may be
    shared (forked prefixes) — the engine privatizes via CoW before any
    write.  Device-side batching consumes ``padded()`` physical ids."""
    block_size: int
    blocks: List[Block] = dataclasses.field(default_factory=list)

    @property
    def capacity(self) -> int:
        return len(self.blocks) * self.block_size

    def block_ids(self) -> List[int]:
        assert all(b.tier == DEVICE for b in self.blocks), \
            "device batching over non-device blocks (missing swap_in?)"
        return [b.idx for b in self.blocks]

    def padded(self, max_blocks: int) -> List[int]:
        """Fixed-width physical-id view for the device (null-block padded)."""
        ids = self.block_ids()
        if len(ids) > max_blocks:
            raise ValueError(f"table {len(ids)} blocks > max {max_blocks}")
        return ids + [NULL_BLOCK] * (max_blocks - len(ids))

    def release_to(self, store: KVStore) -> None:
        for b in self.blocks:
            store.decref(b)
        self.blocks = []


class SlabDeviceView:
    """Device tier over the recurrent-state *slots* of a shared cache pytree.

    SSM/hybrid requests carry O(1) state (conv window + scan state) instead
    of — or, for hybrids, in addition to — per-token KV.  The state lives in
    the same functional cache pytree the block tiers thread through the
    jitted model fns (one holder: the base ``DeviceTier``); this view indexes
    its *slot* axis instead of the block axis.  Slot 0 is the null slot
    (mirrors ``NULL_BLOCK``): padded decode rows scatter there, it is never
    allocated.  Data-plane callbacks come from the model family
    (``ModelFns.state_slot_*``) so the view never assumes a leaf layout —
    for hybrids they touch only the ``ssm`` leaves, the block callbacks only
    the ``k``/``v`` leaves, of one shared pytree.
    """

    name = DEVICE

    def __init__(self, base: DeviceTier, pool: BlockPool,
                 copy_slot: Callable, read_slot: Callable,
                 write_slot: Callable):
        self.base = base
        self.pool = pool
        self._copy = copy_slot
        self._read = read_slot
        self._write = write_slot

    @property
    def cache(self):
        return self.base.cache

    @property
    def block_size(self) -> int:
        return 1                      # one slot holds one request's state

    def alloc(self, reserved: bool = False) -> int:
        return self.pool.alloc(reserved=reserved)

    def free(self, idx: int) -> None:
        self.pool.free([idx])

    def copy(self, src: int, dst: int) -> None:
        self.base.cache = self.base._pin(self._copy(self.base.cache, src, dst))

    def read(self, idx: int):
        return self._read(self.base.cache, idx)

    def write(self, idx: int, data) -> None:
        self.base.cache = self.base._pin(self._write(self.base.cache, idx,
                                                     data))


class StateSlab(KVStore):
    """Recurrent-state tier: the degenerate one-block case of the block pool.

    A request's scan state is fixed-size, so its "table" is a single
    refcounted ``Block`` whose ``idx`` is a slot in the state slab.  All the
    KVStore machinery carries over unchanged — refcounting, ``fork`` +
    ``cow_into`` (state CoW), ``swap_out``/``swap_in`` to a host tier (parked
    state survives preemption exactly like parked KV) — only the chaos sites
    are renamed so ``REPRO_FAULT`` can target slab traffic independently of
    block traffic.  The prefix registry is inherited but unused (a state
    snapshot encodes the *whole* prefix, not a block-aligned piece of it).
    """

    SITE_SWAP_OUT = "slab_swap_out"
    SITE_SWAP_IN = "slab_swap_in"

    def __init__(self, device: SlabDeviceView, host: Optional[HostTier] = None):
        super().__init__(device, host, prefix_cache_blocks=0)
        device.pool.fault_site = "slab_alloc"
