"""AdapterStore: multi-tenant LoRA adapters over one shared paged base.

N tenants share one set of base weights and one KV block pool; the only
per-tenant state is a pair of low-rank deltas ``(A, B)`` per adapted
projection per layer.  This module owns that state, in the same two-tier
shape as the KV ``kv_store``:

* **device tier** — one stacked slab per projection, ``A (L, S, d_in, R)``
  and ``B (L, S, R, d_out)``, where ``S`` is the slot capacity
  (``REPRO_LORA_MAX_ADAPTERS``) and ``R`` the shared rank pad (Auto
  Schedule's granularity, ``repro.core.codegen.lora_tiles``).  The layer
  axis leads so the model's layer scan carries the per-layer slices as scan
  inputs; the slot axis is what the segmented kernels
  (``ops.lora_shrink`` / ``ops.lora_expand``) gather over with per-row slot
  indices.
* **host swap tier** — a write-through copy of every loaded adapter's
  padded weights.  Evicting an adapter just frees its device slot; loading
  it again is a slab write from the host copy, no checkpoint I/O.

Slots are refcounted (one ref per in-flight request using the adapter) and
LRU-ordered; ``load`` past capacity evicts the least-recently-used idle
(refcount-0, unpinned) slot or raises ``AdapterStoreFull`` when every slot
is busy — a full store must reject new tenants, never corrupt a live one.
``pin`` exempts an adapter from eviction (resident system tenants).

Adapters with a rank below the slot pad are zero-padded: the padding
contributes exactly zero through the kernels, so ragged ranks share one
slab shape and a rank-0 adapter is token-identical to the base model.  The
``alpha / rank`` LoRA scale is folded into ``B`` at load time, keeping the
kernels scale-free.

When no checkpoint exists (smoke/bench/gateway lazy loads), adapters are
*materialized from their name*: ``make_lora_params`` derives a deterministic
seed from the adapter name, so any declared tenant is servable and two
gateways agree on what ``base:tenant-a`` computes.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.perf import perf


class AdapterStoreFull(RuntimeError):
    """Every device slot is held by a pinned or in-flight adapter."""


def adapted_projections(cfg) -> "Dict[str, Tuple[int, int]]":
    """name -> (d_in, d_out) of every projection the store adapts: the four
    attention projections always; the MLP projections only for dense FFNs
    (MoE experts are per-token routed — a per-tenant delta there would need
    per-(token, expert) gathers; attention-only LoRA is the standard
    fallback and what this store provides for ``family='moe'``)."""
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    projs = {"q": (d, q), "k": (d, kv), "v": (d, kv), "o": (q, d)}
    if cfg.moe is None:
        if cfg.act == "swiglu":
            projs.update({"gate": (d, cfg.d_ff), "up": (d, cfg.d_ff)})
        else:
            projs.update({"wi": (d, cfg.d_ff)})
        projs.update({"down": (cfg.d_ff, d)})
    return projs


def make_lora_params(cfg, rank: int, seed: int, scale: float = 0.5
                     ) -> Dict[str, Tuple[np.ndarray, np.ndarray]]:
    """Synthesize per-layer LoRA weights: name -> (A (L, d_in, r),
    B (L, r, d_out)) float32.  Both factors are nonzero (unlike train-time
    zero-init B) and deliberately LARGE for a fine-tune (scale 0.5) so
    distinct tenants actually generate distinct tokens on the random-init
    smoke models — that divergence is what the multi-tenant isolation tests
    observe.  rank=0 yields empty factors (exact base behavior)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, (di, do) in adapted_projections(cfg).items():
        a = rng.standard_normal((cfg.n_layers, di, rank)) * scale
        b = rng.standard_normal((cfg.n_layers, rank, do)) * scale
        out[name] = (a.astype(np.float32), b.astype(np.float32))
    return out


def seed_for(name: str) -> int:
    """Deterministic adapter seed from its name (crc32, stable across
    processes — unlike ``hash``)."""
    return zlib.crc32(name.encode("utf-8"))


@dataclasses.dataclass
class _Slot:
    name: str
    rank: int
    refcount: int = 0
    pinned: bool = False
    tick: int = 0               # LRU clock value of the last touch


class AdapterStore:
    """Refcounted, LRU-evictable slab of per-tenant LoRA deltas."""

    def __init__(self, cfg, max_adapters: Optional[int] = None,
                 rank_cap: Optional[int] = None, dtype=None):
        import jax.numpy as jnp
        p = perf()
        self.cfg = cfg
        self.max_adapters = max(1, max_adapters or p.lora_max_adapters)
        cap = rank_cap if rank_cap is not None else max(16, p.lora_rank)
        # sublane-pad the shared rank slot (codegen.lora_tiles applies the
        # plan's granularity on top when the engine routes a schedule)
        self.rank_cap = max(8, ((cap + 7) // 8) * 8)
        self.dtype = dtype or jnp.dtype(cfg.dtype)
        self.projs = adapted_projections(cfg)
        self._slabs: Optional[Dict[str, Dict[str, object]]] = None
        self._slots: List[Optional[_Slot]] = [None] * self.max_adapters
        self._by_name: Dict[str, int] = {}
        self._host: Dict[str, Dict[str, Tuple[np.ndarray, np.ndarray]]] = {}
        self._host_rank: Dict[str, int] = {}
        self._tick = 0
        self.loads = 0
        self.evictions = 0
        self.host_reloads = 0

    # -- byte accounting ----------------------------------------------------

    def device_bytes(self) -> int:
        """Allocated device-slab footprint (zero until the first load —
        the slab only exists once a tenant does)."""
        if self._slabs is None:
            return 0
        itemsize = np.dtype(self.dtype).itemsize
        n = 0
        for di, do in self.projs.values():
            n += self.cfg.n_layers * self.max_adapters * self.rank_cap \
                * (di + do)
        return n * itemsize

    def host_bytes(self) -> int:
        """Write-through host-tier footprint (every loaded adapter, resident
        or evicted)."""
        n = 0
        for w in self._host.values():
            for a, b in w.values():
                n += a.nbytes + b.nbytes
        return n

    def per_adapter_bytes(self, rank: Optional[int] = None) -> int:
        """Device bytes one slot spends on one adapter (at the padded
        rank): the unit the ``REPRO_LORA_MAX_ADAPTERS`` cap multiplies."""
        itemsize = np.dtype(self.dtype).itemsize
        r = self.rank_cap if rank is None else rank
        return sum(self.cfg.n_layers * r * (di + do)
                   for di, do in self.projs.values()) * itemsize

    # -- tiers --------------------------------------------------------------

    def _alloc_slabs(self):
        import jax.numpy as jnp
        slabs = {}
        for name, (di, do) in self.projs.items():
            shape_a = (self.cfg.n_layers, self.max_adapters, di,
                       self.rank_cap)
            shape_b = (self.cfg.n_layers, self.max_adapters, self.rank_cap,
                       do)
            slabs[name] = {"a": jnp.zeros(shape_a, self.dtype),
                           "b": jnp.zeros(shape_b, self.dtype)}
        self._slabs = slabs

    def _write_slot(self, slot: int, weights):
        """Copy one adapter's padded (A, B) factors into device slot
        ``slot`` of every projection slab."""
        import jax.numpy as jnp
        for name in self.projs:
            a, b = weights[name]
            sl = self._slabs[name]
            sl["a"] = sl["a"].at[:, slot].set(jnp.asarray(a, self.dtype))
            sl["b"] = sl["b"].at[:, slot].set(jnp.asarray(b, self.dtype))

    def _pad_weights(self, weights, rank: int, alpha: float):
        """Zero-pad factors to the shared rank slot and fold the
        ``alpha/rank`` scale into B (host-tier canonical form)."""
        scale = (alpha / rank) if rank else 0.0
        out = {}
        for name, (di, do) in self.projs.items():
            a, b = weights[name]
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32) * scale
            if a.shape != (self.cfg.n_layers, di, rank) or \
                    b.shape != (self.cfg.n_layers, rank, do):
                raise ValueError(
                    f"adapter projection {name!r}: got A{a.shape} B{b.shape}"
                    f", want A({self.cfg.n_layers},{di},{rank}) "
                    f"B({self.cfg.n_layers},{rank},{do})")
            pad = self.rank_cap - rank
            out[name] = (np.pad(a, ((0, 0), (0, 0), (0, pad))),
                        np.pad(b, ((0, 0), (0, pad), (0, 0))))
        return out

    def _evict_one(self) -> int:
        """Free the least-recently-used idle slot, or raise."""
        victims = [(s.tick, i) for i, s in enumerate(self._slots)
                   if s is not None and s.refcount == 0 and not s.pinned]
        if not victims:
            raise AdapterStoreFull(
                f"all {self.max_adapters} adapter slots pinned or in use")
        _, idx = min(victims)
        name = self._slots[idx].name
        # host tier already holds the write-through copy; just drop the slot
        del self._by_name[name]
        self._slots[idx] = None
        self.evictions += 1
        return idx

    # -- public API ---------------------------------------------------------

    def load(self, name: str, weights=None, rank: Optional[int] = None,
             alpha: Optional[float] = None) -> int:
        """Make ``name`` device-resident; returns its slot index.  Already
        loaded -> LRU touch only.  ``weights=None`` reloads from the host
        tier if the adapter was evicted, else materializes synthetic
        factors from the adapter name (rank/alpha default to the
        ``REPRO_LORA_*`` knobs)."""
        if name in self._by_name:
            idx = self._by_name[name]
            self._touch(idx)
            return idx
        p = perf()
        if weights is None and name in self._host:
            padded = self._host[name]
            rank = self._host_rank[name]
            self.host_reloads += 1
        else:
            rank = p.lora_rank if rank is None else rank
            alpha = p.lora_alpha if alpha is None else alpha
            if rank > self.rank_cap:
                raise ValueError(f"adapter {name!r} rank {rank} exceeds "
                                 f"store rank cap {self.rank_cap}")
            if weights is None:
                weights = make_lora_params(self.cfg, rank, seed_for(name))
            padded = self._pad_weights(weights, rank, alpha)
        if self._slabs is None:
            self._alloc_slabs()
        try:
            idx = self._slots.index(None)
        except ValueError:
            idx = self._evict_one()
        self._write_slot(idx, padded)
        self._slots[idx] = _Slot(name=name, rank=rank)
        self._by_name[name] = idx
        self._host[name] = padded
        self._host_rank[name] = rank
        self._touch(idx)
        self.loads += 1
        return idx

    def _touch(self, idx: int):
        self._tick += 1
        self._slots[idx].tick = self._tick

    def acquire(self, name: str) -> int:
        """Slot index for a request entering flight; increfs (pair with
        ``release``).  Raises ``KeyError`` if not device-resident — the
        caller decides whether to ``load`` first."""
        idx = self._by_name[name]
        self._slots[idx].refcount += 1
        self._touch(idx)
        return idx

    def release(self, name: str):
        idx = self._by_name.get(name)
        if idx is not None and self._slots[idx].refcount > 0:
            self._slots[idx].refcount -= 1

    def pin(self, name: str):
        self._slots[self._by_name[name]].pinned = True

    def unpin(self, name: str):
        self._slots[self._by_name[name]].pinned = False

    def unload(self, name: str):
        """Drop an adapter from BOTH tiers.  Refuses while in flight."""
        idx = self._by_name.get(name)
        if idx is not None:
            s = self._slots[idx]
            if s.refcount > 0:
                raise RuntimeError(
                    f"adapter {name!r} has {s.refcount} requests in flight")
            del self._by_name[name]
            self._slots[idx] = None
        self._host.pop(name, None)
        self._host_rank.pop(name, None)

    def refcount(self, name: str) -> int:
        idx = self._by_name.get(name)
        return self._slots[idx].refcount if idx is not None else 0

    def is_loaded(self, name: str) -> bool:
        return name in self._by_name

    def known(self, name: str) -> bool:
        """Loaded on either tier."""
        return name in self._by_name or name in self._host

    def loaded(self) -> List[str]:
        """Device-resident adapter names, slot order."""
        return [s.name for s in self._slots if s is not None]

    def rank_of(self, name: str) -> int:
        return self._host_rank[name]

    def slabs(self) -> Optional[Dict[str, Dict[str, object]]]:
        """The stacked device slabs (projection -> {"a", "b"}), or None
        before any adapter was loaded — callers use that None to keep the
        LoRA branch out of the traced graph entirely."""
        return self._slabs

    def metrics(self) -> dict:
        return {
            "adapters_loaded": len(self._by_name),
            "adapter_loads": self.loads,
            "adapter_evictions": self.evictions,
            "adapter_host_reloads": self.host_reloads,
            "adapter_device_bytes": self.device_bytes(),
            "adapter_host_bytes": self.host_bytes(),
            "adapter_slot_cap": self.max_adapters,
        }
