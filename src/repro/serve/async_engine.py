"""Asyncio front-end over ``ServeEngine``: submit/cancel/stream decoupled
from the engine's step loop.

``ServeEngine`` is a closed-loop batch harness — ``run_until_done()`` owns
the caller's thread until every request retires.  Production traffic is the
opposite shape: concurrent requests arriving at arbitrary times, each
wanting its tokens the moment they are sampled.  ``AsyncServeEngine``
bridges the two:

  * a **background stepper thread** owns the engine exclusively and drives
    ``step()`` continuously (the engine is not thread-safe; nothing else may
    touch it).  When the engine drains, the thread parks on an event with a
    ``REPRO_GATEWAY_IDLE_MS`` timeout so an idle gateway burns no CPU and a
    fresh submit wakes it immediately;
  * callers talk to the stepper through a lock-guarded **command inbox**
    (submit/cancel are O(1) appends — never blocked behind a decode step);
  * tokens flow the other way through per-request ``asyncio.Queue``s: the
    engine's ``Request.on_token`` hook fires inside the step loop and the
    stepper forwards each token onto the caller's event loop with
    ``call_soon_threadsafe``, so SSE bytes leave the process while the next
    decode step is still running.

Determinism carries over from the engine: sampling is keyed on (seed, token
index), so a stream is byte-identical to what ``run_until_done()`` would
have produced for the same request — ``tests/test_gateway.py`` holds the
two against each other.  Under legacy drop-and-restart preemption
(``REPRO_KV_SWAP=0``) a replayed request re-fires ``on_token`` for indices
already delivered; the stepper dedupes on index so consumers never see a
duplicate.
"""
from __future__ import annotations

import asyncio
import itertools
import threading
from collections import deque
from typing import AsyncIterator, Dict, List, Optional, Sequence, Tuple

from repro.perf import perf
from repro.serve.engine import GREEDY, Request, SamplingParams, ServeEngine

# terminal queue item kinds (first tuple element)
TOKEN = "token"
DONE = "done"


class TokenStream:
    """One request's live token feed: ``async for token in stream``.

    ``finish_reason`` is set once the stream is exhausted: ``"length"``
    (ran to max_new / max_len), ``"cancelled"``, ``"rejected"`` (with
    ``reject_reason``), or ``"shutdown"`` when the engine stopped underneath
    the request.
    """

    def __init__(self, rid: int, req: Request,
                 queue: "asyncio.Queue[Tuple[str, object]]"):
        self.rid = rid
        self.req = req
        self.queue = queue
        self.finish_reason: str = ""
        # stepper-thread-side state: tokens forwarded so far (dedupe index
        # for legacy-preemption replays); touched only by the stepper.
        self.delivered = 0

    def __aiter__(self) -> AsyncIterator[int]:
        return self

    async def __anext__(self) -> int:
        if self.finish_reason:
            raise StopAsyncIteration
        kind, payload = await self.queue.get()
        if kind == DONE:
            self.finish_reason = str(payload)
            raise StopAsyncIteration
        return int(payload)  # kind == TOKEN

    async def drain(self) -> List[int]:
        """Collect the rest of the stream (non-streaming completions)."""
        toks = [t async for t in self]
        return toks


class AsyncServeEngine:
    """Async multiplexer over one ``ServeEngine``.

    Lifecycle: ``await start()`` binds the running event loop and spawns the
    stepper thread; ``submit()`` returns a ``TokenStream`` immediately;
    ``await stop()`` finishes the stepper (in-flight streams are terminated
    with ``finish_reason="shutdown"``).  One instance serves many concurrent
    callers on the same loop — the engine's continuous batching is what
    interleaves them.
    """

    def __init__(self, engine: ServeEngine, model_id: str = "model",
                 idle_s: Optional[float] = None):
        self.engine = engine
        self.model_id = model_id
        self.idle_s = (perf().gateway_idle_ms / 1e3) if idle_s is None \
            else idle_s
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._inbox: deque = deque()          # (kind, payload) commands
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stopping = False
        self._rids = itertools.count()
        # live streams, keyed by rid; owned by the stepper thread except for
        # the read in ``stats`` (len is atomic enough for a gauge)
        self._live: Dict[int, TokenStream] = {}

    # -- lifecycle ---------------------------------------------------------
    async def start(self) -> "AsyncServeEngine":
        assert self._thread is None, "start() called twice"
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(
            target=self._stepper, name=f"stepper-{self.model_id}",
            daemon=True)
        self._thread.start()
        return self

    async def stop(self) -> None:
        """Terminate the stepper; live streams get ``finish_reason=
        "shutdown"``.  Idempotent."""
        if self._thread is None:
            return
        self._stopping = True
        self._wake.set()
        await asyncio.get_running_loop().run_in_executor(
            None, self._thread.join)
        self._thread = None

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    # -- request API (event-loop side) -------------------------------------
    def submit(self, prompt: Sequence[int], max_new: int = 16,
               sampling: SamplingParams = GREEDY,
               deadline_ms: Optional[float] = None,
               adapter_id: Optional[str] = None) -> TokenStream:
        """Enqueue a generation; returns its ``TokenStream`` immediately.
        The request enters the engine's admission queue at the stepper's
        next iteration — this call never waits on a decode step.

        After ``stop()`` (or a dead stepper thread) the inbox would never
        drain, so the stream terminates immediately with
        ``finish_reason="shutdown"`` instead of hanging its consumer."""
        assert self._loop is not None, "submit() before start()"
        rid = next(self._rids)
        req = Request(rid=rid, prompt=list(prompt), max_new=max_new,
                      sampling=sampling, deadline_ms=deadline_ms,
                      adapter_id=adapter_id)
        stream = TokenStream(rid, req, asyncio.Queue())
        if self._stopping or not self.running:
            # called on the event loop thread: enqueue the terminal directly
            stream.queue.put_nowait((DONE, "shutdown"))
            return stream
        with self._lock:
            self._inbox.append(("submit", stream))
        self._wake.set()
        return stream

    def cancel(self, rid: int) -> None:
        """Abort ``rid`` mid-stream; its KV blocks are freed inside the
        stepper's next iteration and its stream ends with
        ``finish_reason="cancelled"``."""
        with self._lock:
            self._inbox.append(("cancel", rid))
        self._wake.set()

    async def generate(self, prompt: Sequence[int], max_new: int = 16,
                       sampling: SamplingParams = GREEDY,
                       deadline_ms: Optional[float] = None,
                       adapter_id: Optional[str] = None) -> List[int]:
        """Submit and await the full output (the non-streaming path)."""
        return await self.submit(prompt, max_new, sampling,
                                 deadline_ms=deadline_ms,
                                 adapter_id=adapter_id).drain()

    def stats(self) -> Dict[str, object]:
        eng = self.engine
        return {
            "model": self.model_id,
            "live_requests": len(self._live),
            "queued": len(eng.queue),
            "running": self.running,
            "degraded": eng.degraded,
            "step_crashes": eng._step_crashes,
            "requests_errored": len(eng.errored),
            "requests_expired": len(eng.expired),
            "requests_shed": len(eng.shed) + eng._gateway_shed,
            "pool_blocks_used": eng.pool.num_used,
            "pool_blocks": eng.pool.usable_blocks,
            "engine_steps": eng.steps,
        }

    # -- stepper thread ----------------------------------------------------
    def _emit(self, stream: TokenStream, item: Tuple[str, object]) -> None:
        """Forward one queue item onto the caller's event loop.  A closed
        loop (interpreter teardown mid-stream) drops the item — the consumer
        is gone with it."""
        try:
            self._loop.call_soon_threadsafe(stream.queue.put_nowait, item)
        except RuntimeError:
            pass

    def _register(self, stream: TokenStream) -> None:
        """Wire the engine hooks for one request and hand it to the engine.
        Runs on the stepper thread, so the hooks it installs only ever fire
        on this thread too."""
        req = stream.req

        def on_token(tok: int, idx: int) -> None:
            if idx < stream.delivered:
                return              # legacy-preemption replay; already sent
            stream.delivered = idx + 1
            self._emit(stream, (TOKEN, tok))

        def on_finish(r: Request) -> None:
            reason = r.finish_reason or "length"
            if r.rejected and r.reject_reason:
                reason = f"rejected: {r.reject_reason}"
            self._emit(stream, (DONE, reason))
            self._live.pop(stream.rid, None)

        req.on_token = on_token
        req.on_finish = on_finish
        self._live[stream.rid] = stream
        self.engine.submit(req)

    def _drain_inbox(self) -> None:
        with self._lock:
            cmds = list(self._inbox)
            self._inbox.clear()
        for kind, payload in cmds:
            if kind == "submit":
                self._register(payload)
            elif kind == "cancel":
                self.engine.cancel(payload)   # no-op if already finished

    def _stepper(self) -> None:
        # step_guarded (not raw step) is the crash-isolation boundary: an
        # exception inside the engine quarantines the poison request with
        # finish_reason="error" and the loop keeps serving everyone else.
        # The finally still runs if this thread dies some *other* way, so
        # live streams and racing submits always get a terminal event.
        try:
            while True:
                self._drain_inbox()
                if self._stopping:
                    break
                worked = self.engine.step_guarded()
                if not worked:
                    # drained: park until a submit/cancel/stop wakes us (the
                    # timeout covers a race where work arrived after step())
                    self._wake.wait(self.idle_s)
                    self._wake.clear()
        finally:
            # terminate whatever was still in flight so consumers unblock —
            # including submits that raced into the inbox after the last
            # drain (their streams were never registered with the engine)
            with self._lock:
                cmds = list(self._inbox)
                self._inbox.clear()
            for kind, payload in cmds:
                if kind == "submit":
                    self._emit(payload, (DONE, "shutdown"))
            for stream in list(self._live.values()):
                self._emit(stream, (DONE, "shutdown"))
            self._live.clear()
