"""Seeded fault injection + KV-leak invariants for the serving stack.

Production failure handling is only trustworthy if the failure paths
actually run.  This module gives the serve engine a deterministic way to
make them run: a ``FaultInjector`` parsed from the ``REPRO_FAULT`` env knob
(or built explicitly) raises ``InjectedFault`` from well-defined *sites* —
the entry points of ``BlockPool.alloc``, ``KVStore.swap_out``/``swap_in``,
and the engine's jitted prefill/decode dispatch ("step") — and the engine's
recovery machinery (quarantine, swap-failure downgrade, degraded health)
does the rest.  Faults fire at operation *entry*, before any bookkeeping
mutates, so a surviving engine must still satisfy the block-accounting
invariants ``check_invariants`` asserts (``tools/chaos_smoke.py`` and the
chaos tests hold it to that).

Spec grammar (comma-separated, one rule per clause)::

    REPRO_FAULT="alloc:p=0.05,swap_out:after=3,step:exc=1"

    site := alloc | swap_out | swap_in | step
          | slab_alloc | slab_swap_out | slab_swap_in   (state-slab ops)
    mode := p=<float>   each check at the site fires with probability p
                        (seeded RNG: REPRO_FAULT_SEED, default 0)
          | after=<N>   the (N+1)-th check fires, exactly once
          | exc=<N>     the first N checks fire

Multiple clauses may name the same site; any firing rule raises.  The
injector is plain Python (no jax) and cheap enough to leave wired in — a
``None`` injector costs one attribute test per site.
"""
from __future__ import annotations

import dataclasses
import os
import random
from typing import Dict, List, Optional

SITES = ("alloc", "swap_out", "swap_in", "step",
         # recurrent-state slab (SSM / hybrid families): same operations,
         # separately addressable so chaos runs can stress slab traffic
         # without also failing every block allocation
         "slab_alloc", "slab_swap_out", "slab_swap_in")


class InjectedFault(RuntimeError):
    """An artificial failure raised by a ``FaultInjector`` rule.  Carries the
    site so recovery paths (and tests) can tell injected faults from real
    bugs.  Deliberately NOT a ``PoolExhausted``: an injected alloc fault
    models an allocator/device error, not ordinary pool pressure, so it must
    not be absorbed by the eviction/preemption ladder."""

    def __init__(self, site: str, detail: str = ""):
        super().__init__(f"injected {site} fault" + (f" ({detail})" if detail
                                                     else ""))
        self.site = site


@dataclasses.dataclass
class _Rule:
    site: str
    mode: str          # "p" | "after" | "exc"
    value: float
    calls: int = 0
    fired: int = 0

    def should_fire(self, rng: random.Random) -> bool:
        self.calls += 1
        if self.mode == "p":
            fire = rng.random() < self.value
        elif self.mode == "after":
            fire = self.calls == int(self.value) + 1
        else:  # "exc"
            fire = self.calls <= int(self.value)
        self.fired += int(fire)
        return fire


class FaultInjector:
    """Deterministic fault source: ``check(site)`` raises ``InjectedFault``
    when any rule for that site fires.  Seeded, so a chaos run replays the
    same fault schedule given the same spec + seed + call sequence."""

    def __init__(self, rules: List[_Rule], seed: int = 0):
        self.rules = rules
        self.seed = seed
        self.rng = random.Random(seed)

    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultInjector":
        rules: List[_Rule] = []
        for clause in spec.split(","):
            clause = clause.strip()
            if not clause:
                continue
            try:
                site, mode_str = clause.split(":", 1)
                mode, value = mode_str.split("=", 1)
            except ValueError:
                raise ValueError(
                    f"bad REPRO_FAULT clause {clause!r} (want site:mode=value)")
            site, mode = site.strip(), mode.strip()
            if site not in SITES:
                raise ValueError(f"unknown fault site {site!r} "
                                 f"(known: {', '.join(SITES)})")
            if mode not in ("p", "after", "exc"):
                raise ValueError(f"unknown fault mode {mode!r} in {clause!r} "
                                 "(want p=<float>, after=<N>, or exc=<N>)")
            rules.append(_Rule(site=site, mode=mode, value=float(value)))
        return cls(rules, seed=seed)

    @classmethod
    def from_env(cls) -> Optional["FaultInjector"]:
        """The REPRO_FAULT / REPRO_FAULT_SEED knobs; None when unset — the
        common case must stay a single dict lookup."""
        spec = os.environ.get("REPRO_FAULT", "")
        if not spec:
            return None
        return cls.parse(spec, seed=int(os.environ.get("REPRO_FAULT_SEED",
                                                       "0")))

    def check(self, site: str) -> None:
        for r in self.rules:
            if r.site == site and r.should_fire(self.rng):
                raise InjectedFault(site, f"{r.mode}={r.value:g}")

    def counts(self) -> Dict[str, Dict[str, int]]:
        """Per-site {checks, fired} tallies (chaos_smoke reports these)."""
        out: Dict[str, Dict[str, int]] = {}
        for r in self.rules:
            d = out.setdefault(r.site, {"checks": 0, "fired": 0})
            d["checks"] += r.calls
            d["fired"] += r.fired
        return out


def check_kv_invariants(engine) -> List[str]:
    """Block-accounting invariants over a ``ServeEngine`` at a step boundary.

    Every device block the pool says is allocated must be reachable from
    exactly one of the engine's holder sets — active slot tables, parked
    (preempted) requests, the prefix registry — with a refcount equal to the
    number of holder references; ditto host-tier blocks vs parked requests;
    and the pool's reservation ledger must equal the sum of per-slot
    ``reserved_left``.  Returns human-readable violations (empty = healthy).
    Recovery paths call this after every quarantine so a leak shows up at
    the fault that caused it, not at end-of-run teardown.
    """
    from repro.serve.kv_store import DEVICE, HOST

    errs: List[str] = []
    holders: Dict[object, int] = {}   # Block handle (identity) -> references

    def note(b) -> None:
        holders[b] = holders.get(b, 0) + 1

    for a in engine.slots:
        if a is not None:
            for b in a.table.blocks:
                note(b)
    for parked in engine._parked.values():
        for b in parked.blocks:
            note(b)
    for entry in engine.store._prefixes:
        for b in entry.blocks:
            note(b)

    for b, n in holders.items():
        if b.refcount != n:
            errs.append(f"{b.tier} block {b.idx}: refcount {b.refcount} != "
                        f"{n} holder reference(s)")

    pool = engine.pool
    dev_live = {b.idx for b in holders if b.tier == DEVICE}
    pool_used = {i for i in range(1, pool.num_blocks) if i not in pool._free}
    leaked = sorted(pool_used - dev_live)
    phantom = sorted(dev_live - pool_used)
    if leaked:
        errs.append(f"device blocks leaked (allocated, no holder): {leaked}")
    if phantom:
        errs.append(f"device blocks held but marked free: {phantom}")

    host = engine.store.host
    host_live = {b.idx for b in holders if b.tier == HOST}
    host_used = {i for i in range(host.num_blocks) if i not in host._free}
    h_leaked = sorted(host_used - host_live)
    h_phantom = sorted(host_live - host_used)
    if h_leaked:
        errs.append(f"host blocks leaked (allocated, no holder): {h_leaked}")
    if h_phantom:
        errs.append(f"host blocks held but marked free: {h_phantom}")

    reserved = sum(a.reserved_left for a in engine.slots if a is not None)
    if reserved != pool.num_reserved:
        errs.append(f"reservation ledger {pool.num_reserved} != "
                    f"sum of slot reservations {reserved}")

    # recurrent-state slab (SSM / hybrid families): every allocated slot must
    # be some active request's state handle, every parked state must sit in
    # the slab's host tier, and refcounts must match holder counts — the same
    # contract as blocks, at slot granularity
    state_store = getattr(engine, "state_store", None)
    if state_store is not None:
        sholders: Dict[object, int] = {}
        for a in engine.slots:
            if a is not None and getattr(a, "state", None) is not None:
                sholders[a.state] = sholders.get(a.state, 0) + 1
        for parked in engine._parked.values():
            if getattr(parked, "state", None) is not None:
                sholders[parked.state] = sholders.get(parked.state, 0) + 1
        for b, n in sholders.items():
            if b.refcount != n:
                errs.append(f"state {b.tier} slot {b.idx}: refcount "
                            f"{b.refcount} != {n} holder reference(s)")
        spool = state_store.device.pool
        slab_live = {b.idx for b in sholders if b.tier == DEVICE}
        slab_used = {i for i in range(1, spool.num_blocks)
                     if i not in spool._free}
        leaked = sorted(slab_used - slab_live)
        phantom = sorted(slab_live - slab_used)
        if leaked:
            errs.append(f"state slots leaked (allocated, no holder): {leaked}")
        if phantom:
            errs.append(f"state slots held but marked free: {phantom}")
        shost = state_store.host
        sh_live = {b.idx for b in sholders if b.tier == HOST}
        sh_used = {i for i in range(shost.num_blocks) if i not in shost._free}
        h_leaked = sorted(sh_used - sh_live)
        h_phantom = sorted(sh_live - sh_used)
        if h_leaked:
            errs.append(f"host state slots leaked (allocated, no holder): "
                        f"{h_leaked}")
        if h_phantom:
            errs.append(f"host state slots held but marked free: {h_phantom}")
        if spool.num_reserved:
            errs.append(f"state slab has {spool.num_reserved} reserved slots "
                        "(slots are never reserved)")
    return errs
