"""Paged KV-cache bookkeeping: block pool, per-request block tables, metrics.

This module is the *allocator* half of the paged serve engine — pure Python /
numpy, no jax — so it can be unit-tested in milliseconds and reasoned about
independently of the model code.  The device-side layout it manages is

    cache["k"], cache["v"]: (n_layers, num_blocks, block_size, n_kv, head_dim)

Block 0 is the **null block**: never allocated, used as the scatter/gather
target for padded batch rows and padded block-table entries.  Garbage written
there is never read unmasked (attention masks by per-request sequence length),
so collisions on the null block are harmless by construction.

Admission control works on *worst-case footprints*: a request writes at most
``len(prompt) + max_new - 1`` KV positions over its lifetime (the last sampled
token's KV never lands), i.e. ``worst_case_blocks`` blocks.  The conservative
policy reserves that up front so a request, once admitted, can never fail a
mid-flight allocation; the optimistic policy reserves only the prompt's blocks
and relies on preemption when the pool runs dry (MNN-LLM-style block-wise
management, arXiv 2506.10443).

This module owns the *physical* allocator and metrics only.  Refcounted block
handles, tier movement (host swap), copy-on-write sharing, and the per-request
``BlockTable`` live one level up in ``repro.serve.kv_store``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

NULL_BLOCK = 0


def blocks_for_tokens(n_tokens: int, block_size: int) -> int:
    """Number of blocks needed to hold ``n_tokens`` KV entries."""
    return -(-n_tokens // block_size)  # ceil div


def worst_case_blocks(prompt_len: int, max_new: int, block_size: int) -> int:
    """Exact upper bound on blocks a request's KV can ever occupy.

    The last sampled token's KV is never written (generation stops before its
    decode step), so a request writes exactly ``prompt + max_new - 1``
    positions.  Admission reserves this bound — the old ``prompt + max_new``
    bound over-reserved one block whenever the total crossed a block edge.
    """
    return blocks_for_tokens(prompt_len + max(max_new - 1, 0), block_size)


class PoolExhausted(Exception):
    """Raised by ``alloc`` when no free block exists (callers that admit
    conservatively should never see this; optimistic callers catch it and
    preempt)."""


class BlockPool:
    """Fixed-size pool of KV blocks with reservation accounting.

    ``num_blocks`` counts the device-side slabs *including* the null block;
    ``usable_blocks`` is what requests can actually hold.  ``reserve`` /
    ``release`` move blocks between the free and reserved ledgers without
    touching device memory — an admitted request draws its actual blocks out
    of its own reservation via ``alloc(reserved=True)``.
    """

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 2:
            raise ValueError("need at least 2 blocks (block 0 is the null block)")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list keeps recently-freed (cache-warm) blocks hot.
        self._free: List[int] = list(range(num_blocks - 1, 0, -1))
        self._reserved = 0
        self.peak_used = 0
        # optional chaos hook (repro.serve.faults.FaultInjector): checked at
        # alloc entry, BEFORE any ledger mutation, so an injected allocator
        # failure can never corrupt the free list it is testing.  The site
        # name is an attribute so derived pools (the state slab's slot pool)
        # fault under their own REPRO_FAULT site.
        self.fault_injector = None
        self.fault_site = "alloc"

    # -- introspection ----------------------------------------------------
    @property
    def usable_blocks(self) -> int:
        return self.num_blocks - 1

    @property
    def num_free(self) -> int:
        """Blocks not handed out (ignores reservations)."""
        return len(self._free)

    @property
    def num_used(self) -> int:
        return self.usable_blocks - len(self._free)

    @property
    def num_reserved(self) -> int:
        return self._reserved

    def available(self) -> int:
        """Blocks free AND not spoken for by a reservation."""
        return len(self._free) - self._reserved

    def utilization(self) -> float:
        return self.num_used / self.usable_blocks

    # -- reservations -----------------------------------------------------
    def can_reserve(self, n: int) -> bool:
        return n <= self.available()

    def reserve(self, n: int) -> bool:
        """Logically earmark ``n`` free blocks; False if they don't exist."""
        if not self.can_reserve(n):
            return False
        self._reserved += n
        return True

    def release(self, n: int) -> None:
        """Return ``n`` unused reservation slots to the available ledger."""
        if n > self._reserved:
            raise ValueError(f"releasing {n} > reserved {self._reserved}")
        self._reserved -= n

    # -- alloc / free -----------------------------------------------------
    def alloc(self, reserved: bool = False) -> int:
        """Pop one free block id.  ``reserved=True`` draws the block out of an
        existing reservation (the caller must have reserved it); otherwise the
        block must be available over and above all reservations."""
        if self.fault_injector is not None:
            self.fault_injector.check(self.fault_site)
        if reserved:
            if self._reserved < 1:
                raise ValueError("alloc(reserved=True) without a reservation")
            if not self._free:
                raise PoolExhausted("reservation ledger corrupt: no free block")
            self._reserved -= 1
        else:
            if self.available() < 1:
                raise PoolExhausted(
                    f"no unreserved block free (used {self.num_used}/"
                    f"{self.usable_blocks}, reserved {self._reserved})")
        blk = self._free.pop()
        self.peak_used = max(self.peak_used, self.num_used)
        return blk

    def free(self, blocks: List[int]) -> None:
        for b in blocks:
            if b == NULL_BLOCK:
                raise ValueError("attempt to free the null block")
            if not (0 < b < self.num_blocks):
                raise ValueError(f"block id {b} out of range")
            if b in self._free:
                raise ValueError(f"double free of block {b}")
            self._free.append(b)


@dataclasses.dataclass
class ServeMetrics:
    """One serving run's scorecard (emitted into BENCH_serve.json).

    Counters report *delivered* work: tokens discarded by a legacy
    (non-swap) preemption are backed out, so throughput can't be inflated
    by churn.  Field groups: wall/request/token tallies, latency
    (``ttft_*`` submit->first-token, ``itl_mean_s`` between tokens), pool
    footprint vs the dense slot cache, tiered-KVStore traffic, and the
    serve-mesh width.
    """
    wall_s: float = 0.0                  # first step -> last productive step
    requests_submitted: int = 0
    requests_finished: int = 0
    requests_rejected: int = 0           # failed admission validation
    prefill_tokens: int = 0              # prompt tokens actually run
    decode_tokens: int = 0               # sampled tokens actually delivered
    engine_steps: int = 0
    tokens_per_sec: float = 0.0          # decode tokens / wall
    ttft_mean_s: float = 0.0             # submit -> first token
    ttft_max_s: float = 0.0
    itl_mean_s: float = 0.0              # mean inter-token latency
    peak_blocks_used: int = 0            # high-water mark of live KV blocks
    pool_blocks: int = 0                 # usable blocks in the pool
    block_size: int = 0
    peak_pool_utilization: float = 0.0   # peak_blocks_used / pool_blocks
    dense_equiv_blocks: int = 0          # max_batch * ceil(max_len/block_size)
    preemptions: int = 0
    # tiered-KVStore traffic (prefix sharing, copy-on-write, host swap)
    shared_blocks: int = 0               # block adoptions via fork()
    cow_copies: int = 0                  # shared blocks privatized before a write
    swap_out_blocks: int = 0             # device -> host (preemption parking)
    swap_in_blocks: int = 0              # host -> device (restore on readmission)
    re_prefill_avoided: int = 0          # prompt tokens NOT re-prefilled (shared
    #                                      prefixes + restored preemptions)
    # fault tolerance (PR 8): terminal outcomes past the happy path
    requests_expired: int = 0            # deadline reaper kills (queued/active)
    requests_shed: int = 0               # load-shed submits (bounded queue /
    #                                      gateway 429 pressure threshold)
    requests_errored: int = 0            # quarantined by a step-loop crash
    step_crashes: int = 0                # step() exceptions survived
    swap_failures: int = 0               # swap_out faults downgraded to the
    #                                      legacy drop-and-restart path
    degraded: bool = False               # >= max consecutive crashes; /health
    #                                      answers 503 until a clean step
    mesh_devices: int = 1                # "model"-axis width the pool is
    #                                      sharded over (1 = single device)
    tp_devices: int = 1                  # "model"-axis width the WEIGHTS are
    #                                      sharded over (1 = replicated)
    param_bytes_per_device: int = 0      # bytes one device stores
    param_bytes_replicated: int = 0      # logical (unsharded) param bytes
    # multi-LoRA (PR 9): AdapterStore footprint + per-tenant delivery
    adapters_loaded: int = 0             # device-resident adapters now
    adapter_loads: int = 0               # load() calls that wrote a slot
    adapter_evictions: int = 0           # LRU slot evictions (to host tier)
    adapter_host_reloads: int = 0        # evicted adapters brought back
    adapter_device_bytes: int = 0        # allocated slab footprint
    adapter_host_bytes: int = 0          # write-through host copies
    per_tenant: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)            # adapter_id ("base") -> tallies

    def to_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (f"{self.requests_finished}/{self.requests_submitted} requests, "
                f"{self.decode_tokens} decode tokens in {self.wall_s:.2f}s -> "
                f"{self.tokens_per_sec:.1f} tok/s | ttft {self.ttft_mean_s*1e3:.0f}ms "
                f"| itl {self.itl_mean_s*1e3:.1f}ms | pool peak "
                f"{self.peak_blocks_used}/{self.pool_blocks} blocks "
                f"({self.peak_pool_utilization:.0%}) | "
                f"{self.preemptions} preemptions, {self.requests_rejected} rejected"
                f" | {self.shared_blocks} shared / {self.cow_copies} CoW blocks, "
                f"swap {self.swap_out_blocks} out / {self.swap_in_blocks} in, "
                f"{self.re_prefill_avoided} prefill tokens avoided"
                + (f" | {self.requests_shed} shed / {self.requests_expired} "
                   f"expired / {self.requests_errored} errored, "
                   f"{self.step_crashes} step crashes"
                   + (" [DEGRADED]" if self.degraded else "")
                   if (self.requests_shed or self.requests_expired
                       or self.requests_errored or self.step_crashes) else "")
                + (f" | {self.adapters_loaded} adapters resident "
                   f"({self.adapter_device_bytes / 1e6:.2f} MB slab, "
                   f"{self.adapter_evictions} evictions)"
                   if self.adapters_loaded or self.adapter_loads else "")
                + (f" | pool sharded over {self.mesh_devices} devices"
                   if self.mesh_devices > 1 else "")
                + (f" | TP x{self.tp_devices}: "
                   f"{self.param_bytes_per_device / 1e6:.2f} MB/device of "
                   f"{self.param_bytes_replicated / 1e6:.2f} MB params"
                   if self.tp_devices > 1 else ""))


def dense_equiv_blocks(max_batch: int, max_len: int, block_size: int) -> int:
    """KV footprint (in blocks) of the old dense slot cache: every slot
    preallocates max_len positions regardless of the request in it."""
    return max_batch * blocks_for_tokens(max_len, block_size)
