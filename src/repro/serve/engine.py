"""Batched serving engine: slot-based continuous batching over a shared KV
cache (decode-centric, matching the paper's token-throughput evaluation).

Requests occupy fixed batch slots; every engine step decodes one token for
all live slots; finished slots are refilled from the queue after a prefill.
Prefill for a new request runs at batch=slot granularity and its KV is
spliced into the shared cache — the standard slot/continuous-batching
architecture, sized down so it runs on CPU for tests/examples.

Kernel planning goes through the unified ``repro.pipeline`` entry point: at
construction the engine compiles its attention block (max_len x head_dim)
once and keeps the resulting ``KernelPlan`` + ``CompileReport``.  The
pipeline's compile cache makes repeated engine construction (serve restarts,
tests) skip saturation and search entirely.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.pipeline import CompileOptions, Compiler, default_compiler
from repro.core.tensor_ir import inp, matmul, unary


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def attention_block_term(seq_len: int, head_dim: int):
    """The engine's attention inner block as a pipeline-compilable term."""
    q = inp("Q", (seq_len, head_dim))
    k = inp("K", (head_dim, seq_len))
    v = inp("V", (seq_len, head_dim))
    return matmul(unary(matmul(q, k), kind="exp"), v)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 256, compiler: Optional[Compiler] = None,
                 plan_kernels: bool = True):
        assert cfg.family in ("dense", "moe", "vlm"), \
            "slot engine currently targets decoder-LM families"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.fns = build_model(cfg)
        self.cache = self.fns.make_cache(max_batch, max_len)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.slot_len = np.zeros(max_batch, dtype=np.int64)
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, c, b: self.fns.decode_step(p, c, b))
        self.steps = 0
        # unified pipeline: compile the attention block once; cached, so a
        # second engine on the same shapes reuses the plan without re-search
        self.compile_report = None
        self.kernel_plan = None
        if plan_kernels:
            compiler = compiler or default_compiler()
            res = compiler.compile(
                attention_block_term(max_len, cfg.resolved_head_dim),
                options=CompileOptions(extraction="greedy",
                                       schedule_iterations=10))
            self.compile_report = res.report
            self.kernel_plan = res.report.kernel_plan

    # -- request lifecycle -----------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_into_slot(self, slot: int, req: Request):
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache1, logits = self.fns.prefill(self.params, {"tokens": toks})
        # splice single-request cache into the batched slot cache
        def splice(big, small):
            if small.shape[1] == 1 and big.shape[1] == self.max_batch:
                seq_ax = 2
                pad = [(0, 0)] * small.ndim
                pad[seq_ax] = (0, big.shape[seq_ax] - small.shape[seq_ax])
                small2 = jnp.pad(small.astype(big.dtype), pad)
                return big.at[:, slot:slot + 1].set(small2)
            return big
        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.slot_len[slot] = len(req.prompt)
        first = int(jnp.argmax(logits[0]))
        req.out.append(first)
        self.slots[slot] = req

    def _refill(self):
        for i in range(self.max_batch):
            if self.slots[i] is None and self.queue:
                self._prefill_into_slot(i, self.queue.pop(0))

    # -- engine step -------------------------------------------------------
    def step(self):
        """One decode step for all live slots (aligned decode: the engine
        tracks a per-slot length; the batched step uses the max and per-slot
        masking happens through the cache contents)."""
        self._refill()
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if not live:
            return False
        cur = int(self.slot_len[live].max())
        tok = np.zeros((self.max_batch, 1), np.int32)
        for i in live:
            tok[i, 0] = self.slots[i].out[-1]
        batch = {"token": jnp.asarray(tok), "cur_len": jnp.int32(cur)}
        self.cache, logits = self._decode(self.params, self.cache, batch)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.steps += 1
        for i in live:
            req = self.slots[i]
            req.out.append(int(nxt[i]))
            self.slot_len[i] += 1
            if len(req.out) >= req.max_new or self.slot_len[i] >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        return True

    def run_until_done(self, max_steps: int = 1000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_steps):
            if not self.step() and not self.queue:
                break
        return finished
