"""Paged-KV continuous-batching serve engine over a tiered KVStore.

KV memory is owned by ``repro.serve.kv_store``: refcounted block handles in
named storage tiers — the device block pool (``repro.serve.paged_cache``) and
a host swap tier.  Each request holds an ordered table of handles, blocks are
allocated as its sequence grows and released the step it retires, so live KV
scales with tokens actually resident instead of the dense slot cache's
``max_batch x max_len`` preallocation (MNN-LLM block-wise layout, arXiv
2506.10443).  On top of the handles the engine gets two storage-architecture
capabilities the flat pool couldn't express:

  * **Prefix sharing (copy-on-write)** — completed prompts register their
    blocks in the store's budgeted prefix registry; a later request whose
    prompt shares a prefix ``fork()``s the same physical blocks instead of
    re-prefilling them (``ServeMetrics.re_prefill_avoided``), and any write
    into a still-shared block is privatized by a device-side copy first.
  * **Preemption-by-swap** — optimistic admission's evictions park the
    victim's KV on the host tier (``REPRO_KV_SWAP=1``, the default) and
    restore it on re-admission, resuming mid-generation; with the knob off,
    preemption falls back to the legacy drop-and-restart-from-prompt.

Scheduling is continuous batching with **chunked prefill**: every engine step
runs (a) at most one prompt chunk for one admitting request and (b) one
batched decode step for every live request — a long prompt therefore never
stalls tokens streaming out of the decode batch.  Admission is worst-case by
default: the exact bound is ``prompt + max_new - 1`` written KV positions
(the last sampled token's KV never lands), plus one spare block when the
prefix registry may force a copy-on-write of the prompt's partial tail block.
``admission="optimistic"`` reserves only the prompt footprint and preempts
the youngest request when the pool runs dry.

Per-request sampling: greedy, temperature, top-k — Gumbel-max draws keyed on
(request seed, token index), stateless and host-side, so runs are exactly
reproducible (including across preemptions, swapped or restarted) with no
per-token device dispatch in the decode loop.

Kernel planning goes through the unified ``repro.pipeline`` entry point: the
engine compiles its *paged* attention shapes — a 1-token decode query and a
prefill chunk query against the pooled KV span — so the compiler plans for
the layout serving actually uses.  The plan's kv tile also fixes the paged
flash-attention kernel's pages-per-fetch (``repro.kernels.paged_attention``;
dispatch via the REPRO_PAGED_ATTN knob — kernel on TPU, dense-gather
fallback on CPU).  The pipeline's compile cache makes repeated engine
construction skip saturation and search entirely.

**Multi-device serving** (``mesh=`` or the REPRO_SERVE_MESH knob): the
device tier's block slab is sharded over the mesh's "model" axis on the
kv-heads dim (``repro.distributed.sharding.paged_cache_specs``) and the
paged attention paths run under shard_map grouped by KV head — outputs are
token-identical to a single-device run because no floating-point reduction
ever crosses a shard (per-shard head outputs are all-gathered, never
partial-summed).  Scheduling, admission, CoW, prefix sharing, and
preemption-by-swap are untouched: block ids stay global, and
``swap_out``/``swap_in`` gather/scatter each block's per-shard slices so the
host tier keeps holding whole blocks (replicated-on-host).

**Weight tensor parallelism** (``tp=True`` or REPRO_SERVE_TP=1, on top of a
mesh): params are ``device_put`` with the partition rules Auto
Distribution's SBP cost model emits (``repro.distributed.param_sharding``
— canonically column-parallel qkv/up/gate, row-parallel wo/down, so
per-device param bytes drop to ~1/n).  By default weights are gathered at
their use site, keeping decode bitwise identical; REPRO_TP_REDUCE_SCATTER=1
makes compute follow the stored layout with one all-reduce per layer
(fp32-tolerance closeness instead).  ``param_bytes_per_device`` /
``param_bytes_replicated`` report the storage win; see docs/sharding.md.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.codegen import lora_tiles, paged_pages_per_fetch
from repro.core.tensor_ir import inp, matmul, unary
from repro.distributed import param_sharding
from repro.models import build_model
from repro.models import attention as attn_lib
from repro.perf import perf
from repro.pipeline import CompileOptions, Compiler, default_compiler
from repro.kernels import lora as lora_kernels
from repro.serve.adapters import AdapterStore, AdapterStoreFull
from repro.serve.faults import FaultInjector, InjectedFault, check_kv_invariants
from repro.serve.kv_store import (DEVICE, HOST, Block, BlockTable, DeviceTier,
                                  HostTier, KVStore, SlabDeviceView, StateSlab)
from repro.serve.paged_cache import (BlockPool, PoolExhausted, ServeMetrics,
                                     blocks_for_tokens, dense_equiv_blocks,
                                     worst_case_blocks)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding strategy.  temperature <= 0 means greedy;
    top_k == 0 means the full vocabulary."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0


GREEDY = SamplingParams()


def _mesh_from_knob():
    """Resolve REPRO_SERVE_MESH: "0"/"" = single-device (None), "auto" =
    shard over every visible device, an int = shard over the first N."""
    knob = perf().serve_mesh
    if knob in ("", "0", "off"):
        return None
    from repro.launch.mesh import make_serve_mesh
    return make_serve_mesh(None if knob == "auto" else int(knob))


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int = 16
    sampling: SamplingParams = GREEDY
    # multi-LoRA (PR 9): which tenant adapter decorates this request's
    # projections; None = base-only (the traced graph stays structurally
    # adapter-free, so base requests are bitwise identical to a LoRA-less
    # engine).  The engine acquires a refcounted AdapterStore slot at submit
    # and releases it exactly once on whichever terminal path runs.
    adapter_id: Optional[str] = None
    _adapter_slot: int = -1
    _adapter_held: bool = False
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    rejected: bool = False
    cancelled: bool = False
    reject_reason: str = ""
    # fault-tolerance terminal states (PR 8)
    expired: bool = False       # deadline reaper killed it
    shed: bool = False          # bounded queue refused it at submit
    errored: bool = False       # quarantined by a step-loop crash
    error: str = ""             # why (crash message)
    # per-request deadline in ms from submit; None consults the
    # REPRO_SERVE_DEADLINE_MS default, 0 disables.  The engine stamps the
    # absolute monotonic cutoff into _deadline_at at submit time.
    deadline_ms: Optional[float] = None
    _deadline_at: float = 0.0
    # timing (monotonic seconds; filled in by the engine)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # streaming hooks (the async front-end wires these; both run inside the
    # engine's step loop, so they must be cheap and must not raise).
    # on_token(token_id, index): fired the moment a token is sampled.  Under
    # legacy drop-and-restart preemption (REPRO_KV_SWAP=0) a request replays
    # its deterministic sample stream, so indices can repeat — consumers
    # dedupe on ``index``, not on call count.
    on_token: Optional[Callable[[int, int], None]] = None
    # on_finish(request): fired exactly once, after done/rejected/cancelled
    # is set and the request's KV blocks are back in the pool.
    on_finish: Optional[Callable[["Request"], None]] = None

    @property
    def finish_reason(self) -> str:
        """OpenAI-style terminal state ("" while still running)."""
        if self.cancelled:
            return "cancelled"
        if self.expired:
            return "expired"
        if self.shed:
            return "shed"
        if self.errored:
            return "error"
        if self.rejected:
            return "rejected"
        if self.done:
            return "length"
        return ""


@dataclasses.dataclass
class _Active:
    """A request occupying a batch slot."""
    req: Request
    table: BlockTable
    reserved_left: int          # blocks still earmarked in the pool for us
    admit_seq: int              # admission order (preemption picks the max)
    next_prefill: int = 0       # prompt tokens already prefilled
    pos: int = 0                # KV entries written (valid only post-prefill)
    # stateful families (ssm/hybrid): the request's recurrent-state slab
    # slot, a refcounted handle in the engine's StateSlab (None otherwise)
    state: Optional[Block] = None

    @property
    def prefill_done(self) -> bool:
        return self.next_prefill >= len(self.req.prompt)


@dataclasses.dataclass
class _Parked:
    """A preempted request's KV, waiting on the host tier for re-admission.
    ``blocks`` mixes tiers: exclusive blocks were swapped to host; blocks
    shared with the prefix registry stay device-resident (other holders pin
    them anyway), and we just keep our reference."""
    blocks: List[Block]
    next_prefill: int
    pos: int
    # stateful families: the recurrent state, swapped whole to the slab's
    # host tier (state is never shared, so it always moves on park)
    state: Optional[Block] = None


# ---------------------------------------------------------------------------
# Pipeline terms: the attention shapes serving actually executes
# ---------------------------------------------------------------------------

def _attn_term(q_rows: int, kv_span: int, head_dim: int):
    """O = MatMul(Exp(MatMul(Q, K)), V) with ``q_rows`` queries against a
    ``kv_span``-position KV — the one attention inner block every serving
    shape instantiates."""
    q = inp("Q", (q_rows, head_dim))
    k = inp("K", (head_dim, kv_span))
    v = inp("V", (kv_span, head_dim))
    return matmul(unary(matmul(q, k), kind="exp"), v)


def attention_block_term(seq_len: int, head_dim: int):
    """Square attention inner block (kept for inspection tooling)."""
    return _attn_term(seq_len, seq_len, head_dim)


def paged_decode_attention_term(span: int, head_dim: int):
    """One decode token's attention against a request's pooled KV span
    (``span`` = max_blocks_per_seq * block_size gathered positions)."""
    return _attn_term(1, span, head_dim)


def chunked_prefill_attention_term(chunk: int, span: int, head_dim: int):
    """A prefill chunk's attention: ``chunk`` queries against the span."""
    return _attn_term(chunk, span, head_dim)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_len: int = 256, block_size: int = 16,
                 num_blocks: Optional[int] = None,
                 prefill_chunk_tokens: Optional[int] = None,
                 admission: str = "conservative",
                 host_blocks: Optional[int] = None,
                 prefix_cache_blocks: Optional[int] = None,
                 compiler: Optional[Compiler] = None,
                 plan_kernels: bool = True,
                 mesh=None,
                 tp: Optional[bool] = None,
                 max_queue: Optional[int] = None,
                 fault_injector=None):
        # mesh: a jax Mesh with a "model" axis to shard the KV pool over,
        # None to consult REPRO_SERVE_MESH, or False to force single-device
        # tp: also shard the WEIGHTS over the model axis with the partition
        # rules Auto Distribution emits (param_sharding); None consults
        # REPRO_SERVE_TP.  Requires a mesh; no-op without one.
        # max_queue: bound on the admission queue (submits past it are shed
        # with finish_reason="shed"); None consults REPRO_SERVE_MAX_QUEUE,
        # 0 = unbounded.
        # fault_injector: a repro.serve.faults.FaultInjector wired into the
        # allocator, the swap paths, and the step dispatch; None consults
        # REPRO_FAULT, False forces off (oracle/reference engines must not
        # inherit chaos from ambient env).
        # vlm is excluded deliberately: the paged prefill/decode path embeds
        # raw token ids with 2-D positions, which would silently degrade
        # M-RoPE + vision-embeds frontends; wiring the embeds interface
        # through chunked prefill is a roadmap item.
        assert cfg.family in ("dense", "moe", "ssm", "hybrid"), \
            "paged engine targets token-frontend decoder-LM families"
        assert admission in ("conservative", "optimistic")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.block_size = block_size
        self.max_blocks_per_seq = blocks_for_tokens(max_len, block_size)
        if num_blocks is None:
            # capacity parity with the dense slot cache, plus the null block;
            # smaller pools trade throughput for memory via admission control
            num_blocks = max_batch * self.max_blocks_per_seq + 1
        self.pool = BlockPool(num_blocks, block_size)
        self.admission = admission
        self.prefill_chunk_tokens = prefill_chunk_tokens or block_size

        self.fns = build_model(cfg)
        assert self.fns.decode_paged is not None, \
            f"family {cfg.family!r} has no paged decode path"
        assert self.fns.paged_block_copy is not None, \
            f"family {cfg.family!r} has no paged block data plane"
        # stateful families (ssm, hybrid) carry O(1) recurrent state per
        # request in a StateSlab tier beside the block pool; attention-free
        # families never touch the block table at all
        self.has_attention = cfg.family in ("dense", "moe", "hybrid")
        self.has_state = self.fns.state_slot_copy is not None
        if self.has_state:
            # scan-chunk alignment: engine chunk boundaries must land on
            # multiples of the SSD chunk so the associative-scan tree inside
            # an engine chunk matches the dense oracle's bitwise (the masked
            # tail is exact: dt=0 gives a=exp(0)=1, b=0, the scan identity)
            g = cfg.ssm.chunk
            self.prefill_chunk_tokens = max(
                g * ((self.prefill_chunk_tokens + g - 1) // g), g)

        # tiered KV store: device slab + host swap tier + prefix registry
        self.swap_enabled = perf().kv_swap and (host_blocks is None
                                                or host_blocks > 0)
        n_host = (host_blocks if host_blocks is not None else num_blocks) \
            if self.swap_enabled else 0
        prefix_budget = prefix_cache_blocks if prefix_cache_blocks \
            is not None else self.pool.usable_blocks // 4
        if self.has_state:
            # adopted KV blocks cannot reproduce a request's scan state, so
            # prefix sharing is structurally off for stateful families:
            # budget 0 makes match_prefix miss and register_prefix a no-op
            prefix_budget = 0

        # multi-device serving: shard the block slab over the mesh's "model"
        # axis on the kv-heads dim, replicate params, and leave every piece
        # of bookkeeping (global block ids, refcounts, tables) untouched.
        # mesh=None (default) consults REPRO_SERVE_MESH; mesh=False forces
        # single-device regardless of the knob (oracle/reference engines
        # must not be silently sharded by ambient env)
        if mesh is False:
            self.mesh = None
        else:
            self.mesh = mesh if mesh is not None else _mesh_from_knob()
        if self.mesh is not None and self.has_state:
            raise NotImplementedError(
                "sharded serving of ssm/hybrid families is not supported "
                "yet — the state slab has no mesh partition rules; run "
                "stateful families on a single-device engine")
        self.tp = bool(tp) if tp is not None else perf().serve_tp
        if self.mesh is None:
            self.tp = False
        self.tp_rules = None
        self.tp_report = None
        # slot 0 of the state slab is the null slot (padded decode rows)
        self.state_slots = max_batch + 1 if self.has_state else 0
        cache0 = (self.fns.make_paged_cache(num_blocks, block_size,
                                            state_slots=self.state_slots)
                  if self.has_state
                  else self.fns.make_paged_cache(num_blocks, block_size))
        shardings = None
        if self.mesh is not None:
            n_tp = int(self.mesh.shape.get("model", 1))
            if cfg.n_kv_heads % n_tp or cfg.n_heads % n_tp:
                raise ValueError(
                    f"serve mesh model axis {n_tp} must divide n_kv_heads "
                    f"{cfg.n_kv_heads} and n_heads {cfg.n_heads} — the pool "
                    "is sharded per KV head (GQA groups stay intact)")
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.distributed.sharding import paged_cache_specs, to_named
            abstract = jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), cache0)
            shardings = to_named(paged_cache_specs(cfg, abstract, self.mesh),
                                 self.mesh)
            if self.tp:
                # weight tensor parallelism: rules chosen by Auto
                # Distribution's SBP cost model, matched against the param
                # paths, device_put per-leaf — see param_sharding.py
                param_sharding.validate_tp_divisibility(cfg, n_tp)
                abstract_p = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    self.params)
                self.tp_rules = param_sharding.choose_tp_rules(cfg, n_tp)
                pspecs, self.tp_report = param_sharding.tp_param_specs(
                    cfg, abstract_p, n_tp, rules=self.tp_rules)
                self.params = jax.device_put(
                    self.params, to_named(pspecs, self.mesh))
            else:
                self.params = jax.device_put(
                    self.params, NamedSharding(self.mesh, PartitionSpec()))
        self._tp_reduce_scatter = self.tp and perf().tp_reduce_scatter
        self.param_bytes_replicated = param_sharding.param_bytes_total(
            self.params)
        self.param_bytes_per_device = param_sharding.param_bytes_per_device(
            self.params)
        device = DeviceTier(cache0, self.pool,
                            copy_block=self.fns.paged_block_copy,
                            read_block=self.fns.paged_block_read,
                            write_block=self.fns.paged_block_write,
                            shardings=shardings)
        self.store = KVStore(device, HostTier(n_host),
                             prefix_cache_blocks=prefix_budget)

        # state slab: per-request O(1) recurrent state as the degenerate
        # one-block case of the block pool — same refcounted handles, same
        # host swap tier, same ledger invariants.  The slab view shares the
        # DeviceTier (one cache pytree holds KV pages and state slots; the
        # slot data plane touches only the state leaves).
        self.state_store: Optional[StateSlab] = None
        if self.has_state:
            state_pool = BlockPool(self.state_slots, 1)
            slab_view = SlabDeviceView(device, state_pool,
                                       self.fns.state_slot_copy,
                                       self.fns.state_slot_read,
                                       self.fns.state_slot_write)
            # parked states can outnumber the live slots; host-full simply
            # downgrades the park to the legacy drop (perf, not correctness)
            n_state_host = 4 * max_batch if self.swap_enabled else 0
            self.state_store = StateSlab(slab_view, HostTier(n_state_host))

        # fault tolerance: chaos injector (opt-in), bounded queue, default
        # deadline, crash quarantine bookkeeping
        if fault_injector is False:
            self.faults = None
        else:
            self.faults = fault_injector if fault_injector is not None \
                else FaultInjector.from_env()
        self.pool.fault_injector = self.faults
        self.store.fault_injector = self.faults
        if self.state_store is not None:
            self.state_store.fault_injector = self.faults
            self.state_store.device.pool.fault_injector = self.faults
        self.max_queue = perf().serve_max_queue if max_queue is None \
            else max_queue
        self.default_deadline_ms = perf().serve_deadline_ms
        self.shed_pressure = perf().serve_shed_pressure
        self.max_consecutive_crashes = max(perf().serve_max_crashes, 1)
        self.degraded = False
        self.invariant_violations: List[str] = []
        self._blame_rid: Optional[int] = None    # request under the knife now
        self._crash_rid: Optional[int] = None    # captured at raise time
        self._consecutive_crashes = 0
        self._step_crashes = 0
        self._swap_failures = 0
        self._gateway_shed = 0   # 429s the gateway refused pre-submit

        self.slots: List[Optional[_Active]] = [None] * max_batch
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.rejected: List[Request] = []
        self.cancelled: List[Request] = []
        self.expired: List[Request] = []
        self.errored: List[Request] = []
        self.shed: List[Request] = []
        self._parked: Dict[int, _Parked] = {}
        self.steps = 0
        self._admit_seq = 0
        self._t0: Optional[float] = None
        self._t_last = 0.0
        self._submitted = 0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._preemptions = 0
        self._re_prefill_avoided = 0
        # per-tenant delivery tallies (key: adapter_id, "base" for None)
        self._tenant_tokens: Dict[str, int] = {}
        self._tenant_finished: Dict[str, int] = {}

        # multi-LoRA adapter store: per-tenant low-rank deltas in a
        # refcounted two-tier slab (device + host write-through).  Zero
        # device bytes until the first load, so LoRA-less engines pay
        # nothing.  In-flight requests hold a ref, so a live tenant can
        # never be evicted out from under its own decode.
        self.adapters = AdapterStore(cfg)

        # unified pipeline: compile the paged attention shapes once (cached,
        # so a second engine on the same shapes skips the search passes)
        self.compile_reports: Dict[str, object] = {}
        self.compile_report = None
        self.kernel_plan = None
        if plan_kernels and self.has_attention:
            compiler = compiler or default_compiler()
            hd = cfg.resolved_head_dim
            span = self.max_blocks_per_seq * block_size
            opts = CompileOptions(extraction="greedy", schedule_iterations=10)
            dec = compiler.compile(paged_decode_attention_term(span, hd),
                                   options=opts)
            pre = compiler.compile(
                chunked_prefill_attention_term(self.prefill_chunk_tokens,
                                               span, hd), options=opts)
            self.compile_reports = {"decode": dec.report, "prefill": pre.report}
            self.compile_report = dec.report
            self.kernel_plan = dec.report.kernel_plan

        # the compiler's kv tile for the *decode* shape sets how many pages
        # the paged-attention kernel streams per grid step; the jit wrappers
        # publish it at trace time so the traced graph bakes this plan in
        # even if another engine has since planned different shapes
        self.pages_per_fetch = 1
        self.lora_block_out = 256
        if self.kernel_plan is not None:
            self.pages_per_fetch = paged_pages_per_fetch(
                self.kernel_plan, block_size, self.max_blocks_per_seq)
            # the same plan routes the segmented LoRA expand's output tile
            self.lora_block_out, _ = lora_tiles(
                self.kernel_plan, cfg.d_model, self.adapters.rank_cap)

        # set_serve_mesh is restored after tracing (the finally runs at
        # trace time, right after the model graph is built) so the module
        # state never leaks into unrelated traces in the same process
        def _decode(p, c, b):
            attn_lib.set_paged_plan(self.pages_per_fetch)
            lora_kernels.set_lora_plan(self.lora_block_out)
            attn_lib.set_serve_mesh(self.mesh)
            param_sharding.set_serve_tp(self.mesh if self.tp else None,
                                        self._tp_reduce_scatter)
            try:
                return self.fns.decode_paged(p, c, b)
            finally:
                attn_lib.set_serve_mesh(None)
                param_sharding.set_serve_tp(None)

        def _prefill(p, c, b, m_used):
            attn_lib.set_paged_plan(self.pages_per_fetch)
            lora_kernels.set_lora_plan(self.lora_block_out)
            attn_lib.set_serve_mesh(self.mesh)
            param_sharding.set_serve_tp(self.mesh if self.tp else None,
                                        self._tp_reduce_scatter)
            try:
                return self.fns.prefill_chunk(p, c, b, m_used=m_used)
            finally:
                attn_lib.set_serve_mesh(None)
                param_sharding.set_serve_tp(None)

        self._decode_fn = jax.jit(_decode)
        # one retrace per distinct m_used (bounded by max_blocks_per_seq),
        # each strictly cheaper than the old full-table trace
        self._prefill_fn = jax.jit(_prefill, static_argnames=("m_used",))

    # the jitted fns thread the device slab functionally; the store's device
    # tier holds the current reference between dispatches
    @property
    def cache(self):
        return self.store.device.cache

    @cache.setter
    def cache(self, value):
        # _pin re-asserts the slab's mesh sharding (no-op when unsharded or
        # when GSPMD preserved it, which the shard_map out_specs guarantee)
        self.store.device.cache = self.store.device._pin(value)

    # -- multi-LoRA adapters -----------------------------------------------
    def load_adapter(self, name: str, weights=None,
                     rank: Optional[int] = None,
                     alpha: Optional[float] = None) -> int:
        """Make tenant ``name``'s adapter device-resident (synthesizing
        deterministic factors from the name when ``weights`` is None) and
        return its slot.  Multi-LoRA is single-device for now: the segmented
        gather kernels run outside the shard_map the sharded attention paths
        trace, so a mesh engine refuses adapters rather than silently
        computing wrong deltas."""
        if self.mesh is not None:
            raise NotImplementedError(
                "multi-LoRA serving is not supported on a sharded serve "
                "mesh yet — run adapters on a single-device engine")
        return self.adapters.load(name, weights=weights, rank=rank,
                                  alpha=alpha)

    def _release_adapter(self, req: Request) -> None:
        """Drop ``req``'s adapter ref exactly once, whichever terminal path
        runs first (retire / reject / cancel / expire / quarantine)."""
        if req._adapter_held:
            req._adapter_held = False
            self.adapters.release(req.adapter_id)

    def _tenant_count(self, req: Request, n: int = 1) -> None:
        t = req.adapter_id or "base"
        self._tenant_tokens[t] = self._tenant_tokens.get(t, 0) + n

    def _lora_descriptor(self, ids: np.ndarray) -> Optional[dict]:
        """``batch["lora"]`` for one dispatch (``ids``: adapter slot per
        row, -1 = base), or None when no row uses an adapter.  The None
        keeps every LoRA op out of the traced graph — that structural
        absence is the ``adapter_id=None`` bitwise-identity contract."""
        if not (ids >= 0).any():
            return None
        slabs = self.adapters.slabs()
        assert slabs is not None, "row holds an adapter slot but no slab"
        return {"ids": jnp.asarray(ids, jnp.int32), "slabs": slabs}

    # -- request lifecycle -----------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue ``req`` (FIFO).  Admission control runs inside ``step``:
        the request may later be rejected (impossible footprint — see
        ``Request.reject_reason``) or queued until blocks free up.  The
        engine mutates ``req`` in place: ``out`` grows as tokens are
        sampled, ``done``/``rejected`` flip on completion, and the
        ``t_submit``/``t_first``/``t_done`` stamps feed ``ServeMetrics``.

        A bounded queue (``max_queue`` / REPRO_SERVE_MAX_QUEUE) sheds the
        request instead of enqueueing it — ``finish_reason="shed"``, hooks
        fired — so a flooded engine answers immediately rather than growing
        an unbounded backlog.  The deadline cutoff (per-request
        ``deadline_ms`` or the REPRO_SERVE_DEADLINE_MS default) is stamped
        here; the step loop's reaper enforces it."""
        req.t_submit = time.monotonic()
        self._submitted += 1
        if self.max_queue and len(self.queue) >= self.max_queue:
            req.shed = True
            req.done = True
            req.t_done = req.t_submit
            self.shed.append(req)
            if req.on_finish is not None:
                req.on_finish(req)
            return
        if req.adapter_id is not None:
            if self.mesh is not None:
                raise NotImplementedError(
                    "multi-LoRA serving is not supported on a sharded "
                    "serve mesh yet")
            if not self.adapters.known(req.adapter_id):
                self._reject(req, f"unknown adapter {req.adapter_id!r}")
                return
            try:
                if not self.adapters.is_loaded(req.adapter_id):
                    # evicted to the host tier; slab write brings it back
                    self.adapters.load(req.adapter_id)
                req._adapter_slot = self.adapters.acquire(req.adapter_id)
            except AdapterStoreFull as e:
                self._reject(req, f"adapter store full: {e}")
                return
            req._adapter_held = True
        dl = req.deadline_ms if req.deadline_ms is not None \
            else self.default_deadline_ms
        if dl and dl > 0:
            req._deadline_at = req.t_submit + dl / 1e3
        self.queue.append(req)

    def _reject(self, req: Request, reason: str) -> None:
        self._release_adapter(req)
        req.rejected = True
        req.done = True
        req.reject_reason = reason
        self.rejected.append(req)
        if req.on_finish is not None:
            req.on_finish(req)

    def _admission_need(self, req: Request, parked: Optional[_Parked]) -> int:
        """Blocks to reserve at admission.

        Conservative: the exact lifetime bound (prompt + max_new - 1 written
        positions), plus one spare when the prefix registry may retain the
        prompt's partial tail block and force a copy-on-write allocation at
        the first decode write — the spare is what keeps the admitted-never-
        dies guarantee with sharing enabled.  Optimistic: just the prompt.
        A restored request already holds its written blocks; it reserves the
        remaining growth plus one slot per host block to swap back in.
        """
        if not self.has_attention:
            # attention-free: the footprint is one fixed-size state slot,
            # bounded by construction (slots == max_batch) — no KV blocks
            # to reserve, admission is gated by batch slots alone
            return 0
        plen, bs = len(req.prompt), self.block_size
        worst = worst_case_blocks(plen, req.max_new, bs)
        if parked is not None:
            swap_ins = sum(1 for b in parked.blocks if b.tier == HOST)
            if self.admission == "optimistic":
                return swap_ins
            cow_spare = 1 if (self.store.prefix_cache_blocks > 0 and plen % bs
                              and req.max_new >= 2 and parked.pos == 0) else 0
            return worst - len(parked.blocks) + swap_ins + cow_spare
        if self.admission == "optimistic":
            return blocks_for_tokens(plen, bs)
        cow_spare = 1 if (self.store.prefix_cache_blocks > 0 and plen % bs
                          and req.max_new >= 2) else 0
        # clamp: the spare must not make a barely-fitting request unadmittable
        # (the CoW fallback path evicts/preempts if the spare was clamped off)
        return min(worst + cow_spare, self.pool.usable_blocks)

    def _admit(self) -> int:
        """Move queued requests into free slots, FIFO, under admission
        control.  Head-of-line order is preserved: if the head doesn't fit
        *right now*, nothing behind it jumps the queue."""
        admitted = 0
        while self.queue:
            req = self.queue[0]
            worst = worst_case_blocks(len(req.prompt), req.max_new,
                                      self.block_size)
            if not req.prompt:
                self.queue.pop(0)
                self._reject(req, "empty prompt")
                continue
            if req.max_new < 1:
                self.queue.pop(0)
                self._reject(req, f"max_new must be >= 1, got {req.max_new}")
                continue
            if len(req.prompt) + req.max_new > self.max_len:
                self.queue.pop(0)
                self._reject(req, f"prompt+max_new {len(req.prompt) + req.max_new}"
                                  f" exceeds max_len {self.max_len}")
                continue
            if self.has_attention and worst > self.pool.usable_blocks:
                self.queue.pop(0)
                self._reject(req, f"worst-case footprint {worst} blocks exceeds "
                                  f"pool capacity {self.pool.usable_blocks}")
                continue
            slot = next((i for i, s in enumerate(self.slots) if s is None), None)
            if slot is None:
                break
            parked = self._parked.get(req.rid)
            need = self._admission_need(req, parked)
            if not self.pool.reserve(need):
                # pressure-relief ladder, mirroring _alloc_device: the prefix
                # registry is droppable cache, and OTHER parked requests'
                # stranded device blocks (shared at preemption, exclusive
                # since) can move to the host tier — without this, a drained
                # engine with a strand-blocked queue head would halt with
                # requests permanently queued
                self.store.evict_prefixes(need - self.pool.available())
                if not self.pool.reserve(need):
                    self._swap_parked_out(need - self.pool.available(),
                                          exclude_rid=req.rid)
                    if not self.pool.reserve(need):
                        break
            a = _Active(req=req, table=BlockTable(self.block_size),
                        reserved_left=need, admit_seq=self._admit_seq)
            if parked is not None:
                try:
                    with self._blame(req.rid):
                        self._restore(a, parked)
                except BaseException:
                    # failed restore: ``a`` was never slotted, so quarantine
                    # can't reach what it holds — release it here (req stays
                    # at queue[0] with its remaining parked blocks; the
                    # quarantine path drops those and fails the request)
                    a.table.release_to(self.store)
                    self.pool.release(a.reserved_left)
                    a.reserved_left = 0
                    if a.state is not None:
                        self.state_store.decref(a.state)
                        a.state = None
                    raise
            elif self.state_store is not None:
                # fresh stateful request: claim its slab slot now.  Exhaustion
                # is impossible by construction (slots == max_batch, and a
                # free batch slot implies a free slab slot) — a raise here is
                # an injected slab_alloc fault, and quarantine finds the
                # request still at queue[0] holding nothing
                try:
                    with self._blame(req.rid):
                        a.state = self.state_store.alloc()
                except BaseException:
                    self.pool.release(a.reserved_left)
                    a.reserved_left = 0
                    raise
            self.slots[slot] = a
            self._admit_seq += 1
            self.queue.pop(0)
            admitted += 1
        return admitted

    def _restore(self, a: _Active, parked: _Parked) -> None:
        """Re-admission of a preempted request: swap its parked blocks back
        onto the device and resume exactly where it stopped — this replaces
        the legacy restart-from-prompt.

        Crash-safe: blocks move out of ``parked.blocks`` only once fully
        restored, and a swap_in/alloc failure mid-restore undoes its own
        partial allocation before propagating — so a quarantine can release
        ``a.table`` plus the *remaining* parked blocks without double-frees.
        """
        if parked.state is not None:
            # the recurrent state comes back first: one slab slot, swapped in
            # whole.  A failure undoes its own allocation; quarantine then
            # drops ``parked`` (including the still-parked state block).
            dst = self.state_store.alloc()
            try:
                a.state = self.state_store.swap_in(parked.state, dst)
            except BaseException:
                self.state_store.decref(dst)
                raise
            parked.state = None
        while parked.blocks:
            b = parked.blocks[0]
            if b.tier == DEVICE:
                a.table.blocks.append(b)       # stayed resident (shared)
            else:
                dst = self.store.alloc(reserved=True)
                a.reserved_left -= 1
                try:
                    restored = self.store.swap_in(b, dst)
                except BaseException:
                    self.store.decref(dst)     # undo: dst never held data
                    self.pool.reserve(1)       # re-earmark the freed block
                    a.reserved_left += 1
                    raise
                a.table.blocks.append(restored)
            parked.blocks.pop(0)
        a.next_prefill = parked.next_prefill
        a.pos = parked.pos
        # the legacy path would have re-prefilled everything written so far
        self._re_prefill_avoided += parked.next_prefill
        del self._parked[a.req.rid]

    # -- block accounting --------------------------------------------------
    def _alloc_device(self, a: _Active) -> Optional[Block]:
        """One device block for ``a``: reservation first, then the open pool;
        under pressure evict prefix-cache entries, swap parked stragglers
        out, and finally preempt the youngest active request.  None means
        ``a`` itself was the youngest and got preempted."""
        while True:
            if a.reserved_left > 0:
                # alloc BEFORE decrementing: a crash inside alloc (injected
                # or real) must leave the slot ledger matching the pool's
                blk = self.store.alloc(reserved=True)
                a.reserved_left -= 1
                return blk
            try:
                return self.store.alloc()
            except PoolExhausted:
                if self.store.evict_prefixes(1) > 0:
                    continue
                if self._swap_parked_out(1) > 0:
                    continue
                # Evict the youngest active request — possibly ourselves.
                # Age-ordered eviction means the oldest request always makes
                # progress, so overcommit can't livelock into mutual
                # preemption ping-pong.
                victim = max((s for s in self.slots if s is not None),
                             key=lambda s: s.admit_seq)
                self._requeue(victim)
                if victim is a:
                    return None

    def _swap_parked_out(self, min_blocks: int,
                         exclude_rid: Optional[int] = None) -> int:
        """Parked requests can strand device blocks (blocks that were shared
        at preemption time and have since gone exclusive); push them to the
        host tier to relieve pool pressure.  ``exclude_rid`` protects the
        request currently being admitted — swapping its own resident blocks
        out would invalidate the admission need just computed for it."""
        freed = 0
        for rid, parked in self._parked.items():
            if rid == exclude_rid:
                continue
            for j, b in enumerate(parked.blocks):
                if (b.tier == DEVICE and not b.shared
                        and self.store.host.num_free > 0):
                    try:
                        parked.blocks[j] = self.store.swap_out(b)
                    except InjectedFault:
                        # swap faults at entry: the block is still intact on
                        # device — skip it, pressure relief just frees less
                        self._swap_failures += 1
                        continue
                    freed += 1
                    if freed >= min_blocks:
                        return freed
        return freed

    def _grow(self, a: _Active, n_tokens: int) -> bool:
        """Grow ``a``'s table to hold ``n_tokens`` positions; False if the
        pool ran dry and preemption evicted ``a`` itself (optimistic mode —
        conservative reservations make this infallible)."""
        if not self.has_attention:
            return True  # attention-free: no KV table to grow
        while a.table.capacity < n_tokens:
            blk = self._alloc_device(a)
            if blk is None:
                return False
            a.table.blocks.append(blk)
        return True

    def _make_writable(self, a: _Active, start: int, end: int) -> bool:
        """Privatize every shared block overlapping write positions
        [start, end) — copy-on-write: sharers (prefix registry, forked
        siblings) keep the original, ``a`` gets a device-side copy.  False if
        allocating a copy preempted ``a`` itself."""
        if not self.has_attention:
            return True
        bs = self.block_size
        for i in range(start // bs, min((end - 1) // bs + 1,
                                        len(a.table.blocks))):
            while a.table.blocks[i].shared:
                dst = self._alloc_device(a)
                if dst is None:
                    return False
                if not a.table.blocks[i].shared:
                    # eviction inside _alloc_device dropped the other holder;
                    # the block went exclusive under us — write in place
                    self.store.decref(dst)
                    break
                a.table.blocks[i] = self.store.cow_into(a.table.blocks[i], dst)
        return True

    def _requeue(self, victim: _Active) -> None:
        """Preempt ``victim`` back to the queue head.  With the host tier
        enabled (REPRO_KV_SWAP=1) its KV is parked there and restored on
        re-admission — generated tokens survive.  Otherwise (or when the host
        tier is full) fall back to the legacy drop: KV and generated tokens
        are discarded and the request restarts from its prompt."""
        self.pool.release(victim.reserved_left)
        victim.reserved_left = 0
        req = victim.req
        # attention families only park victims that actually hold KV: parking
        # an empty table would re-admit with a zero reservation (no
        # backpressure) and ping-pong straight back into preemption under
        # pool pressure.  Stateful families park whenever their slab state
        # can move — the state block IS the resumable footprint, even with an
        # empty (or absent) KV table.
        parked: Optional[List[Block]] = None
        state_parked: Optional[Block] = None
        holds = bool(victim.table.blocks) or (
            self.state_store is not None and victim.state is not None)
        can = self.swap_enabled and holds \
            and self.store.can_swap_out(victim.table.blocks)
        if can and self.state_store is not None:
            can = victim.state is not None \
                and self.state_store.can_swap_out([victim.state])
        if can:
            park_ok = True
            if self.state_store is not None:
                try:
                    state_parked = self.state_store.swap_out(victim.state)
                    victim.state = None
                except Exception as e:  # noqa: BLE001 — downgrade
                    self._swap_failures += 1
                    print(f"serve-engine: state swap_out failed parking "
                          f"request {req.rid} ({type(e).__name__}: {e}); "
                          "dropping its state (legacy restart)",
                          file=sys.stderr)
                    park_ok = False
            if park_ok:
                parked = []
                try:
                    for b in victim.table.blocks:
                        parked.append(self.store.swap_out(b))
                except Exception as e:  # noqa: BLE001 — downgrade, don't crash
                    # swap failed mid-park: degrade to the legacy drop.  Faults
                    # fire at swap_out entry, so the failing block is still a
                    # live device ref; release everything parked so far plus
                    # the untouched remainder and let the request restart from
                    # its prompt — token-identical by stateless-sampling
                    # replay.
                    self._swap_failures += 1
                    print(f"serve-engine: swap_out failed parking request "
                          f"{req.rid} ({type(e).__name__}: {e}); dropping its "
                          "KV (legacy restart)", file=sys.stderr)
                    for b in parked:
                        self.store.decref(b)
                    for b in victim.table.blocks[len(parked):]:
                        self.store.decref(b)
                    victim.table.blocks = []
                    parked = None
                    if state_parked is not None:
                        # already on the slab's host tier; the restart
                        # re-creates state from scratch, so just drop it
                        self.state_store.decref(state_parked)
                        state_parked = None
        if parked is not None:
            victim.table.blocks = []
            self._parked[req.rid] = _Parked(
                blocks=parked, next_prefill=victim.next_prefill,
                pos=victim.pos, state=state_parked)
        else:
            victim.table.release_to(self.store)
            if victim.state is not None:
                self.state_store.decref(victim.state)
                victim.state = None
            # counters report *delivered* work: back out the discarded tokens
            # so preemption churn can't inflate the CI-gated tokens/sec
            self._prefill_tokens -= victim.next_prefill
            self._decode_tokens -= max(len(req.out) - 1, 0)
            self._tenant_count(req, -len(req.out))  # replay re-emits them
            req.out.clear()
        self.queue.insert(0, req)
        self.slots[self.slots.index(victim)] = None
        self._preemptions += 1

    def _retire(self, a: _Active, now: Optional[float] = None) -> None:
        self._release_adapter(a.req)
        t = a.req.adapter_id or "base"
        self._tenant_finished[t] = self._tenant_finished.get(t, 0) + 1
        a.req.done = True
        a.req.t_done = time.monotonic() if now is None else now
        a.table.release_to(self.store)
        self.pool.release(a.reserved_left)
        a.reserved_left = 0
        if a.state is not None:
            self.state_store.decref(a.state)
            a.state = None
        self.finished.append(a.req)
        self.slots[self.slots.index(a)] = None
        if a.req.on_finish is not None:
            a.req.on_finish(a.req)

    # -- cancellation ------------------------------------------------------
    def _drop_parked(self, rid: int) -> None:
        parked = self._parked.pop(rid, None)
        if parked is not None:
            for b in parked.blocks:
                self.store.decref(b)
            if parked.state is not None:
                self.state_store.decref(parked.state)

    def _finish_cancel(self, req: Request) -> None:
        self._release_adapter(req)
        req.cancelled = True
        req.done = True
        req.t_done = time.monotonic()
        self.cancelled.append(req)
        if req.on_finish is not None:
            req.on_finish(req)

    def cancel(self, rid: int) -> bool:
        """Abort request ``rid`` wherever it currently lives — queued,
        occupying a batch slot, or parked on the host tier after preemption —
        and return every KV block it held to the pool the same call (a
        mid-stream client disconnect must free memory immediately, not when
        the generation would have finished).  Tokens already sampled stay in
        ``req.out``.  Returns False if the id is unknown or already done."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(i)
                # a preempted request sits in the queue AND holds parked KV
                self._drop_parked(rid)
                self._finish_cancel(req)
                return True
        for a in self.slots:
            if a is not None and a.req.rid == rid:
                self._release_active(a)
                self._finish_cancel(a.req)
                return True
        return False

    # -- fault tolerance ---------------------------------------------------
    @contextlib.contextmanager
    def _blame(self, rid: int):
        """Attribute any exception raised in the body to request ``rid``:
        the innermost attribution at raise time wins (captured in
        ``_crash_rid``, read by ``_on_step_crash`` after the stack unwinds).
        """
        prev = self._blame_rid
        self._blame_rid = rid
        try:
            yield
        except BaseException:
            if self._crash_rid is None:
                self._crash_rid = rid
            raise
        finally:
            self._blame_rid = prev

    def _release_active(self, a: _Active) -> None:
        """Free everything an active slot holds: table blocks back to the
        store, reservation back to the pool, slot emptied."""
        a.table.release_to(self.store)
        self.pool.release(a.reserved_left)
        a.reserved_left = 0
        if a.state is not None:
            self.state_store.decref(a.state)
            a.state = None
        self.slots[self.slots.index(a)] = None

    def _finish_expired(self, req: Request) -> None:
        self._release_adapter(req)
        req.expired = True
        req.done = True
        req.t_done = time.monotonic()
        self.expired.append(req)
        if req.on_finish is not None:
            req.on_finish(req)

    def _fail_request(self, req: Request, msg: str) -> None:
        """Terminal error state (quarantine outcome).  The on_finish hook is
        guarded: a raising hook is exactly the kind of poison quarantine
        exists to absorb, so it must not re-crash the recovery path."""
        self._release_adapter(req)
        req.errored = True
        req.error = msg
        req.done = True
        req.t_done = time.monotonic()
        self.errored.append(req)
        if req.on_finish is not None:
            try:
                req.on_finish(req)
            except Exception as e:  # noqa: BLE001
                print(f"serve-engine: on_finish hook raised for errored "
                      f"request {req.rid}: {type(e).__name__}: {e}",
                      file=sys.stderr)

    def _reap_deadlines(self) -> int:
        """Expire queued, parked, and active requests past their deadline
        cutoff, freeing every block and reservation they hold.  Runs at the
        top of ``step`` — before the crash-prone model dispatch — so
        deadlines keep draining a persistently-crashing engine."""
        now = time.monotonic()
        n = 0
        for req in [r for r in self.queue
                    if r._deadline_at and now > r._deadline_at]:
            self.queue.remove(req)
            self._drop_parked(req.rid)   # a preempted request queues parked
            self._finish_expired(req)
            n += 1
        for a in [s for s in self.slots
                  if s is not None and s.req._deadline_at
                  and now > s.req._deadline_at]:
            self._release_active(a)
            self._finish_expired(a.req)
            n += 1
        return n

    def _quarantine(self, rid: int, msg: str) -> bool:
        """Remove request ``rid`` from wherever it lives (queue, slot,
        parked) and fail it with ``finish_reason="error"``, releasing its
        device/host blocks and reservations."""
        for i, req in enumerate(self.queue):
            if req.rid == rid:
                self.queue.pop(i)
                self._drop_parked(rid)
                self._fail_request(req, msg)
                return True
        for a in self.slots:
            if a is not None and a.req.rid == rid:
                self._release_active(a)
                self._fail_request(a.req, msg)
                return True
        parked = self._parked.pop(rid, None)
        if parked is not None:       # parked without a queue entry: cleanup
            for b in parked.blocks:
                self.store.decref(b)
            if parked.state is not None:
                self.state_store.decref(parked.state)
            return True
        return False

    def _on_step_crash(self, exc: BaseException) -> None:
        """Recovery after ``step()`` raised: quarantine the blamed request
        (or the youngest live one when the crash had no single owner — a
        batched decode dispatch), count consecutive crashes toward the
        degraded state, and assert the KV-leak invariants."""
        self._step_crashes += 1
        self._consecutive_crashes += 1
        if self._consecutive_crashes >= self.max_consecutive_crashes:
            self.degraded = True
        rid = self._crash_rid
        if rid is None:
            live = [s for s in self.slots if s is not None]
            if live:
                rid = max(live, key=lambda s: s.admit_seq).req.rid
        msg = f"engine step crashed: {type(exc).__name__}: {exc}"
        print(f"serve-engine: {msg} (crash {self._step_crashes}, "
              f"{self._consecutive_crashes} consecutive"
              + (f"; quarantining request {rid}" if rid is not None else
                 "; no request to blame")
              + (", engine DEGRADED" if self.degraded else "") + ")",
              file=sys.stderr)
        if rid is not None:
            self._quarantine(rid, msg)
        violations = self.check_invariants()
        if violations:
            self.invariant_violations.extend(violations)
            for v in violations:
                print(f"serve-engine: KV-LEAK INVARIANT VIOLATED: {v}",
                      file=sys.stderr)

    def step_guarded(self) -> bool:
        """``step()`` wrapped in crash isolation: an exception quarantines
        the request that poisoned the batch and the loop keeps going —
        this is what the async stepper thread drives.  Returns True after a
        crash (recovery IS work); a clean productive step resets the
        consecutive-crash counter and clears the degraded flag."""
        self._crash_rid = None
        try:
            worked = self.step()
        except Exception as e:  # noqa: BLE001 — isolate, quarantine, go on
            self._on_step_crash(e)
            return True
        if worked:
            self._consecutive_crashes = 0
            self.degraded = False
        return worked

    def overload_reason(self) -> str:
        """Why a new submit should be shed right now ("" = accept): the
        admission queue hit its bound, or — with REPRO_SERVE_SHED_PRESSURE
        set — the pool is pressure-saturated with a backlog already queued.
        The gateway turns a non-empty reason into HTTP 429 + Retry-After."""
        if self.max_queue and len(self.queue) >= self.max_queue:
            return (f"admission queue full "
                    f"({len(self.queue)} >= {self.max_queue})")
        if self.shed_pressure > 0 and self.queue:
            frac = (self.pool.usable_blocks - self.pool.available()) \
                / self.pool.usable_blocks
            if frac >= self.shed_pressure:
                return (f"block pool pressure {frac:.2f} >= "
                        f"{self.shed_pressure:g} with "
                        f"{len(self.queue)} queued")
        return ""

    def note_gateway_shed(self) -> None:
        """Count a request the gateway refused before submit (429)."""
        self._gateway_shed += 1

    def check_invariants(self) -> List[str]:
        """KV-leak invariants (see ``repro.serve.faults``): every allocated
        device/host block reachable from active+parked+prefix-registry with
        a consistent refcount, reservation ledgers in agreement.  Empty list
        = healthy."""
        return check_kv_invariants(self)

    # -- sampling ----------------------------------------------------------
    @staticmethod
    def _sample(logits_row: np.ndarray, sp: SamplingParams, n_emitted: int) -> int:
        """Gumbel-max sampling keyed on (seed, token index): stateless, so a
        preempted request replays the same draws on restart, and host-side,
        so the decode hot loop pays no per-token device dispatches."""
        if sp.temperature <= 0.0:
            return int(np.argmax(logits_row))
        x = logits_row.astype(np.float64) / sp.temperature
        if 0 < sp.top_k < x.size:
            kth = np.partition(x, -sp.top_k)[-sp.top_k]
            x = np.where(x < kth, -np.inf, x)
        rng = np.random.default_rng(
            np.random.SeedSequence([sp.seed & (2**63 - 1), n_emitted]))
        return int(np.argmax(x + rng.gumbel(size=x.size)))

    # -- prefill -----------------------------------------------------------
    def _adopt_prefix(self, a: _Active) -> None:
        """First prefill chunk of a fresh request: fork the longest
        registered prompt prefix instead of recomputing it.  Capped at
        ``plen - 1`` — the last prompt position must run through the model to
        produce the first sampled token's logits."""
        req = a.req
        plen, bs = len(req.prompt), self.block_size
        # prefixes are namespaced by tenant: identical prompts under
        # different adapters have different KV, so a cross-tenant hit would
        # serve one tenant's activations to another (isolation contract)
        n, blocks = self.store.match_prefix(req.prompt,
                                            namespace=req.adapter_id)
        n = min(n, plen - 1)
        if n <= 0:
            return
        a.table.blocks = self.store.fork(blocks[:blocks_for_tokens(n, bs)])
        # fully-shared blocks are mappings, not allocations: hand their
        # reservation slots back (the shared partial tail, if any, keeps its
        # slot — the copy-on-write before our first write consumes it)
        release = min(n // bs, a.reserved_left)
        if release:
            self.pool.release(release)
            a.reserved_left -= release
        a.next_prefill = n
        self._re_prefill_avoided += n

    def _prefill_step(self) -> bool:
        """Run ONE prompt chunk for the oldest admitting request.  Bounding
        prefill work per engine step is what keeps decode latency flat while
        long prompts trickle in."""
        pending = [s for s in self.slots if s is not None and not s.prefill_done]
        if not pending:
            return False
        a = min(pending, key=lambda s: s.admit_seq)
        with self._blame(a.req.rid):
            return self._prefill_chunk_for(a)

    def _prefill_chunk_for(self, a: _Active) -> bool:
        req, c = a.req, self.prefill_chunk_tokens
        plen = len(req.prompt)
        if a.next_prefill == 0 and not a.table.blocks:
            self._adopt_prefix(a)
        start = a.next_prefill
        # realign to the canonical chunk grid: an adopted (or restored)
        # prefix can leave ``start`` mid-chunk, and letting every offset
        # produce its own attended-span value would retrace the jitted
        # prefill per offset — the first chunk is shortened to the next grid
        # point instead, so m_used stays in the same small set every request
        # visits (the write limit masks the chunk's unused tail positions)
        end = min(plen, start + c, (start // c + 1) * c)
        if not self._grow(a, end):
            return True  # preempted ourselves; the step still did work
        if not self._make_writable(a, start, end):
            return True
        chunk = req.prompt[start:end] + [0] * (c - (end - start))
        batch = {
            "tokens": jnp.asarray([chunk], jnp.int32),
            "block_table": jnp.asarray(
                [a.table.padded(self.max_blocks_per_seq)], jnp.int32),
            "start": jnp.int32(start),
            "prompt_len": jnp.int32(end),
        }
        if a.state is not None:
            # traced slot index: one jit per cache shape, not per slot
            batch["state_slot"] = jnp.int32(a.state.idx)
        lora = self._lora_descriptor(
            np.asarray([a.req._adapter_slot], np.int32))
        if lora is not None:
            batch["lora"] = lora
        # attend only over blocks written so far, not the full table capacity
        # (attention-free prefill ignores the span — pin the static arg to 0
        # so distinct chunk counts don't retrace the jit)
        m_used = min(blocks_for_tokens(end, self.block_size),
                     self.max_blocks_per_seq) if self.has_attention else 0
        if self.faults is not None:
            self.faults.check("step")
        self.cache, logits = self._prefill_fn(self.params, self.cache, batch,
                                              m_used=m_used)
        a.next_prefill = end
        self._prefill_tokens += end - start
        if a.prefill_done:
            a.pos = plen
            # retain the finished prompt for future sharers (the registry
            # holds its own refs; budget-bounded, LRU-evicted under pressure)
            self.store.register_prefix(
                req.prompt,
                a.table.blocks[:blocks_for_tokens(plen, self.block_size)],
                namespace=req.adapter_id)
            row = np.asarray(logits[0, plen - 1 - start])
            first = self._sample(row, req.sampling, 0)
            req.out.append(first)
            self._tenant_count(req)
            req.t_first = time.monotonic()
            if req.on_token is not None:
                req.on_token(first, 0)
            if req.max_new <= 1:
                self._retire(a)
        return True

    # -- decode ------------------------------------------------------------
    def _decode_step(self) -> bool:
        """One batched decode step for every live (prefill-complete) slot."""
        live = [s for s in self.slots if s is not None and s.prefill_done]
        # make sure every live row can write its next KV entry — growing the
        # table AND privatizing a shared write target; under optimistic
        # admission either can preempt (an earlier row's growth may evict a
        # later row — or the row itself, when it is the youngest)
        for a in live:
            if a in self.slots:
                with self._blame(a.req.rid):
                    if self._grow(a, a.pos + 1):
                        self._make_writable(a, a.pos, a.pos + 1)
        live = [a for a in live if a in self.slots]
        if not live:
            return False

        m = self.max_blocks_per_seq
        tok = np.zeros((self.max_batch, 1), np.int32)
        tables = np.zeros((self.max_batch, m), np.int32)
        lens = np.zeros((self.max_batch,), np.int32)
        state_slots = np.zeros((self.max_batch,), np.int32)  # 0 = null slot
        adapter_ids = np.full((self.max_batch,), -1, np.int32)
        rows = []
        for a in live:
            i = self.slots.index(a)
            rows.append((i, a))
            tok[i, 0] = a.req.out[-1]
            tables[i] = a.table.padded(m)
            lens[i] = a.pos
            if a.state is not None:
                state_slots[i] = a.state.idx
            adapter_ids[i] = a.req._adapter_slot
        batch = {"token": jnp.asarray(tok),
                 "block_tables": jnp.asarray(tables),
                 "seq_lens": jnp.asarray(lens)}
        if self.has_state:
            batch["state_slots"] = jnp.asarray(state_slots)
        lora = self._lora_descriptor(adapter_ids)
        if lora is not None:
            batch["lora"] = lora
        # the batched dispatch has no single owner: a crash here blames no
        # rid and _on_step_crash falls back to the youngest live request
        if self.faults is not None:
            self.faults.check("step")
        self.cache, logits = self._decode_fn(self.params, self.cache, batch)
        logits_np = np.asarray(logits)
        now = time.monotonic()
        for i, a in rows:
            req = a.req
            with self._blame(req.rid):
                nxt = self._sample(logits_np[i], req.sampling, len(req.out))
                req.out.append(nxt)
                a.pos += 1
                self._decode_tokens += 1
                self._tenant_count(req)
                if req.on_token is not None:
                    req.on_token(nxt, len(req.out) - 1)
                if len(req.out) >= req.max_new or a.pos >= self.max_len:
                    self._retire(a, now=now)
        return True

    # -- engine loop -------------------------------------------------------
    def step(self) -> bool:
        """One engine iteration: reap deadlines, admit, one prefill chunk,
        one batched decode step.  Returns False when there is nothing left
        to do."""
        if self._t0 is None:
            self._t0 = time.monotonic()
        worked = self._reap_deadlines() > 0
        worked = self._admit() > 0 or worked
        worked = self._prefill_step() or worked
        worked = self._decode_step() or worked
        if worked:
            self.steps += 1
            self._t_last = time.monotonic()
        return worked

    def run_until_done(self, max_steps: int = 100_000) -> List[Request]:
        """Drive ``step`` until queue and slots drain (or ``max_steps``
        engine iterations pass); returns the finished requests in completion
        order.  Rejected requests are in ``self.rejected``, not here; a
        request preempted mid-run is restored (or restarted, see
        REPRO_KV_SWAP) and still finishes before this returns."""
        for _ in range(max_steps):
            if not self.step():
                break
        return list(self.finished)

    def release_prefix_cache(self) -> int:
        """Drop every retained prompt prefix, returning blocks freed —
        benchmarks and tests call this to drain the pool to zero."""
        return self.store.drop_prefixes()

    def reset_metrics(self) -> None:
        """Zero the run counters (benchmarks warm the jit caches with a
        throwaway workload first, then measure a clean window).  Requests
        already finished are dropped from the ledger — callers keep their own
        references."""
        assert all(s is None for s in self.slots) and not self.queue, \
            "reset_metrics with requests in flight"
        self.steps = 0
        self._t0 = None
        self._t_last = 0.0
        self._submitted = 0
        self._prefill_tokens = 0
        self._decode_tokens = 0
        self._preemptions = 0
        self._re_prefill_avoided = 0
        self._tenant_tokens = {}
        self._tenant_finished = {}
        self.store.reset_counters()
        if self.state_store is not None:
            self.state_store.reset_counters()
        self.finished = []
        self.rejected = []
        self.cancelled = []
        self.expired = []
        self.errored = []
        self.shed = []
        self._step_crashes = 0
        self._consecutive_crashes = 0
        self._swap_failures = 0
        self._gateway_shed = 0
        self.degraded = False
        self.invariant_violations = []
        self.pool.peak_used = self.pool.num_used

    # -- metrics -----------------------------------------------------------
    def metrics(self) -> ServeMetrics:
        wall = max(self._t_last - self._t0, 1e-9) if self._t0 else 0.0
        fin = self.finished
        ttfts = [r.t_first - r.t_submit for r in fin if r.t_first > 0]
        itl_num = sum(r.t_done - r.t_first for r in fin if len(r.out) > 1)
        itl_den = sum(len(r.out) - 1 for r in fin if len(r.out) > 1)
        am = self.adapters.metrics()
        tenants = sorted(set(self._tenant_tokens) | set(self._tenant_finished))
        return ServeMetrics(
            wall_s=wall,
            requests_submitted=self._submitted,
            requests_finished=len(fin),
            requests_rejected=len(self.rejected),
            prefill_tokens=self._prefill_tokens,
            decode_tokens=self._decode_tokens,
            engine_steps=self.steps,
            tokens_per_sec=self._decode_tokens / wall if wall else 0.0,
            ttft_mean_s=float(np.mean(ttfts)) if ttfts else 0.0,
            ttft_max_s=float(np.max(ttfts)) if ttfts else 0.0,
            itl_mean_s=itl_num / itl_den if itl_den else 0.0,
            peak_blocks_used=self.pool.peak_used,
            pool_blocks=self.pool.usable_blocks,
            block_size=self.block_size,
            peak_pool_utilization=self.pool.peak_used / self.pool.usable_blocks,
            dense_equiv_blocks=dense_equiv_blocks(self.max_batch, self.max_len,
                                                  self.block_size),
            preemptions=self._preemptions,
            shared_blocks=self.store.shared_blocks,
            cow_copies=self.store.cow_copies,
            # the state slab is the degenerate one-block pool: its swaps are
            # the same tier movement, folded into the same counters
            swap_out_blocks=self.store.swapped_out
            + (self.state_store.swapped_out if self.state_store else 0),
            swap_in_blocks=self.store.swapped_in
            + (self.state_store.swapped_in if self.state_store else 0),
            re_prefill_avoided=self._re_prefill_avoided,
            requests_expired=len(self.expired),
            requests_shed=len(self.shed) + self._gateway_shed,
            requests_errored=len(self.errored),
            step_crashes=self._step_crashes,
            swap_failures=self._swap_failures,
            degraded=self.degraded,
            mesh_devices=int(self.mesh.shape.get("model", 1))
            if self.mesh is not None else 1,
            tp_devices=int(self.mesh.shape.get("model", 1))
            if self.tp and self.mesh is not None else 1,
            param_bytes_per_device=self.param_bytes_per_device,
            param_bytes_replicated=self.param_bytes_replicated,
            adapters_loaded=am["adapters_loaded"],
            adapter_loads=am["adapter_loads"],
            adapter_evictions=am["adapter_evictions"],
            adapter_host_reloads=am["adapter_host_reloads"],
            adapter_device_bytes=am["adapter_device_bytes"],
            adapter_host_bytes=am["adapter_host_bytes"],
            per_tenant={
                t: {"tokens": self._tenant_tokens.get(t, 0),
                    "requests_finished": self._tenant_finished.get(t, 0)}
                for t in tenants},
        )
