"""Serving driver: batched decode over synthetic prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=8).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new))
    t0 = time.monotonic()
    eng.run_until_done()
    dt = time.monotonic() - t0
    total_tokens = args.requests * args.max_new
    print(f"{args.requests} requests, {total_tokens} tokens in {dt:.2f}s "
          f"-> {total_tokens / dt:.1f} tok/s (decode steps: {eng.steps})")


if __name__ == "__main__":
    main()
