"""Serving driver: paged-KV continuous batching over synthetic prompts.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-0.6b --smoke \
        --requests 8 --max-new 16 --block-size 8 --temperature 0.8 --top-k 40

Family-agnostic: any registered arch serves through the same engine —
attention families (dense/moe) page their KV through the block pool, ssm
archs (``--arch falcon-mamba-7b``) keep per-request recurrent state in the
StateSlab tier, and hybrid archs (``--arch zamba2-2.7b``) carry the mixed
layout (KV blocks for the shared attention, slab slots for the Mamba2
backbone).

``--mesh N`` shards the KV block pool over N devices on the kv-heads axis
(on a chipless host it forces an N-device CPU fake pod first); outputs are
token-identical to the single-device run.  ``--tp N`` additionally shards
the WEIGHTS over the same mesh using the partition rules Auto Distribution
emits (~1/N param bytes per device; see docs/sharding.md and the
REPRO_TP_REDUCE_SCATTER knob).  Prints per-run ServeMetrics;
``--metrics-out`` dumps them as JSON (the same shape bench_serve emits into
BENCH_serve.json).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import ensure_fake_pod
from repro.models import build_model
from repro.serve.engine import Request, SamplingParams, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="KV pool size in blocks (0 = dense-capacity parity)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="prompt tokens prefetched per engine step "
                         "(0 = one block)")
    ap.add_argument("--admission", choices=["conservative", "optimistic"],
                    default="conservative")
    ap.add_argument("--host-blocks", type=int, default=-1,
                    help="host swap-tier size in blocks (-1 = pool-sized, "
                         "0 = no swap tier; see REPRO_KV_SWAP)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=-1,
                    help="blocks retained for prompt-prefix sharing "
                         "(-1 = pool/4, 0 = sharing off)")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard the KV pool over this many devices on the "
                         "kv-heads axis (1 = explicit 1-device mesh; 0 = "
                         "defer to REPRO_SERVE_MESH; forces a CPU fake pod "
                         "when not enough devices exist)")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel: shard the weights AND the KV pool "
                         "over this many devices (implies --mesh N; 0 = "
                         "defer to REPRO_SERVE_TP)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args()

    mesh_n = max(args.mesh, args.tp)
    ensure_fake_pod(mesh_n)
    mesh = None          # 0: defer to the REPRO_SERVE_MESH knob
    if mesh_n >= 1:      # an explicit CLI width always beats the env knob
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_n)
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    fns = build_model(cfg)
    params = fns.init(jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.max_len, block_size=args.block_size,
                      num_blocks=args.num_blocks or None,
                      prefill_chunk_tokens=args.prefill_chunk or None,
                      admission=args.admission,
                      host_blocks=None if args.host_blocks < 0 else args.host_blocks,
                      prefix_cache_blocks=None if args.prefix_cache_blocks < 0
                      else args.prefix_cache_blocks,
                      mesh=mesh, tp=True if args.tp >= 1 else None)
    if eng.tp:
        print(f"tensor parallel x{eng.metrics().tp_devices}: "
              f"{eng.param_bytes_per_device / 1e6:.2f} MB/device of "
              f"{eng.param_bytes_replicated / 1e6:.2f} MB params "
              f"({eng.param_bytes_per_device / eng.param_bytes_replicated:.0%})")
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=int(rng.integers(4, 12))).tolist()
        eng.submit(Request(rid=i, prompt=prompt, max_new=args.max_new,
                           sampling=SamplingParams(temperature=args.temperature,
                                                   top_k=args.top_k,
                                                   seed=args.seed + i)))
    eng.run_until_done()
    m = eng.metrics()
    print(m.summary())
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(m.to_dict(), f, indent=2)
        print(f"metrics written to {args.metrics_out}")


if __name__ == "__main__":
    main()
