"""Step builders shared by the dry-run, trainer, server, and benchmarks."""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.train.optimizer import AdamW, AdamWConfig


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None,
                    remat: bool = True) -> Tuple[Callable, AdamW]:
    fns = build_model(cfg)
    if opt_cfg is None:
        from repro.perf import perf
        opt_cfg = AdamWConfig(state_dtype=perf().opt_state)
    opt = AdamW(opt_cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: fns.loss(p, batch, remat=remat))(params)
        new_params, new_state, metrics = opt.update(grads, opt_state, params)
        metrics["loss"] = loss
        return new_params, new_state, metrics

    return train_step, opt


def make_prefill_step(cfg: ModelConfig) -> Callable:
    fns = build_model(cfg)

    def prefill_step(params, batch):
        return fns.prefill(params, batch)

    return prefill_step


def make_decode_step(cfg: ModelConfig) -> Callable:
    fns = build_model(cfg)

    def decode_step(params, cache, batch):
        return fns.decode_step(params, cache, batch)

    return decode_step
