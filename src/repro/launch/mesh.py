"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Degenerate 1x1 mesh over whatever devices exist (tests/examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def ensure_fake_pod(n: int) -> None:
    """Ask XLA for an ``n``-device CPU fake pod by appending
    ``--xla_force_host_platform_device_count`` to XLA_FLAGS.

    Only effective if the backend has not initialized yet (XLA reads the
    flag at first device use) — call it before anything touches
    ``jax.devices()``.  No-op when ``n <= 1`` or when XLA_FLAGS already
    carries a forced count (an operator's explicit setting wins); on real
    accelerators the flag only affects the CPU platform and is ignored."""
    import os

    flags = os.environ.get("XLA_FLAGS", "")
    if n > 1 and "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count={n}").strip()


def make_serve_mesh(n_model=None):
    """Serving mesh: tensor-parallel only, ``(1, n)`` over ("data", "model").

    The serve engine shards its KV block pool on the kv-heads axis, which
    maps to "model"; the size-1 "data" axis exists so the cache PartitionSpec
    rules in ``repro.distributed.sharding`` resolve every axis name.  Uses
    the first ``n_model`` devices (default: all visible — on a CPU fake pod
    that is whatever ``--xla_force_host_platform_device_count`` forced)."""
    import numpy as np

    from jax.sharding import Mesh

    devices = jax.devices()
    n = n_model or len(devices)
    if n > len(devices):
        raise ValueError(f"serve mesh wants {n} devices, only "
                         f"{len(devices)} visible")
    return Mesh(np.array(devices[:n]).reshape(1, n), ("data", "model"))


def mesh_device_count(mesh) -> int:
    return int(mesh.devices.size)
