"""Boot the OpenAI-compatible HTTP gateway over one or more serve engines.

    PYTHONPATH=src python -m repro.launch.gateway --arch qwen3-0.6b --smoke \
        --port 8011

    # two models multiplexed by one router (ids default to the cfg names):
    PYTHONPATH=src python -m repro.launch.gateway --smoke \
        --arch qwen3-0.6b --arch stablelm-3b --port 8011

Prints ``gateway listening on http://HOST:PORT`` once ready (CI polls
``/health``), serves until SIGINT/SIGTERM, then prints ``gateway shut down
cleanly`` and exits 0 — the gateway-smoke CI job asserts both lines.
``--mesh N`` builds the engines over a mesh-sharded KV pool, same semantics
as ``repro.launch.serve``.
"""
from __future__ import annotations

import argparse
import asyncio
import signal


def build_router(archs, smoke: bool, mesh_devices: int, max_batch: int,
                 max_len: int, block_size: int, plan_kernels: bool):
    import jax

    from repro.configs.base import get_config, reduced_config
    from repro.models import build_model as build_model_fns
    from repro.serve.gateway import build_model, Router

    mesh = None          # defer to REPRO_SERVE_MESH
    if mesh_devices >= 1:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(mesh_devices)
    models = []
    for arch in archs:
        cfg = get_config(arch)
        if smoke:
            cfg = reduced_config(cfg)
        fns = build_model_fns(cfg)
        params = fns.init(jax.random.PRNGKey(0))
        models.append(build_model(
            cfg, params, max_batch=max_batch, max_len=max_len,
            block_size=block_size, plan_kernels=plan_kernels, mesh=mesh))
    return Router(models)


async def serve(args) -> None:
    from repro.serve.gateway import Gateway

    router = build_router(
        args.arch or ["qwen3-0.6b"], smoke=args.smoke,
        mesh_devices=args.mesh, max_batch=args.max_batch,
        max_len=args.max_len, block_size=args.block_size,
        plan_kernels=not args.no_plan_kernels)
    gw = Gateway(router, host=args.host, port=args.port)
    await gw.start()
    ids = ", ".join(m.model_id for m in router.models())
    print(f"gateway listening on {gw.url} (models: {ids})", flush=True)

    stop = asyncio.Event()
    loop = asyncio.get_running_loop()
    for sig in (signal.SIGINT, signal.SIGTERM):
        loop.add_signal_handler(sig, stop.set)
    await stop.wait()
    await gw.stop()
    print("gateway shut down cleanly", flush=True)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="model arch to serve; repeatable — each becomes "
                         "one routed model id (default: qwen3-0.6b)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced per-arch configs (CPU CI size)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8000,
                    help="0 picks an ephemeral port (printed when ready)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard each engine's KV pool over N devices "
                         "(0 = defer to REPRO_SERVE_MESH)")
    ap.add_argument("--no-plan-kernels", action="store_true",
                    help="skip the pipeline compile of the paged attention "
                         "shapes (faster boot; smoke/CI use)")
    args = ap.parse_args()

    from repro.launch.mesh import ensure_fake_pod
    ensure_fake_pod(args.mesh)
    asyncio.run(serve(args))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
