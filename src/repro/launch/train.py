"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --smoke \
        --steps 50 --seq-len 256 --batch 4 --workdir /tmp/run1

``--smoke`` swaps in the reduced same-family config so the driver runs on
CPU; without it the full config is used (TPU pods via --mesh pod1/pod2).
"""
from __future__ import annotations

import argparse

from repro.configs.base import get_config, reduced_config
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--workdir", default=None)
    ap.add_argument("--mesh", default="local", choices=["local", "pod1", "pod2"])
    ap.add_argument("--opt-state", default="f32", choices=["f32", "int8"])
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (recovery demo)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)
    mesh = (make_local_mesh() if args.mesh == "local"
            else make_production_mesh(multi_pod=args.mesh == "pod2"))
    tcfg = TrainerConfig(seq_len=args.seq_len, global_batch=args.batch,
                         steps=args.steps, workdir=args.workdir)
    opt_cfg = AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(1, args.steps // 20),
                          state_dtype=args.opt_state)
    trainer = Trainer(cfg, tcfg, opt_cfg, mesh=mesh)
    result = trainer.train(fail_at=args.fail_at)
    print(f"done at step {result['final_step']}; "
          f"first loss {result['log'][0]['loss']:.4f} -> "
          f"last {result['log'][-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
