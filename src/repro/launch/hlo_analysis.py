"""Post-compile HLO analysis for the roofline terms.

XLA's ``compiled.cost_analysis()`` visits each while body ONCE, so scanned
models under-report FLOPs/bytes by the trip count.  This module re-derives
the three roofline inputs directly from the scheduled HLO text:

  * dot FLOPs            (2 * result_elems * contracted_elems, x trip counts)
  * write traffic bytes  (sum of op result bytes; ~1 write + 1 read per tensor)
  * collective bytes     (per type, with replica-group sizes)

Trip counts come from ``backend_config={"known_trip_count":{"n":...}}`` which
the backends attach to counted loops.  Operand shapes are resolved through a
per-computation symbol table (scheduled HLO omits operand types on op lines).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*(\w+\[[\d,]*\])")
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")
_SKIP_HEADS = ("parameter", "get-tuple-element", "tuple(", "bitcast(",
               "constant", "after-all", "partition-id", "replica-id",
               "iota(", "broadcast(")


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _nbytes(dt: str, dims: List[int]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES[dt]


def _shape_bytes(text: str) -> int:
    return sum(_nbytes(dt, dims) for dt, dims in _shapes_in(text))


def _split_computations(hlo: str):
    comps: Dict[str, Dict] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and line.rstrip().endswith("{") \
                and "(" in line:
            header = line.split("(")[0].strip()
            is_entry = header.startswith("ENTRY")
            name = header.replace("ENTRY", "").strip().lstrip("%")
            cur = name
            comps[cur] = {"header": line, "lines": []}
            if is_entry:
                entry = name
        elif line.startswith("}"):
            cur = None
        elif cur is not None:
            comps[cur]["lines"].append(line)
    return comps, entry


def _symbols(comp: Dict) -> Dict[str, Tuple[str, List[int]]]:
    """op/param name -> (dtype, dims) for simple (non-tuple) results."""
    syms: Dict[str, Tuple[str, List[int]]] = {}
    for name, ty in _PARAM_RE.findall(comp["header"]):
        sh = _shapes_in(ty)
        if sh:
            syms[name] = sh[0]
    for ln in comp["lines"]:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        head = rhs.split("(")[0].strip()
        if head.startswith("("):
            continue  # tuple result
        sh = _shapes_in(head)
        if sh:
            syms[name] = sh[0]
    return syms


def _trip_count(line: str) -> int:
    m = re.search(r'known_trip_count[\\":{]+n[\\":]+(\d+)', line)
    return int(m.group(1)) if m else 1


def _dot_flops(rhs: str, syms: Dict) -> int:
    res_shapes = _shapes_in(rhs.split("dot(")[0])
    if not res_shapes:
        return 0
    res_elems = 1
    for d in res_shapes[0][1]:
        res_elems *= d
    args = rhs[rhs.index("dot(") + 4:]
    m = re.search(r"%([\w\.\-]+)", args)
    contracted = 1
    if m and m.group(1) in syms:
        lhs_dims = syms[m.group(1)][1]
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
        if cm and cm.group(1):
            for i in cm.group(1).split(","):
                ii = int(i)
                if ii < len(lhs_dims):
                    contracted *= lhs_dims[ii]
    return 2 * res_elems * contracted


def _group_size(line: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _merge_coll(dst: Dict, src: Dict, mult: int = 1):
    for op, e in src.items():
        a = dst.setdefault(op, {"bytes": 0, "count": 0, "max_group": 1})
        a["bytes"] += e["bytes"] * mult
        a["count"] += e["count"] * mult
        a["max_group"] = max(a["max_group"], e["max_group"])


def analyze_hlo(hlo: str, n_devices: int) -> Dict:
    comps, entry = _split_computations(hlo)
    cache: Dict[str, Dict] = {}

    def analyze(name: str, stack=frozenset()) -> Dict:
        if name in cache:
            return cache[name]
        if name in stack or name not in comps:
            return {"flops": 0, "bytes": 0, "coll": {}}
        comp = comps[name]
        syms = _symbols(comp)
        agg = {"flops": 0, "bytes": 0, "coll": {}}
        for ln in comp["lines"]:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            matched = False
            for op in COLLECTIVE_OPS:
                if re.search(rf"\b{op}(-start)?\(", rhs):
                    e = agg["coll"].setdefault(
                        op, {"bytes": 0, "count": 0, "max_group": 1})
                    e["bytes"] += _shape_bytes(rhs.split(op)[0])
                    e["count"] += 1
                    e["max_group"] = max(e["max_group"],
                                         _group_size(rhs, n_devices))
                    matched = True
                    break
            if matched:
                continue
            if " dot(" in rhs or rhs.startswith("dot("):
                agg["flops"] += _dot_flops(rhs, syms)
                agg["bytes"] += _shape_bytes(rhs.split("dot(")[0])
                continue
            if " while(" in rhs or rhs.startswith("while("):
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                if bm:
                    tc = _trip_count(rhs)
                    sub = analyze(bm.group(1), stack | {name})
                    agg["flops"] += sub["flops"] * tc
                    agg["bytes"] += sub["bytes"] * tc
                    _merge_coll(agg["coll"], sub["coll"], tc)
                continue
            cm = re.search(r"(?:calls=|to_apply=)%?([\w\.\-]+)", rhs)
            if ("fusion(" in rhs or " call(" in rhs or rhs.startswith("call(")) and cm:
                sub = analyze(cm.group(1), stack | {name})
                agg["flops"] += sub["flops"]
                agg["bytes"] += _shape_bytes(
                    rhs.split("fusion(")[0].split("call(")[0])
                _merge_coll(agg["coll"], sub["coll"])
                continue
            if "conditional(" in rhs:
                for grp in re.findall(r"branch_computations=\{([^}]*)\}", rhs):
                    for c in grp.split(","):
                        sub = analyze(c.strip().lstrip("%"), stack | {name})
                        agg["flops"] += sub["flops"]
                        agg["bytes"] += sub["bytes"]
                        _merge_coll(agg["coll"], sub["coll"])
                continue
            head = rhs.lstrip()
            body = head.split("(")[0]
            if any(head.startswith(k.rstrip("(")) and
                   (k.endswith("(") is False or body == k.rstrip("("))
                   for k in _SKIP_HEADS):
                continue
            agg["bytes"] += _shape_bytes(rhs.split("(")[0])
        cache[name] = agg
        return agg

    top = analyze(entry) if entry else {"flops": 0, "bytes": 0, "coll": {}}
    return {"flops": top["flops"], "bytes_traffic": 2 * top["bytes"],
            "collectives": top["coll"]}


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e per chip)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12      # bf16 FLOP/s
HBM_BW = 819e9           # B/s
ICI_BW = 50e9            # B/s per link
HBM_BYTES = 16 * 2**30


def collective_time_s(coll: Dict) -> float:
    """Alpha-beta per-chip collective time on ICI (ring algorithms):
    all-gather/reduce-scatter move (g-1)/g of payload, all-reduce 2x that,
    all-to-all (g-1)/g, collective-permute 1 hop.  ~1us alpha per op."""
    ALPHA = 1e-6
    t = 0.0
    for op, e in coll.items():
        g = max(2, e.get("max_group", 2))
        frac = (g - 1) / g
        factor = {"all-gather": frac, "reduce-scatter": frac,
                  "all-reduce": 2 * frac, "all-to-all": frac,
                  "collective-permute": 1.0}[op]
        t += factor * e["bytes"] / ICI_BW + ALPHA * e.get("count", 1)
    return t


def roofline_terms(analysis: Dict) -> Dict:
    """Per-chip seconds for the three roofline terms + dominant bottleneck."""
    tc = analysis["flops"] / PEAK_FLOPS
    tm = analysis["bytes_traffic"] / HBM_BW
    tn = collective_time_s(analysis["collectives"])
    dom = max((tc, "compute"), (tm, "memory"), (tn, "collective"))[1]
    return {"compute_s": tc, "memory_s": tm, "collective_s": tn,
            "bottleneck": dom,
            "step_time_s": max(tc, tm, tn)}
