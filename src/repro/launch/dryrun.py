import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective analyses.

One-cell mode (used by the driver via subprocess so each compile gets a fresh
XLA):    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh pod1
Driver:  python -m repro.launch.dryrun --all [--mesh pod1|pod2|both]

Results land in results/dryrun/<arch>__<shape>__<mesh>.json; existing files
are skipped, so the driver is resumable.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


# ---------------------------------------------------------------------------
# One-cell dry run
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, out_path: Path,
             save_hlo: bool = False) -> dict:
    import jax
    from repro.configs.base import SHAPES, cell_is_runnable, get_config
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_decode_step, make_prefill_step, make_train_step
    from repro.models import build_model
    from repro.models.model_zoo import abstract_params

    from repro.perf import knob_snapshot

    t0 = time.time()
    cfg = get_config(arch)
    spec = SHAPES[shape_name]
    result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
              "kind": spec.kind, "seq_len": spec.seq_len,
              "global_batch": spec.global_batch,
              "params": cfg.param_count(),
              "active_params": cfg.active_param_count(),
              "perf_knobs": knob_snapshot()}

    ok, why = cell_is_runnable(cfg, spec)
    if not ok:
        result["status"] = "skipped"
        result["skip_reason"] = why
        out_path.write_text(json.dumps(result, indent=1))
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "pod2"))
    n_dev = int(mesh.devices.size)
    result["devices"] = n_dev

    fns = build_model(cfg)
    params_abs = abstract_params(cfg)
    pspecs = shd.param_specs(cfg, params_abs, mesh)
    batch_abs = fns.input_specs(spec)
    bspecs = shd.batch_specs(cfg, batch_abs, mesh)

    with mesh:
        if spec.kind == "train":
            step, opt = make_train_step(cfg)
            opt_abs = jax.eval_shape(opt.init, params_abs)
            ospecs = shd.opt_state_specs(pspecs, opt_abs, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(shd.to_named(pspecs, mesh),
                              shd.to_named(ospecs, mesh),
                              shd.to_named(bspecs, mesh)),
                out_shardings=(shd.to_named(pspecs, mesh),
                               shd.to_named(ospecs, mesh), None),
                donate_argnums=(0, 1),
            ).lower(params_abs, opt_abs, batch_abs)
        elif spec.kind == "prefill":
            step = make_prefill_step(cfg)
            cache_abs, logits_abs = jax.eval_shape(step, params_abs, batch_abs)
            cspecs = shd.cache_specs(cfg, cache_abs, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(shd.to_named(pspecs, mesh),
                              shd.to_named(bspecs, mesh)),
                out_shardings=(shd.to_named(cspecs, mesh), None),
            ).lower(params_abs, batch_abs)
        else:  # decode
            step = make_decode_step(cfg)
            cache_abs = jax.eval_shape(
                lambda: fns.make_cache(spec.global_batch, spec.seq_len))
            cspecs = shd.cache_specs(cfg, cache_abs, mesh)
            lowered = jax.jit(
                step,
                in_shardings=(shd.to_named(pspecs, mesh),
                              shd.to_named(cspecs, mesh),
                              shd.to_named(bspecs, mesh)),
                out_shardings=(shd.to_named(cspecs, mesh), None),
                donate_argnums=(1,),
            ).lower(params_abs, cache_abs, batch_abs)

        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    from repro.launch.hlo_analysis import analyze_hlo, roofline_terms

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    analysis = analyze_hlo(text, n_dev)

    result["status"] = "ok"
    result["lower_s"] = round(t1 - t0, 2)
    result["compile_s"] = round(t2 - t1, 2)
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            result[attr] = int(getattr(mem, attr))
        except Exception:
            pass
    # XLA's own cost analysis (NOTE: visits while bodies once; kept for
    # reference only — the roofline uses the trip-count-aware HLO analysis).
    result["xla_cost_flops"] = float(cost.get("flops", -1)) if hasattr(cost, "get") else -1
    result["xla_cost_bytes"] = float(cost.get("bytes accessed", -1)) if hasattr(cost, "get") else -1
    result["hlo_flops_per_device"] = analysis["flops"]
    result["hlo_bytes_per_device"] = analysis["bytes_traffic"]
    result["collectives"] = analysis["collectives"]
    result["roofline"] = roofline_terms(analysis)
    # model flops: 6*N_active*D for train (x3 for bwd? 6ND already counts
    # fwd+bwd for training); for inference use 2*N_active*D.
    spec_tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
    if spec.kind == "train":
        model_flops = 6 * cfg.active_param_count() * spec_tokens
    else:
        model_flops = 2 * cfg.active_param_count() * spec_tokens
    result["model_flops_global"] = float(model_flops)
    hlo_flops_global = analysis["flops"] * n_dev
    result["model_vs_hlo_flops"] = (
        float(model_flops / hlo_flops_global) if hlo_flops_global else None)
    result["hlo_lines"] = text.count("\n")
    if save_hlo:
        (out_path.parent / (out_path.stem + ".hlo.txt")).write_text(text)
    out_path.write_text(json.dumps(result, indent=1))

    print(json.dumps({k: v for k, v in result.items() if k != "collectives"},
                     indent=1))
    print("collectives:", json.dumps(analysis["collectives"]))
    print("memory_analysis:", mem)
    return result


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def all_cells(meshes):
    from repro.configs.base import SHAPES
    from repro.configs import ALL_ARCHS
    for arch in ALL_ARCHS:
        for shape in SHAPES:
            for mesh in meshes:
                yield arch, shape, mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--tag", default="", help="suffix for the result file "
                    "(perf-knob experiments, see benchmarks/hillclimb.py)")
    ap.add_argument("--timeout", type=int, default=3000)
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if args.all:
        meshes = ["pod1", "pod2"] if args.mesh == "both" else [args.mesh]
        todo = list(all_cells(meshes))
        for i, (arch, shape, mesh) in enumerate(todo):
            out = RESULTS / f"{arch}__{shape}__{mesh}.json"
            if out.exists():
                continue
            print(f"[{i+1}/{len(todo)}] {arch} x {shape} x {mesh}", flush=True)
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh]
            if args.save_hlo:
                cmd.append("--save-hlo")
            try:
                r = subprocess.run(cmd, timeout=args.timeout,
                                   capture_output=True, text=True)
                if r.returncode != 0:
                    err = (r.stderr or "")[-3000:]
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh,
                         "status": "error", "error": err}, indent=1))
                    print(f"  ERROR (see {out})", flush=True)
                else:
                    print("  ok", flush=True)
            except subprocess.TimeoutExpired:
                out.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "mesh": mesh,
                     "status": "timeout"}, indent=1))
                print("  TIMEOUT", flush=True)
        return

    suffix = f"__{args.tag}" if args.tag else ""
    out = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
    try:
        run_cell(args.arch, args.shape, args.mesh, out, save_hlo=args.save_hlo)
    except Exception:
        out.write_text(json.dumps(
            {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
             "status": "error", "error": traceback.format_exc()[-4000:]},
            indent=1))
        raise


if __name__ == "__main__":
    main()
