"""Performance knobs for the §Perf hillclimbing loop.

Each knob is an env var so a dry-run subprocess can flip it without code
edits; ``benchmarks/hillclimb.py`` drives the hypothesis -> change ->
re-lower -> measure cycles and records them in EXPERIMENTS.md §Perf.

Knobs (defaults = the paper-faithful baseline):
  REPRO_REMAT_POLICY   dots | nothing
      dots    — save no-batch-dim dot outputs (fast recompute, high memory)
      nothing — save only layer boundaries (lowest memory, ~30% fwd recompute)
  REPRO_TRAIN_SHARDING fsdp_tp | dp
      fsdp_tp — weights sharded over (data x model); the baseline
      dp      — pure data parallelism over ALL mesh axes, weights replicated
                (what Auto Distribution picks for small models when the
                per-device memory constraint is satisfied)
  REPRO_SEQ_PARALLEL   0 | 1
      1 — residual stream sharded over the model axis on the sequence dim
          between attention/mlp regions (Korthikanti-style SP)
  REPRO_MOE_DECODE     gather | dispatch
      gather   — each token gathers its experts' weights (baseline)
      dispatch — capacity-based token all-to-all to expert shards
  REPRO_ATTN_CHUNK     int (q-chunk for the online-softmax attention path)
  REPRO_NORM_F32       1 | 0
      0 — rms_norm computes in the activation dtype (bf16): prevents the
          CPU-backend convert-folding that upgrades downstream dots and
          collectives to f32 (on TPU the MXU keeps bf16 inputs regardless)
  REPRO_OPT_STATE      f32 | int8
      int8 — block-quantized AdamW moments (~2.03 B/param instead of 8)
  REPRO_WEIGHT_AG      0 | 1
      1 — constrain layer weights to TP-only inside the layer body, forcing
          GSPMD to ALL-GATHER the (small) FSDP weight shards instead of
          partial-summing + all-reducing the (huge) activations — the fix
          for the dominant collective in the qwen2-vl train cell (§Perf)
  REPRO_KV_SWAP        1 | 0
      1 — serve-engine preemption parks a request's KV blocks on the host
          tier (repro.serve.kv_store.HostTier) and restores them on
          re-admission, resuming mid-generation
      0 — legacy behavior: preempted requests drop their KV and restart
          from the prompt
  REPRO_PAGED_ATTN     auto | kernel | gather
      auto   — paged decode/prefill attention uses the block-streaming
               Pallas kernel on TPU and the dense-gather jnp path on CPU
               (interpret-mode Pallas is emulation, far slower than XLA)
      kernel — force the Pallas paged-attention kernel (interpret on CPU;
               what the parity suite runs)
      gather — force the dense pages[tables] gather fallback
  REPRO_SERVE_MESH     0 | auto | N
      0    — single-device serve KV pool (the default)
      auto — shard the serve engine's block pool over ALL visible devices
             on the kv-heads axis (repro.serve.kv_store.DeviceTier gets a
             NamedSharding slab; attention runs under shard_map per KV head)
      N    — shard over the first N devices.  N must divide the arch's
             n_kv_heads and n_heads; the engine raises otherwise.  An
             explicit ``ServeEngine(mesh=...)`` argument overrides the knob.
  REPRO_GATEWAY_IDLE_MS  int (2)
      how long the gateway's background stepper thread sleeps between polls
      when the engine has no work — lower = lower TTFT on an idle gateway,
      higher = fewer wasted wakeups (repro.serve.async_engine)
  REPRO_GATEWAY_MAX_NEW  int (128)
      per-request cap the HTTP gateway clamps ``max_tokens`` to before
      admission (requests never see the engine's rejection path for
      oversized asks — they get a truncated generation instead)
  REPRO_SERVE_TP       0 | 1
      1 — a mesh-backed ServeEngine also shards the WEIGHTS over the model
          axis using the partition rules Auto Distribution's SBP cost model
          emits (repro.distributed.param_sharding): per-device param bytes
          drop to ~1/n.  Equivalent to ``ServeEngine(tp=True)``; requires
          a mesh (REPRO_SERVE_MESH / ``mesh=``) and divisible
          n_heads/n_kv_heads/d_ff.
  REPRO_SERVE_DEADLINE_MS  int (0)
      default per-request deadline for the serve engine: a request older
      than this (queued, active, or parked) is expired by the step-loop
      reaper with finish_reason="expired" and its KV freed.  0 = no default
      deadline; a per-request ``deadline_ms`` (the gateway's ``timeout``
      body field, seconds) always overrides the knob.
  REPRO_SERVE_MAX_QUEUE  int (0)
      bound on the engine's admission queue.  A submit that would push the
      queue past the bound is shed immediately (finish_reason="shed"; the
      gateway answers 429 with Retry-After).  0 = unbounded (the default —
      closed-loop benches rely on deep queues).
  REPRO_SERVE_SHED_PRESSURE  float (0)
      block-pool pressure threshold for gateway load shedding: when the
      fraction of the pool that is used-or-reserved reaches this value AND
      requests are already queued, new submissions are shed with 429.
      0 = disabled (pool saturation is the *normal* operating point of a
      well-fed engine; only enable for latency-sensitive deployments).
  REPRO_SERVE_MAX_CRASHES  int (3)
      consecutive step-loop crashes (each one quarantines the request it
      blames) before the engine declares itself ``degraded`` — surfaced by
      the gateway's /health as a 503 until a productive step succeeds.
  REPRO_FAULT          fault-injection spec (default "": disabled)
      e.g. "alloc:p=0.05,swap_out:after=3,step:exc=1" — see
      repro.serve.faults.FaultInjector for the grammar.  Injects failures
      at the entry of BlockPool.alloc, KVStore.swap_out/swap_in, and the
      engine's prefill/decode dispatch so the recovery paths actually run
      (the CI chaos-smoke lane drives the gateway under this knob).
  REPRO_FAULT_SEED     int (0)
      seed for the p= probabilistic fault rules (deterministic replay)
  REPRO_LORA_MAX_ADAPTERS  int (8)
      device-slot capacity of the serve engine's AdapterStore: at most this
      many LoRA adapters resident in the device slab at once.  Loading past
      the cap LRU-evicts an idle (refcount-0, unpinned) adapter to the host
      swap tier; if every slot is busy the load fails and the request is
      rejected rather than silently degrading a live tenant.
  REPRO_LORA_RANK      int (8)
      rank of synthetically materialized adapters (the gateway's lazy
      loader and the multilora bench derive adapter weights from the
      adapter *name*, so any declared tenant is servable without a
      checkpoint on disk).  Explicitly supplied weights keep their own rank.
  REPRO_LORA_ALPHA     float (16)
      LoRA alpha for synthetic adapters; the alpha/rank scale is folded
      into the B slab at load time so the kernels stay scale-free.
  REPRO_TP_REDUCE_SCATTER  0 | 1
      0 — TP weights are gathered at their use site, so decode stays
          BITWISE identical to single-device (storage scales, traffic
          doesn't)
      1 — compute follows the stored column/row layout: in-projections run
          shard-local and each output projection partial-sums into one
          all-reduce per layer — real TP traffic, output matches within
          fp32 tolerance instead of bitwise (see docs/sharding.md)
"""
from __future__ import annotations

import dataclasses
import os


@dataclasses.dataclass(frozen=True)
class PerfConfig:
    remat_policy: str = "dots"
    train_sharding: str = "fsdp_tp"
    seq_parallel: bool = False
    moe_decode: str = "gather"
    attn_chunk: int = 1024
    norm_f32: bool = True
    opt_state: str = "f32"
    weight_ag: bool = False
    paged_attn: str = "auto"
    kv_swap: bool = True
    serve_mesh: str = "0"
    gateway_idle_ms: int = 2
    gateway_max_new: int = 128
    serve_tp: bool = False
    tp_reduce_scatter: bool = False
    serve_deadline_ms: int = 0
    serve_max_queue: int = 0
    serve_shed_pressure: float = 0.0
    serve_max_crashes: int = 3
    fault_spec: str = ""
    fault_seed: int = 0
    lora_max_adapters: int = 8
    lora_rank: int = 8
    lora_alpha: float = 16.0


def perf() -> PerfConfig:
    return PerfConfig(
        remat_policy=os.environ.get("REPRO_REMAT_POLICY", "dots"),
        train_sharding=os.environ.get("REPRO_TRAIN_SHARDING", "fsdp_tp"),
        seq_parallel=os.environ.get("REPRO_SEQ_PARALLEL", "0") == "1",
        moe_decode=os.environ.get("REPRO_MOE_DECODE", "gather"),
        attn_chunk=int(os.environ.get("REPRO_ATTN_CHUNK", "1024")),
        norm_f32=os.environ.get("REPRO_NORM_F32", "1") == "1",
        opt_state=os.environ.get("REPRO_OPT_STATE", "f32"),
        weight_ag=os.environ.get("REPRO_WEIGHT_AG", "0") == "1",
        paged_attn=os.environ.get("REPRO_PAGED_ATTN", "auto"),
        kv_swap=os.environ.get("REPRO_KV_SWAP", "1") == "1",
        serve_mesh=os.environ.get("REPRO_SERVE_MESH", "0"),
        gateway_idle_ms=int(os.environ.get("REPRO_GATEWAY_IDLE_MS", "2")),
        gateway_max_new=int(os.environ.get("REPRO_GATEWAY_MAX_NEW", "128")),
        serve_tp=os.environ.get("REPRO_SERVE_TP", "0") == "1",
        tp_reduce_scatter=os.environ.get("REPRO_TP_REDUCE_SCATTER", "0") == "1",
        serve_deadline_ms=int(os.environ.get("REPRO_SERVE_DEADLINE_MS", "0")),
        serve_max_queue=int(os.environ.get("REPRO_SERVE_MAX_QUEUE", "0")),
        serve_shed_pressure=float(
            os.environ.get("REPRO_SERVE_SHED_PRESSURE", "0")),
        serve_max_crashes=int(os.environ.get("REPRO_SERVE_MAX_CRASHES", "3")),
        fault_spec=os.environ.get("REPRO_FAULT", ""),
        fault_seed=int(os.environ.get("REPRO_FAULT_SEED", "0")),
        lora_max_adapters=int(
            os.environ.get("REPRO_LORA_MAX_ADAPTERS", "8")),
        lora_rank=int(os.environ.get("REPRO_LORA_RANK", "8")),
        lora_alpha=float(os.environ.get("REPRO_LORA_ALPHA", "16")),
    )


def remat_policy_fn():
    import jax
    p = perf().remat_policy
    if p == "nothing":
        return jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint_policies.dots_with_no_batch_dims_saveable


def knob_snapshot() -> dict:
    p = perf()
    return dataclasses.asdict(p)
