"""zamba2-2.7b [hybrid] — 54L d_model=2560 Mamba2 + shared attention block.

54 Mamba2 (SSD, state=64) layers; one *weight-shared* transformer block
(32H kv=32, d_ff=10240) applied every 6 layers.  Sub-quadratic overall:
runs long_500k (attention caches exist only for the 9 shared-block call
sites).  [arXiv:2411.15242; hf]
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig, register


@register("zamba2-2.7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000, head_dim=80,
        act="swiglu", rope="rope",
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      chunk=256, version=2),
        hybrid=HybridConfig(attn_every=6, shared_d_ff=10240),
        full_attention=False,
    )
