"""qwen3-0.6b [dense] — 28L d_model=1024 16H (GQA kv=8) d_ff=3072 vocab=151936.

qk_norm + GQA; head_dim=128 (q proj widens 1024 -> 2048).  The paper's own
evaluation family (Qwen3).  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.configs.base import ModelConfig, register


@register("qwen3-0.6b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-0.6b", family="dense",
        n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
        d_ff=3072, vocab=151936, head_dim=128,
        act="swiglu", qk_norm=True, rope="rope", rope_theta=1e6,
        tie_embeddings=True, full_attention=True,
    )
