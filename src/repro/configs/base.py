"""Config system: model configs, input-shape specs, and the arch registry.

Every assigned architecture is a ``ModelConfig`` instance registered under its
``--arch`` id.  Shapes are registered ``ShapeSpec``s; an (arch x shape) pair is
a dry-run *cell*.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    # Apply MoE every `every` layers (1 = every layer).  Non-MoE layers use a
    # dense FFN of width `d_ff_dense`.
    every: int = 1
    d_ff_dense: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    # mamba2/SSD only:
    head_dim: int = 64
    chunk: int = 256
    version: int = 1  # 1 = mamba1 selective scan, 2 = mamba2 SSD


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    # zamba2-style: a single *shared* transformer block applied every N layers.
    attn_every: int = 6
    shared_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    n_enc_layers: int = 12
    # decoder layer count reuses ModelConfig.n_layers


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | ssm | moe | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 -> d_model // n_heads
    act: str = "swiglu"          # swiglu | squared_relu | gelu
    qk_norm: bool = False
    rope: str = "rope"           # rope | mrope | none
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    dtype: str = "bfloat16"
    # Whether this arch has *any* full-attention path (drives long_500k skip).
    full_attention: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-flops in roofline)."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        n = emb
        if self.family == "ssm" and self.ssm is not None:
            di = self.ssm.expand * d
            per = (d * 2 * di               # in_proj (x, z)
                   + di * self.ssm.d_conv   # depthwise conv
                   + di * (2 * self.ssm.d_state + max(1, d // 16))  # B,C,dt proj
                   + max(1, d // 16) * di   # dt up-proj
                   + di * self.ssm.d_state  # A
                   + di                     # D
                   + di * d)                # out_proj
            n += self.n_layers * (per + d)
            return n
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.act in ("swiglu",):
            ffn_dense = 3 * d * self.d_ff
        else:
            ffn_dense = 2 * d * self.d_ff
        if self.moe is not None:
            m = self.moe
            per_exp = (3 if self.act == "swiglu" else 2) * d * m.d_ff_expert
            n_moe = self.n_layers // m.every
            n_dense = self.n_layers - n_moe
            ffn_total = (n_moe * (m.n_experts + m.n_shared_experts) * per_exp
                         + n_moe * d * m.n_experts  # router
                         + n_dense * ((3 if self.act == "swiglu" else 2) * d * (m.d_ff_dense or self.d_ff)))
        else:
            ffn_total = self.n_layers * ffn_dense
        if self.family == "hybrid" and self.ssm is not None and self.hybrid is not None:
            di = self.ssm.expand * d
            per = (d * 2 * di + di * self.ssm.d_conv + di * 2 * self.ssm.d_state
                   + di + di + di * d)
            n += self.n_layers * (per + d)
            # one shared attention+mlp block
            n += attn + (3 * d * (self.hybrid.shared_d_ff or self.d_ff)) + 2 * d
            return n
        n += self.n_layers * (attn + 2 * d) + ffn_total
        if self.encdec is not None:
            # encoder layers + decoder cross-attention
            n += self.encdec.n_enc_layers * (attn + ffn_dense + 2 * d)
            n += self.n_layers * attn  # cross-attn per decoder layer
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE-aware) for 6*N_active*D flops."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        per_exp = (3 if self.act == "swiglu" else 2) * d * m.d_ff_expert
        n_moe = self.n_layers // m.every
        inactive = n_moe * (m.n_experts - m.top_k) * per_exp
        return self.param_count() - inactive


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn
    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        # import registers all configs
        from repro import configs  # noqa: F401
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]()


def list_archs():
    from repro import configs  # noqa: F401
    return sorted(_REGISTRY)


def cell_is_runnable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Assignment rules: long_500k only for sub-quadratic (ssm/hybrid) archs."""
    if shape.name == "long_500k" and cfg.full_attention:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md §4)"
    return True, ""


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        name=cfg.name + "-smoke", family=cfg.family,
        n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16, d_ff=128, vocab=256,
        act=cfg.act, qk_norm=cfg.qk_norm, rope=cfg.rope,
        tie_embeddings=cfg.tie_embeddings, dtype="float32",
        full_attention=cfg.full_attention,
    )
    if cfg.moe is not None:
        kw["moe"] = MoEConfig(
            n_experts=4, top_k=min(cfg.moe.top_k, 2), d_ff_expert=32,
            every=cfg.moe.every, d_ff_dense=64,
            n_shared_experts=cfg.moe.n_shared_experts)
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2, head_dim=16,
                              chunk=8, version=cfg.ssm.version)
    if cfg.hybrid is not None:
        kw["hybrid"] = HybridConfig(attn_every=2, shared_d_ff=128)
    if cfg.encdec is not None:
        kw["encdec"] = EncDecConfig(n_enc_layers=2)
    return ModelConfig(**kw)


def jnp_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
