"""olmoe-1b-7b [moe] — 16L d_model=2048 16H (GQA kv=16) d_ff=1024, 64e top-8.

Every layer is MoE: 64 experts, top-8 routing.  [arXiv:2409.02060; hf]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("olmoe-1b-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1024, vocab=50304, head_dim=128,
        act="swiglu", qk_norm=True, rope="rope",
        moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024,
                      every=1, capacity_factor=2.0),
        full_attention=True,
    )
