"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192.

MoE 128 experts top-1 + 1 shared expert on every other layer (interleaved
dense FFN d_ff=16384), early fusion, vocab=202048.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""
from repro.configs.base import ModelConfig, MoEConfig, register


@register("llama4-maverick-400b-a17b")
def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048, head_dim=128,
        act="swiglu", rope="rope",
        moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192,
                      every=2, d_ff_dense=16384, n_shared_experts=1,
                      capacity_factor=1.25),
        full_attention=True,
    )
