"""qwen2-vl-72b [vlm] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064.

M-RoPE + dynamic resolution; the vision patch frontend is a STUB
(input_specs provides precomputed patch/token embeddings).
[arXiv:2409.12191; hf]
"""
from repro.configs.base import ModelConfig, register


@register("qwen2-vl-72b")
def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, head_dim=128,
        act="swiglu", rope="mrope", rope_theta=1e6, full_attention=True,
    )
