"""whisper-small [audio] — 12L enc + 12L dec, d_model=768 12H d_ff=3072.

Enc-dec; the conv/mel frontend is a STUB per the assignment (input_specs
provides precomputed frame embeddings).  vocab=51865, GELU MLP.
[arXiv:2212.04356; unverified]
"""
from repro.configs.base import EncDecConfig, ModelConfig, register


@register("whisper-small")
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865, head_dim=64,
        act="gelu", rope="none",
        encdec=EncDecConfig(n_enc_layers=12),
        full_attention=True,
    )
