"""Arch registry: importing this package registers all assigned architectures."""
from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, HybridConfig, EncDecConfig,
    ShapeSpec, SHAPES, get_config, list_archs, cell_is_runnable,
    reduced_config, jnp_dtype,
)
from repro.configs import (  # noqa: F401
    stablelm_3b, qwen3_0_6b, nemotron_4_15b, phi3_mini_3_8b,
    falcon_mamba_7b, qwen2_vl_72b, llama4_maverick_400b_a17b,
    olmoe_1b_7b, whisper_small, zamba2_2_7b,
)

ALL_ARCHS = list_archs()
