"""falcon-mamba-7b [ssm] — 64L d_model=4096 attention-free, ssm_state=16.

Mamba1 selective-scan architecture; vocab=65024.  Sub-quadratic: runs
long_500k.  [arXiv:2410.05355; unverified]
"""
from repro.configs.base import ModelConfig, SSMConfig, register


@register("falcon-mamba-7b")
def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="ssm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=65024,
        act="swiglu", rope="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, chunk=256, version=1),
        full_attention=False,
    )
