"""Unified end-to-end nncase pipeline: one call from Term to executable.

The paper's framing is an *end-to-end* compiler — an e-graph term-rewriting
engine feeding Auto Vectorize, Auto Distribution, and Auto Schedule, closed
out by a buffer-aware Codegen.  This module is that driver: every pass the
repo implements as a library call is chained behind one entry point,

    from repro.pipeline import compile
    result = compile(term, target=CompileTarget(...), options=CompileOptions(...))
    y = result(**inputs)                  # executable callable
    result.report.pass_times              # per-pass wall time
    result.report.modeled_speedup         # extraction cost vs baseline

Pass chain (each stage timed into ``CompileReport.pass_times``):

  rewrite     e-graph construction + transpose-rule equality saturation
  extract     cost-aware extraction — greedy / branch-and-bound / WPMaxSAT
  vectorize   MetaPackOperation saturation + re-extraction (packed variants)
  distribute  SBP strategy search (skipped on 1-device targets)
  schedule    Term -> TileGraph bridge, MCTS structure + MINLP tiles
  buffer      liveness + bin-packing memory plan (greedy or exact)
  codegen     compile_term -> jit-able callable (jnp reference or Pallas)

Compilation results are cached content-addressed on
(term fingerprint, target, options) — in-memory per ``Compiler`` and
optionally on disk — so repeated serve / benchmark invocations skip
saturation and extraction entirely and only re-run codegen.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import os
import pickle
import tempfile
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core.buffer_schedule import (liveness_from_term, naive_peak,
                                        plan_greedy, plan_optimal)
from repro.core.codegen import KernelPlan, compile_term, kernel_plan
from repro.core.egraph import EGraph
from repro.core.extraction import (branch_bound_extract, extract_term,
                                   greedy_extract, wpmaxsat_extract)
from repro.core.rewrite import TRANSPOSE_RULES
from repro.core.sbp import Placement
from repro.core.schedule import auto_schedule
from repro.core.schedule.ntt import op_ukernel
from repro.core.schedule.tile_graph import Buffer, Group, OpSpec, TileGraph
from repro.core.tensor_ir import Term, term_shape
from repro.core.vectorize import VECTORIZE_RULES

PIPELINE_VERSION = 1

PASS_NAMES = ("rewrite", "extract", "vectorize", "distribute", "schedule",
              "buffer", "codegen")

EXTRACTION_BACKENDS = ("greedy", "branch-and-bound", "wpmaxsat")


# ---------------------------------------------------------------------------
# Targets / options / report
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompileTarget:
    """Where the compiled program runs: device mesh + per-device memory."""
    mesh_axes: Tuple[str, ...] = ("data",)
    mesh_sizes: Tuple[int, ...] = (1,)
    memory_capacity: Optional[int] = None    # bytes/device for distribution
    use_pallas: bool = False
    dtype_bytes: int = 2

    @property
    def placement(self) -> Placement:
        return Placement(tuple(self.mesh_axes), tuple(self.mesh_sizes))

    @property
    def n_devices(self) -> int:
        n = 1
        for s in self.mesh_sizes:
            n *= s
        return n


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Pass toggles and search budgets."""
    extraction: str = "wpmaxsat"         # one of EXTRACTION_BACKENDS
    saturation_iters: int = 8
    node_limit: int = 8000
    vectorize: bool = True
    distribute: Optional[bool] = None    # None = auto: only when devices > 1
    # the SBP e-graph is much larger than the vectorize one; WPMaxSAT there
    # is minutes-slow, so the distribution extractor is chosen separately
    # (memory-capped targets always use the exact branch & bound)
    distribution_use_sat: bool = False
    schedule: bool = True
    schedule_iterations: int = 25
    buffer_plan: str = "greedy"          # "greedy" | "optimal"
    cache: bool = True

    def __post_init__(self):
        if self.extraction not in EXTRACTION_BACKENDS:
            raise ValueError(f"extraction must be one of {EXTRACTION_BACKENDS},"
                             f" got {self.extraction!r}")
        if self.buffer_plan not in ("greedy", "optimal"):
            raise ValueError(f"unknown buffer_plan {self.buffer_plan!r}")


@dataclasses.dataclass
class CompileReport:
    """Per-pass telemetry for one compile() invocation."""
    pass_times: Dict[str, float] = dataclasses.field(default_factory=dict)
    egraph: Dict[str, Any] = dataclasses.field(default_factory=dict)
    extraction_backend: str = ""
    baseline_cost: float = 0.0           # greedy cost of the unrewritten term
    optimized_cost: float = 0.0          # cost of the final extracted term
    modeled_speedup: float = 1.0
    distribution: Optional[Dict[str, Any]] = None
    schedule: Optional[Dict[str, Any]] = None
    kernel_plan: Optional[KernelPlan] = None
    buffer: Dict[str, Any] = dataclasses.field(default_factory=dict)
    cache_hit: bool = False
    cache_key: str = ""
    total_seconds: float = 0.0

    def summary(self) -> str:
        lines = [f"cache_hit={self.cache_hit} "
                 f"backend={self.extraction_backend} "
                 f"total={self.total_seconds * 1e3:.1f}ms"]
        for name in PASS_NAMES:
            if name in self.pass_times:
                lines.append(f"  {name:10s} {self.pass_times[name] * 1e3:8.2f}ms")
        lines.append(f"  modeled: baseline {self.baseline_cost:.3e}s -> "
                     f"optimized {self.optimized_cost:.3e}s "
                     f"({self.modeled_speedup:.2f}x)")
        if self.distribution:
            lines.append(f"  distribute: cost {self.distribution['cost']:.3e}s "
                         f"peak {self.distribution['peak_memory'] / 1e6:.1f} MB/dev")
        if self.schedule:
            lines.append(f"  schedule: {self.schedule['baseline_latency']:.3e}s -> "
                         f"{self.schedule['latency']:.3e}s, "
                         f"vmem peak {self.schedule['vmem_peak'] / 2**20:.1f} MB")
        if self.buffer:
            lines.append(f"  buffer: peak {self.buffer['peak']} B "
                         f"(naive {self.buffer['naive']} B)")
        return "\n".join(lines)


@dataclasses.dataclass
class CompileResult:
    """Executable + the term it runs + full telemetry."""
    fn: Callable
    term: Term                           # final (possibly packed) term
    logical_term: Term                   # pre-vectorize logical term
    report: CompileReport

    def __call__(self, **inputs):
        return self.fn(**inputs)


# ---------------------------------------------------------------------------
# Term -> TileGraph bridge (feeds Auto Schedule from arbitrary 2-D terms)
# ---------------------------------------------------------------------------

_SCHEDULABLE_OPS = ("input", "matmul", "unary", "binary")


def tile_graph_from_term(term: Term) -> Optional[TileGraph]:
    """Lower a 2-D logical Term DAG to a TileGraph for Auto Schedule.

    Loop names come from unifying tensor dimensions across ops: matmul ties
    (A row, out row), (B col, out col) and (A col, B row) — the contraction
    loop; elementwise ops tie every dim to their inputs'.  Returns None when
    the term contains ops the schedule space doesn't model (packed/boxed
    forms are scheduled at kernel granularity instead).
    """
    topo: List[Term] = []
    seen: Dict[Term, int] = {}

    def walk(t: Term):
        if t in seen:
            return
        for c in t.children:
            walk(c)
        seen[t] = len(topo)
        topo.append(t)
    walk(term)

    shape_cache: Dict[Term, Tuple[int, ...]] = {}
    for t in topo:
        if t.op not in _SCHEDULABLE_OPS:
            return None
        if len(term_shape(t, shape_cache)) != 2:
            return None

    # union-find over (term index, dim) pairs -> shared loop names
    parent: Dict[Tuple[int, int], Tuple[int, int]] = {}

    def find(x):
        parent.setdefault(x, x)
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[rb] = ra

    contraction: Dict[int, Tuple[int, int]] = {}
    for t in topo:
        ti = seen[t]
        if t.op == "matmul":
            a, b = seen[t.children[0]], seen[t.children[1]]
            union((ti, 0), (a, 0))
            union((ti, 1), (b, 1))
            union((a, 1), (b, 0))
            contraction[ti] = (a, 1)
        elif t.op in ("unary", "binary"):
            for c in t.children:
                ci = seen[c]
                union((ti, 0), (ci, 0))
                union((ti, 1), (ci, 1))

    # name each dim class in first-seen topo order; verify extents agree
    loop_name: Dict[Tuple[int, int], str] = {}
    extents: List[Tuple[str, int]] = []
    extent_of: Dict[str, int] = {}
    for t in topo:
        ti = seen[t]
        for d, size in enumerate(term_shape(t, shape_cache)):
            root = find((ti, d))
            if root not in loop_name:
                name = f"l{len(extents)}"
                loop_name[root] = name
                extents.append((name, size))
                extent_of[name] = size
            elif extent_of[loop_name[root]] != size:
                return None

    def loops_of(ti: int, t: Term) -> Tuple[str, ...]:
        return tuple(loop_name[find((ti, d))]
                     for d in range(len(term_shape(t, shape_cache))))

    buffers: Dict[int, Buffer] = {}
    for t in topo:
        ti = seen[t]
        buffers[ti] = Buffer(f"t{ti}", loops_of(ti, t),
                             elem_bytes=2)

    ops: List[OpSpec] = []
    groups: List[Group] = []
    for t in topo:
        if t.op == "input":
            continue
        ti = seen[t]
        out_loops = loops_of(ti, t)
        if t.op == "matmul":
            k_loop = loop_name[find(contraction[ti])]
            op_loops = out_loops + (k_loop,)
        else:
            op_loops = out_loops
        reads = tuple(buffers[seen[c]] for c in t.children)
        spec = OpSpec(f"op{ti}", op_ukernel(t.op, t.attr("kind")),
                      op_loops, reads, buffers[ti])
        ops.append(spec)
        groups.append(Group((spec.name,), op_loops))
    if not ops:
        return None
    return TileGraph(tuple(ops), tuple(extents), tuple(groups))


# ---------------------------------------------------------------------------
# Fingerprinting (content-addressed cache keys)
# ---------------------------------------------------------------------------

def term_fingerprint(term: Term) -> str:
    """Stable content hash of a term tree (repr is deterministic: attrs are
    sorted tuples, children ordered)."""
    return hashlib.sha256(repr(term).encode()).hexdigest()


def cache_key(term: Term, target: CompileTarget,
              options: CompileOptions) -> str:
    payload = json.dumps({
        "v": PIPELINE_VERSION,
        "term": term_fingerprint(term),
        "target": repr(dataclasses.astuple(target)),
        "options": repr(dataclasses.astuple(options)),
    }, sort_keys=True)
    return hashlib.sha256(payload.encode()).hexdigest()


# ---------------------------------------------------------------------------
# The compiler driver
# ---------------------------------------------------------------------------

class _Timer:
    def __init__(self, report: CompileReport, name: str):
        self.report, self.name = report, name

    def __enter__(self):
        self.t0 = time.monotonic()
        return self

    def __exit__(self, *exc):
        self.report.pass_times[self.name] = time.monotonic() - self.t0
        return False


def _extract(backend: str, eg: EGraph, root: int):
    if backend == "greedy":
        return greedy_extract(eg, root)
    if backend == "branch-and-bound":
        return branch_bound_extract(eg, root)
    return wpmaxsat_extract(eg, root)


class Compiler:
    """Stateful driver: owns the compile cache.

    By default the on-disk location comes from ``REPRO_CACHE_DIR`` (unset ->
    memory-only); pass ``cache_dir=<path>`` to persist extracted terms +
    reports across processes, or an explicit ``cache_dir=None`` to force a
    memory-only cache regardless of the environment.  Cache hits skip
    saturation/extraction/search and only re-run codegen (callables are not
    serializable; everything else is).
    """

    _FROM_ENV = object()

    def __init__(self, cache_dir=_FROM_ENV):
        if cache_dir is Compiler._FROM_ENV:
            cache_dir = os.environ.get("REPRO_CACHE_DIR") or None
        self.cache_dir = cache_dir
        self._memory: Dict[str, Dict[str, Any]] = {}
        self.stats = {"hits": 0, "misses": 0}

    # -- cache plumbing ----------------------------------------------------
    def _disk_path(self, key: str) -> Optional[str]:
        if not self.cache_dir:
            return None
        return os.path.join(self.cache_dir, f"{key}.pkl")

    def _cache_get(self, key: str) -> Optional[Dict[str, Any]]:
        if key in self._memory:
            return self._memory[key]
        path = self._disk_path(key)
        if path and os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    entry = pickle.load(f)
                self._memory[key] = entry
                return entry
            except Exception:
                return None
        return None

    def _cache_put(self, key: str, entry: Dict[str, Any]):
        self._memory[key] = entry
        path = self._disk_path(key)
        if not path:
            return
        os.makedirs(self.cache_dir, exist_ok=True)
        # atomic write: never leave a torn pickle for concurrent readers
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f)
            os.replace(tmp, path)
        except Exception:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # -- passes ------------------------------------------------------------
    def _run_pipeline(self, term: Term, target: CompileTarget,
                      options: CompileOptions, report: CompileReport
                      ) -> Tuple[Term, Term]:
        """Saturate/extract/search; returns (logical, packed) terms and
        fills in every report field except codegen timing."""
        # 1. rewrite: e-graph + transpose-rule equality saturation
        with _Timer(report, "rewrite"):
            eg = EGraph()
            root = eg.add_term(term)
            report.baseline_cost, _ = greedy_extract(eg, root)
            stats = eg.saturate(TRANSPOSE_RULES,
                                max_iters=options.saturation_iters,
                                node_limit=options.node_limit)
            report.egraph = {"rewrite_iters": stats["iters"],
                            "rewrite_applications": stats["applications"],
                            "size_after_rewrite": eg.size()}

        # 2. extract: cost-aware extraction with the selected backend
        with _Timer(report, "extract"):
            cost, choice = _extract(options.extraction, eg, root)
            logical = extract_term(eg, root, choice)
            report.optimized_cost = cost

        # 3. vectorize: packed-variant saturation over the extracted term
        packed = logical
        if options.vectorize:
            with _Timer(report, "vectorize"):
                veg = EGraph()
                vroot = veg.add_term(logical)
                vstats = veg.saturate(VECTORIZE_RULES + TRANSPOSE_RULES,
                                      max_iters=options.saturation_iters,
                                      node_limit=options.node_limit)
                vcost, vchoice = _extract(options.extraction, veg, vroot)
                packed = extract_term(veg, vroot, vchoice)
                report.optimized_cost = vcost
                report.egraph.update(
                    {"vectorize_iters": vstats["iters"],
                     "vectorize_applications": vstats["applications"],
                     "size_after_vectorize": veg.size()})
        report.modeled_speedup = (report.baseline_cost
                                  / max(report.optimized_cost, 1e-30))

        # 4. distribute: SBP search on the logical term (Fig. 6 granularity);
        # a 1-device mesh has exactly one strategy, so the search is skipped
        # unless explicitly forced with distribute=True
        do_dist = options.distribute
        if do_dist is None:
            do_dist = target.n_devices > 1
        if do_dist:
            from repro.core.distribution import auto_distribute
            with _Timer(report, "distribute"):
                plan = auto_distribute(
                    logical, target.placement,
                    mem_capacity=target.memory_capacity,
                    use_sat=options.distribution_use_sat)
                report.distribution = {
                    "cost": plan.cost,
                    "peak_memory": plan.peak_memory,
                    "n_boxing": len(plan.boxing),
                    "assignments": plan.assignments,
                }

        # 5. schedule: MCTS structure + MINLP tiles over the tile graph
        if options.schedule:
            with _Timer(report, "schedule"):
                tg = tile_graph_from_term(logical)
                if tg is not None:
                    state, sched, base = auto_schedule(
                        tg, iterations=options.schedule_iterations)
                    report.schedule = {
                        "latency": sched.latency,
                        "baseline_latency": base.latency,
                        "t_mem": sched.t_mem,
                        "t_comp": sched.t_comp,
                        "vmem_peak": sched.vmem_peak,
                        "groups": [list(g.ops) for g in state.groups],
                    }
                    report.kernel_plan = kernel_plan(sched)

        # 6. buffer: liveness + bin-packing plan on the final packed term
        with _Timer(report, "buffer"):
            bufs = liveness_from_term(packed, dtype_bytes=target.dtype_bytes)
            planner = plan_optimal if options.buffer_plan == "optimal" \
                else plan_greedy
            offsets, peak = planner(bufs)
            report.buffer = {"peak": peak, "naive": naive_peak(bufs),
                             "n_buffers": len(bufs),
                             "offsets": offsets}
        return logical, packed

    # -- entry point -------------------------------------------------------
    def compile(self, term: Term,
                target: Optional[CompileTarget] = None,
                options: Optional[CompileOptions] = None) -> CompileResult:
        target = target or CompileTarget()
        options = options or CompileOptions()
        if not isinstance(term, Term):
            raise TypeError(f"compile() expects a Term, got {type(term)!r}")
        t0 = time.monotonic()
        key = cache_key(term, target, options)

        entry = self._cache_get(key) if options.cache else None
        if entry is not None:
            self.stats["hits"] += 1
            # deep copy: the report's nested dicts must not alias the cache
            # entry, or caller mutation would poison every later hit
            report = CompileReport(**copy.deepcopy(entry["report"]))
            report.cache_hit = True
            report.cache_key = key
            with _Timer(report, "codegen"):
                fn = compile_term(entry["packed"],
                                  use_pallas=target.use_pallas)
            report.total_seconds = time.monotonic() - t0
            return CompileResult(fn, entry["packed"], entry["logical"],
                                 report)

        self.stats["misses"] += 1
        report = CompileReport(extraction_backend=options.extraction,
                               cache_key=key)
        logical, packed = self._run_pipeline(term, target, options, report)

        # 7. codegen: Term -> executable callable
        with _Timer(report, "codegen"):
            fn = compile_term(packed, use_pallas=target.use_pallas)
        report.total_seconds = time.monotonic() - t0

        if options.cache:
            # field-wise deep copy (dataclasses.asdict would mangle the SBP
            # objects nested in the distribution dict, and sharing dicts with
            # the returned report would let callers mutate the cache);
            # cache_hit/total_seconds are per-invocation, recomputed on hit
            stored = {f.name: copy.deepcopy(getattr(report, f.name))
                      for f in dataclasses.fields(report)
                      if f.name not in ("cache_hit", "total_seconds")}
            self._cache_put(key, {"packed": packed, "logical": logical,
                                  "report": stored})
        return CompileResult(fn, packed, logical, report)


_DEFAULT_COMPILER: Optional[Compiler] = None


def default_compiler() -> Compiler:
    global _DEFAULT_COMPILER
    if _DEFAULT_COMPILER is None:
        _DEFAULT_COMPILER = Compiler()
    return _DEFAULT_COMPILER


def compile(term: Term,
            target: Optional[CompileTarget] = None,
            options: Optional[CompileOptions] = None) -> CompileResult:
    """One-call end-to-end compile through the module-level default
    ``Compiler`` (shares its cache across callers in the process)."""
    return default_compiler().compile(term, target=target, options=options)
