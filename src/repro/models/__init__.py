from repro.models.model_zoo import build_model, ModelFns  # noqa: F401
