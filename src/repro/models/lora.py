"""Model-side multi-LoRA glue: apply each batch row's own adapter delta.

The serve engine threads a ``lora`` descriptor through the paged model
functions when (and only when) at least one adapter is loaded:

    {"ids": (B,) int32 per-sequence adapter slot (-1 = base-only),
     "slabs": {proj: {"a": (L, S, d_in, R), "b": (L, S, R, d_out)}}}

The layer scan slices the leading layer axis off every slab, so inside a
layer body ``slabs[proj]`` is ``(S, d_in, R)`` / ``(S, R, d_out)`` and the
segmented kernels gather per-row.  When the descriptor is ``None`` (no
tenant has an adapter) nothing here traces a single op — that structural
absence is the ``adapter_id=None`` bitwise-identity contract, asserted by
tests/test_multilora.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def split_layers(lora: Optional[dict], every: int):
    """Reshape a full-stack descriptor's slabs for the transformer's
    super-layer scan: returns a tuple of ``every`` per-sub-layer slab
    stacks, each with leading axis ``n_layers // every`` (matching how
    ``init_lm`` stacks ``params['layers']``: sub-stack ``j`` holds layers
    ``j, every+j, ...``).  The ids stay in the scan body's closure; only
    the slabs ride the xs."""
    if lora is None:
        return None
    return tuple(
        {p: {"a": sl["a"][j::every], "b": sl["b"][j::every]}
         for p, sl in lora["slabs"].items()}
        for j in range(every))


def delta(proj: str, x: jax.Array, lora: Optional[dict]) -> jax.Array:
    """The per-row LoRA delta for projection ``proj`` of one layer:
    x (B, S, d_in) -> (B, S, d_out) in x.dtype, or 0 contribution when the
    descriptor is None / doesn't adapt this projection (returns None so the
    caller can skip the add entirely)."""
    if lora is None or proj not in lora["slabs"]:
        return None
    from repro.kernels import ops
    from repro.kernels.lora import lora_plan_block_out
    a = lora["slabs"][proj]["a"]
    b = lora["slabs"][proj]["b"]
    assert a.ndim == 3, \
        f"lora slab for {proj} must be layer-sliced (S,d,R), got {a.shape}"
    bsz, s, d = x.shape
    rows = x.reshape(bsz * s, d)
    ids = jnp.repeat(lora["ids"].astype(jnp.int32), s)
    h = ops.lora_shrink(rows, a, ids)
    block_out = max(1, min(lora_plan_block_out(), int(b.shape[-1])))
    y = ops.lora_expand(h, b, ids, block_out=block_out)
    return y.reshape(bsz, s, -1).astype(x.dtype)


def add_delta(proj: str, base: jax.Array, x: jax.Array,
              lora: Optional[dict]) -> jax.Array:
    """base + per-row delta(proj, x); the base array passes through
    untouched (not even an add traced) when no LoRA is active."""
    d = delta(proj, x, lora)
    return base if d is None else base + d
