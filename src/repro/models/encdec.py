"""Whisper-style encoder-decoder.  The conv/mel frontend is a STUB per the
assignment: inputs are precomputed frame embeddings (B, S_audio, d_model).
Encoder = bidirectional attention blocks; decoder = causal self-attn +
cross-attn + MLP.  Both stacks are scanned.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models.layers import (
    apply_mlp, embed_tokens, init_embed, init_mlp, logits_from_hidden,
    rms_norm, sinusoidal_positions, softmax_cross_entropy,
)


def _init_enc_layer(cfg, rng, dtype):
    r = jax.random.split(rng, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(cfg, r[0], dtype),
        "mlp": init_mlp(cfg, r[1], cfg.d_ff, dtype),
    }


def _init_dec_layer(cfg, rng, dtype):
    r = jax.random.split(rng, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln_x": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "self_attn": attn.init_attention(cfg, r[0], dtype),
        "cross_attn": attn.init_attention(cfg, r[1], dtype),
        "mlp": init_mlp(cfg, r[2], cfg.d_ff, dtype),
    }


def init_encdec(cfg: ModelConfig, rng) -> Dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    ne, nd = cfg.encdec.n_enc_layers, cfg.n_layers
    r = jax.random.split(rng, ne + nd + 1)
    enc = [_init_enc_layer(cfg, r[i], dtype) for i in range(ne)]
    dec = [_init_dec_layer(cfg, r[ne + i], dtype) for i in range(nd)]
    return {
        "embed": init_embed(cfg, r[-1], dtype),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "enc_layers": jax.tree.map(lambda *x: jnp.stack(x), *enc),
        "dec_layers": jax.tree.map(lambda *x: jnp.stack(x), *dec),
    }


def encode(cfg: ModelConfig, params, frames: jax.Array, remat: bool = False):
    """frames (B,S,d) stub embeddings -> encoder output (B,S,d)."""
    b, s, d = frames.shape
    pos = sinusoidal_positions(s, d).astype(frames.dtype)
    x = frames + pos[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        h = x + attn.attention_block(cfg, lp["attn"],
                                     rms_norm(x, lp["ln1"], cfg.norm_eps),
                                     positions, causal=False)
        h = h + apply_mlp(cfg, lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, None
    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer(cfg, lp, x, enc_out, positions, causal=True):
    h = x + attn.attention_block(cfg, lp["self_attn"],
                                 rms_norm(x, lp["ln1"], cfg.norm_eps),
                                 positions, causal=causal)
    # cross attention: q from decoder, k/v from encoder output
    xn = rms_norm(h, lp["ln_x"], cfg.norm_eps)
    q, _, _ = attn.qkv_project(cfg, lp["cross_attn"], xn, positions)
    ek, ev = _enc_kv(cfg, lp["cross_attn"], enc_out)
    o = attn.multi_head_attention(q, ek, ev, causal=False)
    b, s = x.shape[:2]
    h = h + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                       lp["cross_attn"]["wo"])
    h = h + apply_mlp(cfg, lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
    return h


def _enc_kv(cfg, p, enc_out):
    b, s, _ = enc_out.shape
    hd = cfg.resolved_head_dim
    k = jnp.einsum("bsd,de->bse", enc_out, p["wk"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = jnp.einsum("bsd,de->bse", enc_out, p["wv"]).reshape(b, s, cfg.n_kv_heads, hd)
    return k, v


def encdec_loss(cfg: ModelConfig, params, batch: Dict, remat: bool = True):
    enc_out = encode(cfg, params, batch["frames"], remat)
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        return _dec_layer(cfg, lp, x, enc_out, positions), None
    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return softmax_cross_entropy(logits, batch["labels"])


def encdec_prefill(cfg: ModelConfig, params, batch: Dict):
    """Encode audio + prefill decoder self/cross KV caches."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params["embed"], tokens)
    x = x + sinusoidal_positions(s, cfg.d_model).astype(x.dtype)[None]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lp):
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(cfg, lp["self_attn"], xn, positions)
        o = attn.multi_head_attention(q, k, v, causal=True)
        h = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                           lp["self_attn"]["wo"])
        xn = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        qx, _, _ = attn.qkv_project(cfg, lp["cross_attn"], xn, positions)
        ek, ev = _enc_kv(cfg, lp["cross_attn"], enc_out)
        o = attn.multi_head_attention(qx, ek, ev, causal=False)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                           lp["cross_attn"]["wo"])
        h = h + apply_mlp(cfg, lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, (k, v, ek, ev)

    x, (ks, vs, eks, evs) = jax.lax.scan(body, x, params["dec_layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    cache = {"k": ks, "v": vs, "xk": eks, "xv": evs,
             "enc_len": jnp.int32(enc_out.shape[1])}
    return cache, logits


def make_encdec_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "xk": jnp.zeros(shape, dtype), "xv": jnp.zeros(shape, dtype),
            "enc_len": jnp.zeros((), jnp.int32)}


def encdec_decode_step(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    cur_len = batch["cur_len"]
    x = embed_tokens(params["embed"], batch["token"])
    b = x.shape[0]
    # decoder position embedding for the new token
    pos_table = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + jax.lax.dynamic_slice_in_dim(pos_table, cur_len, 1, axis=0
                                         )[None].astype(x.dtype)
    positions = jnp.broadcast_to(cur_len[None, None], (b, 1)).astype(jnp.int32)

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
        o, kc, vc = attn.attention_decode_block(cfg, lp["self_attn"], xn, kc, vc,
                                                cur_len, positions)
        h = x + o
        xn = rms_norm(h, lp["ln_x"], cfg.norm_eps)
        q, _, _ = attn.qkv_project(cfg, lp["cross_attn"], xn, positions)
        # mask cross-attention to the true encoder length (cache may be padded)
        o = attn.decode_attention(q, xk, xv, cache["enc_len"])
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(b, 1, cfg.q_dim),
                           lp["cross_attn"]["wo"])
        h = h + apply_mlp(cfg, lp["mlp"], rms_norm(h, lp["ln2"], cfg.norm_eps))
        return h, (kc, vc)

    x, (k2, v2) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return {"k": k2, "v": v2, "xk": cache["xk"], "xv": cache["xv"],
            "enc_len": cache["enc_len"]}, logits
