"""zamba2-style hybrid: Mamba2 (SSD) backbone + one weight-shared attention
block applied every `attn_every` layers.

Structure: scan over `n_segments = n_layers // attn_every` segments; each
segment body is an inner scan over `attn_every` Mamba2 layers followed by the
shared transformer block (whose weights are closure constants, so HLO stays
one-segment sized).  KV caches are per *call site*: (n_segments, B, S, KV, hd).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba
from repro.models.layers import (
    apply_mlp, embed_tokens, init_embed, init_mlp, logits_from_hidden,
    rms_norm, softmax_cross_entropy,
)


def _n_segments(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.hybrid.attn_every


def init_hybrid(cfg: ModelConfig, rng) -> Dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    n_seg, per = _n_segments(cfg), cfg.hybrid.attn_every
    r = jax.random.split(rng, cfg.n_layers + 4)
    layers = [
        {"ln": jnp.ones((cfg.d_model,), dtype),
         "mamba": mamba.init_mamba2(cfg, r[i], dtype)}
        for i in range(cfg.n_layers)
    ]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    stacked = jax.tree.map(
        lambda x: x.reshape((n_seg, per) + x.shape[1:]), stacked)
    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(cfg, r[-3], dtype),
        "mlp": init_mlp(cfg, r[-2], cfg.hybrid.shared_d_ff or cfg.d_ff, dtype),
    }
    return {
        "embed": init_embed(cfg, r[-1], dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": stacked,        # leading dims (n_seg, per)
        "shared": shared,
    }


def _segment_fwd(cfg, shared, x, seg_layers, positions, collect_kv,
                 impl: Optional[str] = None):
    """Inner scan over `per` mamba layers, then the shared attention block."""
    def mbody(x, lp):
        y, _ = mamba.mamba2_forward(cfg, lp["mamba"],
                                    rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + y, None
    x, _ = jax.lax.scan(mbody, x, seg_layers)
    xn = rms_norm(x, shared["ln1"], cfg.norm_eps)
    if collect_kv:
        q, k, v = attn.qkv_project(cfg, shared["attn"], xn, positions)
        o = attn.multi_head_attention(q, k, v, causal=True, impl=impl)
        b, s = x.shape[:2]
        h = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                           shared["attn"]["wo"])
        kv = (k, v)
    else:
        h = x + attn.attention_block(cfg, shared["attn"], xn, positions,
                                     causal=True, impl=impl)
        kv = None
    h = h + apply_mlp(cfg, shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps))
    return h, kv


def _fwd(cfg: ModelConfig, params, embeds, remat: bool, collect_kv: bool = False,
         impl: Optional[str] = None):
    b, s = embeds.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, seg_layers):
        return _segment_fwd(cfg, params["shared"], x, seg_layers, positions,
                            collect_kv, impl)
    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, kvs = jax.lax.scan(body, embeds, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps), kvs


def hybrid_loss(cfg: ModelConfig, params, batch: Dict, remat: bool = True):
    embeds = embed_tokens(params["embed"], batch["tokens"])
    h, _ = _fwd(cfg, params, embeds, remat)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return softmax_cross_entropy(logits, batch["labels"])


def hybrid_prefill(cfg: ModelConfig, params, batch: Dict):
    embeds = embed_tokens(params["embed"], batch["tokens"])
    # collect mamba states AND attention kv per segment
    b, s = embeds.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, seg_layers):
        def mbody(x, lp):
            y, st = mamba.mamba2_forward(cfg, lp["mamba"],
                                         rms_norm(x, lp["ln"], cfg.norm_eps))
            return x + y, st
        x, sts = jax.lax.scan(mbody, x, seg_layers)
        xn = rms_norm(x, params["shared"]["ln1"], cfg.norm_eps)
        q, k, v = attn.qkv_project(cfg, params["shared"]["attn"], xn, positions)
        o = attn.multi_head_attention(q, k, v, causal=True)
        h = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                           params["shared"]["attn"]["wo"])
        h = h + apply_mlp(cfg, params["shared"]["mlp"],
                          rms_norm(h, params["shared"]["ln2"], cfg.norm_eps))
        return h, (sts, (k, v))

    x, (sts, kvs) = jax.lax.scan(body, embeds, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    cache = {"ssm": sts, "k": kvs[0], "v": kvs[1]}
    return cache, logits


def make_hybrid_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype):
    n_seg, per = _n_segments(cfg), cfg.hybrid.attn_every
    di = cfg.ssm.expand * cfg.d_model
    heads, hd_ssd = di // cfg.ssm.head_dim, cfg.ssm.head_dim
    hd = cfg.resolved_head_dim
    return {
        "ssm": {
            "h": jnp.zeros((n_seg, per, batch_size, heads, hd_ssd, cfg.ssm.d_state),
                           jnp.float32),
            "conv": jnp.zeros((n_seg, per, batch_size, cfg.ssm.d_conv - 1, di), dtype),
        },
        "k": jnp.zeros((n_seg, batch_size, max_len, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((n_seg, batch_size, max_len, cfg.n_kv_heads, hd), dtype),
    }


def hybrid_decode_step(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    cur_len = batch["cur_len"]
    x = embed_tokens(params["embed"], batch["token"])
    b = x.shape[0]
    positions = jnp.broadcast_to(cur_len[None, None], (b, 1)).astype(jnp.int32)
    shared = params["shared"]

    def body(x, xs):
        seg_layers, ssm_st, kc, vc = xs

        def mbody(x, ys):
            lp, st = ys
            y, st2 = mamba.mamba2_decode_step(
                cfg, lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), st)
            return x + y, st2
        x, ssm_st2 = jax.lax.scan(mbody, x, (seg_layers, ssm_st))
        xn = rms_norm(x, shared["ln1"], cfg.norm_eps)
        o, kc, vc = attn.attention_decode_block(cfg, shared["attn"], xn, kc, vc,
                                                cur_len, positions)
        h = x + o
        h = h + apply_mlp(cfg, shared["mlp"], rms_norm(h, shared["ln2"], cfg.norm_eps))
        return h, (ssm_st2, kc, vc)

    x, (ssm2, k2, v2) = jax.lax.scan(
        body, x, (params["layers"], cache["ssm"], cache["k"], cache["v"]))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return {"ssm": ssm2, "k": k2, "v": v2}, logits


# ---------------------------------------------------------------------------
# Paged serving: mixed layout — KV block pool for the shared-attention call
# sites, state slab for the Mamba2 backbone
# ---------------------------------------------------------------------------
# cache = {"k"/"v": (n_seg, num_blocks, block_size, KV, hd)  — block axis 1,
#          "ssm": {"h":   (n_seg, per, state_slots, H, P, N) f32,
#                  "conv": (n_seg, per, state_slots, K-1, di)} — slot axis 2}
# The two address spaces never mix: the block data plane (paged_block_*)
# touches only the k/v leaves, the slab data plane (state_slot_*) only the
# ssm leaves, so KVStore and StateSlab each manage their half of one shared
# pytree.  Block 0 / slot 0 are the null targets for padded rows.


def make_hybrid_paged_cache(cfg: ModelConfig, num_blocks: int,
                            block_size: int, state_slots: int, dtype):
    n_seg, per = _n_segments(cfg), cfg.hybrid.attn_every
    di = cfg.ssm.expand * cfg.d_model
    heads, hd_ssd = di // cfg.ssm.head_dim, cfg.ssm.head_dim
    hd = cfg.resolved_head_dim
    return {
        "ssm": {
            "h": jnp.zeros((n_seg, per, state_slots, heads, hd_ssd,
                            cfg.ssm.d_state), jnp.float32),
            "conv": jnp.zeros((n_seg, per, state_slots, cfg.ssm.d_conv - 1,
                               di), dtype),
        },
        "k": jnp.zeros((n_seg, num_blocks, block_size, cfg.n_kv_heads, hd),
                       dtype),
        "v": jnp.zeros((n_seg, num_blocks, block_size, cfg.n_kv_heads, hd),
                       dtype),
    }


# Block / slot indices are TRACED scalars (one jit per cache shape — the
# transformer._paged_copy_jit convention).
_block_copy_jit = jax.jit(lambda c, src, dst: {
    **c, "k": c["k"].at[:, dst].set(c["k"][:, src]),
    "v": c["v"].at[:, dst].set(c["v"][:, src])})
_block_read_jit = jax.jit(lambda c, idx: {"k": c["k"][:, idx],
                                          "v": c["v"][:, idx]})
_block_write_jit = jax.jit(lambda c, idx, data: {
    **c, "k": c["k"].at[:, idx].set(data["k"].astype(c["k"].dtype)),
    "v": c["v"].at[:, idx].set(data["v"].astype(c["v"].dtype))})
_slot_copy_jit = jax.jit(lambda c, src, dst: {
    **c, "ssm": jax.tree.map(lambda v: v.at[:, :, dst].set(v[:, :, src]),
                             c["ssm"])})
_slot_read_jit = jax.jit(lambda c, idx: jax.tree.map(
    lambda v: v[:, :, idx], c["ssm"]))
_slot_write_jit = jax.jit(lambda c, idx, data: {
    **c, "ssm": jax.tree.map(lambda v, d: v.at[:, :, idx].set(
        d.astype(v.dtype)), c["ssm"], data)})


def paged_block_copy(cache: Dict, src, dst) -> Dict:
    """CoW data plane for the attention half (k/v leaves only)."""
    return _block_copy_jit(cache, jnp.int32(src), jnp.int32(dst))


def paged_block_read(cache: Dict, idx) -> Dict:
    import numpy as np
    return {k: np.asarray(v)
            for k, v in _block_read_jit(cache, jnp.int32(idx)).items()}


def paged_block_write(cache: Dict, idx, data: Dict) -> Dict:
    return _block_write_jit(cache, jnp.int32(idx),
                            {k: jnp.asarray(v) for k, v in data.items()})


def state_slot_copy(cache: Dict, src, dst) -> Dict:
    """CoW / fork data plane for the scan half (ssm leaves only)."""
    return _slot_copy_jit(cache, jnp.int32(src), jnp.int32(dst))


def state_slot_read(cache: Dict, idx) -> Dict:
    import numpy as np
    return {k: np.asarray(v)
            for k, v in _slot_read_jit(cache, jnp.int32(idx)).items()}


def state_slot_write(cache: Dict, idx, data: Dict) -> Dict:
    return _slot_write_jit(cache, jnp.int32(idx),
                           {k: jnp.asarray(v) for k, v in data.items()})


def hybrid_prefill_chunk(cfg: ModelConfig, params, cache: Dict, batch: Dict,
                         m_used=None):
    """One prompt chunk for a single request: scan carry-state threads across
    chunk boundaries through the state slab while attention KV lands in the
    block table — the mixed layout in one pass.

    batch: {"tokens" (1,C), "block_table" (1,M), "state_slot" (),
    "start" (), "prompt_len" ()} — conventions as in
    ``transformer.lm_prefill_chunk`` plus the slab slot.  At ``start == 0``
    the slot's recycled state reads as zeros in-graph.
    """
    slot = batch["state_slot"].astype(jnp.int32)
    start = batch["start"].astype(jnp.int32)
    prompt_len = batch["prompt_len"].astype(jnp.int32)
    valid_len = prompt_len - start
    table = batch["block_table"].astype(jnp.int32)
    c = batch["tokens"].shape[1]
    chunk_pos = start + jnp.arange(c, dtype=jnp.int32)
    x = embed_tokens(params["embed"], batch["tokens"])
    st = jax.tree.map(lambda v: v[:, :, slot][:, :, None], cache["ssm"])
    st = jax.tree.map(lambda v: jnp.where(start > 0, v, 0), st)
    shared = params["shared"]

    def body(x, xs):
        seg_layers, ssm_st, kp, vp = xs

        def mbody(x, ys):
            lp, s = ys
            y, s2 = mamba.mamba2_chunk(
                cfg, lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), s,
                valid_len)
            return x + y, s2
        x, ssm2 = jax.lax.scan(mbody, x, (seg_layers, ssm_st))
        xn = rms_norm(x, shared["ln1"], cfg.norm_eps)
        o, kp, vp = attn.attention_prefill_chunk_block(
            cfg, shared["attn"], xn, kp, vp, table, chunk_pos, prompt_len,
            m_used=m_used)
        h = x + o
        h = h + apply_mlp(cfg, shared["mlp"],
                          rms_norm(h, shared["ln2"], cfg.norm_eps))
        return h, (ssm2, kp, vp)

    x, (ssm2, k2, v2) = jax.lax.scan(
        body, x, (params["layers"], st, cache["k"], cache["v"]))
    cache = {"k": k2, "v": v2,
             "ssm": jax.tree.map(
                 lambda v, s: v.at[:, :, slot].set(s[:, :, 0].astype(v.dtype)),
                 cache["ssm"], ssm2)}
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return cache, logits_from_hidden(cfg, params["embed"], h)


def hybrid_decode_step_paged(cfg: ModelConfig, params, cache: Dict,
                             batch: Dict):
    """One decode step over the mixed layout.

    batch: {"token" (B,1), "block_tables" (B,M), "seq_lens" (B,),
    "state_slots" (B,)}.  Every row sits at its own position (no shared
    ``cur_len``): attention uses per-row seq_lens against the block pool,
    the Mamba2 backbone gathers/scatters per-row slab slots.
    """
    tables = batch["block_tables"].astype(jnp.int32)
    seq_lens = batch["seq_lens"].astype(jnp.int32)
    slots = batch["state_slots"].astype(jnp.int32)
    x = embed_tokens(params["embed"], batch["token"])
    st = jax.tree.map(lambda v: v[:, :, slots], cache["ssm"])
    shared = params["shared"]

    def body(x, xs):
        seg_layers, ssm_st, kp, vp = xs

        def mbody(x, ys):
            lp, s = ys
            y, s2 = mamba.mamba2_decode_step(
                cfg, lp["mamba"], rms_norm(x, lp["ln"], cfg.norm_eps), s)
            return x + y, s2
        x, ssm2 = jax.lax.scan(mbody, x, (seg_layers, ssm_st))
        xn = rms_norm(x, shared["ln1"], cfg.norm_eps)
        o, kp, vp = attn.attention_decode_block_paged(
            cfg, shared["attn"], xn, kp, vp, tables, seq_lens)
        h = x + o
        h = h + apply_mlp(cfg, shared["mlp"],
                          rms_norm(h, shared["ln2"], cfg.norm_eps))
        return h, (ssm2, kp, vp)

    x, (ssm2, k2, v2) = jax.lax.scan(
        body, x, (params["layers"], st, cache["k"], cache["v"]))
    cache = {"k": k2, "v": v2,
             "ssm": jax.tree.map(lambda v, s: v.at[:, :, slots].set(
                 s.astype(v.dtype)), cache["ssm"], ssm2)}
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return cache, logits
