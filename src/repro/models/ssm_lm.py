"""falcon-mamba-style attention-free LM: a scan over Mamba1 blocks."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba
from repro.models.layers import (
    embed_tokens, init_embed, logits_from_hidden, rms_norm,
    softmax_cross_entropy,
)


def init_ssm_lm(cfg: ModelConfig, rng) -> Dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    r = jax.random.split(rng, cfg.n_layers + 1)
    layers = [
        {"ln": jnp.ones((cfg.d_model,), dtype),
         "mamba": mamba.init_mamba1(cfg, r[i + 1], dtype)}
        for i in range(cfg.n_layers)
    ]
    return {
        "embed": init_embed(cfg, r[0], dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def _fwd(cfg: ModelConfig, params, embeds: jax.Array, remat: bool):
    def body(x, lp):
        y, _ = mamba.mamba1_forward(cfg, lp["mamba"],
                                    rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + y, None
    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, _ = jax.lax.scan(body, embeds, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def ssm_lm_loss(cfg: ModelConfig, params, batch: Dict, remat: bool = True):
    embeds = embed_tokens(params["embed"], batch["tokens"])
    h = _fwd(cfg, params, embeds, remat)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return softmax_cross_entropy(logits, batch["labels"])


def ssm_lm_prefill(cfg: ModelConfig, params, batch: Dict) -> Tuple[Dict, jax.Array]:
    embeds = embed_tokens(params["embed"], batch["tokens"])

    def body(x, lp):
        y, st = mamba.mamba1_forward(cfg, lp["mamba"],
                                     rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + y, st
    x, states = jax.lax.scan(body, embeds, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    return states, logits  # states: {"h": (L,B,di,N), "conv": (L,B,K-1,di)}


def make_ssm_cache(cfg: ModelConfig, batch_size: int, dtype):
    di = cfg.ssm.expand * cfg.d_model
    return {
        "h": jnp.zeros((cfg.n_layers, batch_size, di, cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm.d_conv - 1, di), dtype),
    }


def ssm_lm_decode_step(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    x = embed_tokens(params["embed"], batch["token"])

    def body(x, xs):
        lp, st = xs
        y, st2 = mamba.mamba1_decode_step(cfg, lp["mamba"],
                                          rms_norm(x, lp["ln"], cfg.norm_eps), st)
        return x + y, st2
    x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return new_states, logits
