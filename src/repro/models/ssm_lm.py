"""falcon-mamba-style attention-free LM: a scan over Mamba1 blocks."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import mamba
from repro.models.layers import (
    embed_tokens, init_embed, logits_from_hidden, rms_norm,
    softmax_cross_entropy,
)


def init_ssm_lm(cfg: ModelConfig, rng) -> Dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    r = jax.random.split(rng, cfg.n_layers + 1)
    layers = [
        {"ln": jnp.ones((cfg.d_model,), dtype),
         "mamba": mamba.init_mamba1(cfg, r[i + 1], dtype)}
        for i in range(cfg.n_layers)
    ]
    return {
        "embed": init_embed(cfg, r[0], dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
    }


def _fwd(cfg: ModelConfig, params, embeds: jax.Array, remat: bool):
    def body(x, lp):
        y, _ = mamba.mamba1_forward(cfg, lp["mamba"],
                                    rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + y, None
    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, _ = jax.lax.scan(body, embeds, params["layers"])
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def ssm_lm_loss(cfg: ModelConfig, params, batch: Dict, remat: bool = True):
    embeds = embed_tokens(params["embed"], batch["tokens"])
    h = _fwd(cfg, params, embeds, remat)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return softmax_cross_entropy(logits, batch["labels"])


def ssm_lm_prefill(cfg: ModelConfig, params, batch: Dict) -> Tuple[Dict, jax.Array]:
    embeds = embed_tokens(params["embed"], batch["tokens"])

    def body(x, lp):
        y, st = mamba.mamba1_forward(cfg, lp["mamba"],
                                     rms_norm(x, lp["ln"], cfg.norm_eps))
        return x + y, st
    x, states = jax.lax.scan(body, embeds, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    return states, logits  # states: {"h": (L,B,di,N), "conv": (L,B,K-1,di)}


def make_ssm_cache(cfg: ModelConfig, batch_size: int, dtype):
    di = cfg.ssm.expand * cfg.d_model
    return {
        "h": jnp.zeros((cfg.n_layers, batch_size, di, cfg.ssm.d_state), jnp.float32),
        "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm.d_conv - 1, di), dtype),
    }


def ssm_lm_decode_step(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    x = embed_tokens(params["embed"], batch["token"])

    def body(x, xs):
        lp, st = xs
        y, st2 = mamba.mamba1_decode_step(cfg, lp["mamba"],
                                          rms_norm(x, lp["ln"], cfg.norm_eps), st)
        return x + y, st2
    x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return new_states, logits


# ---------------------------------------------------------------------------
# Paged serving: state-slab cache (slot axis instead of batch axis)
# ---------------------------------------------------------------------------
# The paged "cache" for an attention-free LM is the same pytree as the dense
# one with the batch axis widened to ``state_slots``: slot s holds request
# s's O(1) recurrent state.  Slot 0 is the null slot (padded decode rows).
# There are no KV pages at all — the engine's block pool stays empty.


def make_ssm_paged_cache(cfg: ModelConfig, state_slots: int, dtype):
    return make_ssm_cache(cfg, state_slots, dtype)


# Slot indices are TRACED scalars (one jit per cache shape, shared across all
# slots and engines — the same convention as transformer._paged_copy_jit).
_slot_copy_jit = jax.jit(lambda c, src, dst: jax.tree.map(
    lambda v: v.at[:, dst].set(v[:, src]), c))
_slot_read_jit = jax.jit(lambda c, idx: jax.tree.map(lambda v: v[:, idx], c))
_slot_write_jit = jax.jit(lambda c, idx, data: jax.tree.map(
    lambda v, d: v.at[:, idx].set(d.astype(v.dtype)), c, data))


def state_slot_copy(cache: Dict, src, dst) -> Dict:
    """Device-side copy of one request's recurrent state (all layers): the
    CoW / fork data plane for ``repro.serve.kv_store.StateSlab``."""
    return _slot_copy_jit(cache, jnp.int32(src), jnp.int32(dst))


def state_slot_read(cache: Dict, idx) -> Dict:
    """Slot ``idx`` -> host numpy (the device->host half of a state swap)."""
    import numpy as np
    return {k: np.asarray(v)
            for k, v in _slot_read_jit(cache, jnp.int32(idx)).items()}


def state_slot_write(cache: Dict, idx, data: Dict) -> Dict:
    """Host numpy state -> slot ``idx`` (the swap_in half)."""
    return _slot_write_jit(cache, jnp.int32(idx),
                           {k: jnp.asarray(v) for k, v in data.items()})


def ssm_lm_prefill_chunk(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    """Process one prompt chunk for a single request into its state slot.

    batch: {"tokens" (1,C) int32 (null-padded past the prompt),
    "state_slot" () int32, "start" () int32, "prompt_len" () int32 — the
    chunk's write limit, as in ``transformer.lm_prefill_chunk``}.  At
    ``start == 0`` the slot's (recycled, unzeroed) state is replaced by
    zeros in-graph, so slots never need a zeroing pass on alloc.  Returns
    (cache, logits (1,C,V)).
    """
    slot = batch["state_slot"].astype(jnp.int32)
    start = batch["start"].astype(jnp.int32)
    valid_len = batch["prompt_len"].astype(jnp.int32) - start
    x = embed_tokens(params["embed"], batch["tokens"])
    st = jax.tree.map(lambda v: v[:, slot][:, None], cache)   # (L,1,...)
    st = jax.tree.map(lambda v: jnp.where(start > 0, v, 0), st)

    def body(x, xs):
        lp, s = xs
        y, s2 = mamba.mamba1_chunk(cfg, lp["mamba"],
                                   rms_norm(x, lp["ln"], cfg.norm_eps), s,
                                   valid_len)
        return x + y, s2
    x, new_st = jax.lax.scan(body, x, (params["layers"], st))
    cache = jax.tree.map(
        lambda v, s: v.at[:, slot].set(s[:, 0].astype(v.dtype)), cache, new_st)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return cache, logits_from_hidden(cfg, params["embed"], h)


def ssm_lm_decode_step_paged(cfg: ModelConfig, params, cache: Dict,
                             batch: Dict):
    """One decode step over the state slab.

    batch: {"token" (B,1) int32, "state_slots" (B,) int32}.  Rows gather
    their slot's state, step the recurrence, and scatter back; padded rows
    use slot 0 (collisions there are harmless — the null slot is never an
    allocated request's state).
    """
    slots = batch["state_slots"].astype(jnp.int32)
    x = embed_tokens(params["embed"], batch["token"])
    st = jax.tree.map(lambda v: v[:, slots], cache)           # (L,B,...)

    def body(x, xs):
        lp, s = xs
        y, s2 = mamba.mamba1_decode_step(cfg, lp["mamba"],
                                         rms_norm(x, lp["ln"], cfg.norm_eps),
                                         s)
        return x + y, s2
    x, new_st = jax.lax.scan(body, x, (params["layers"], st))
    cache = jax.tree.map(
        lambda v, s: v.at[:, slots].set(s.astype(v.dtype)), cache, new_st)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return cache, logits
