"""Unified model dispatch: one ModelFns bundle per architecture family.

Everything downstream (trainer, server, dry-run, benchmarks) talks to models
exclusively through this interface.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec, jnp_dtype
from repro.models import encdec, hybrid, ssm_lm, transformer


@dataclasses.dataclass(frozen=True)
class ModelFns:
    init: Callable           # (rng) -> params
    loss: Callable           # (params, batch) -> scalar loss
    prefill: Callable        # (params, batch) -> (cache, logits)
    decode_step: Callable    # (params, cache, batch) -> (cache, logits)
    make_cache: Callable     # (batch_size, max_len) -> cache pytree
    input_specs: Callable    # (shape_spec) -> dict of ShapeDtypeStruct
    # Paged serving interface (block-table-aware).  Families with recurrent
    # state (ssm/hybrid) take a ``state_slots`` kwarg on make_paged_cache and
    # read "state_slot(s)" from the batch; attention families ignore both.
    make_paged_cache: Optional[Callable] = None  # (num_blocks, block_size[, state_slots=]) -> cache
    decode_paged: Optional[Callable] = None      # (params, cache, batch) -> (cache, logits)
    prefill_chunk: Optional[Callable] = None     # (params, cache, batch, m_used=) -> (cache, logits)
    # Tiered-KVStore data plane (repro.serve.kv_store): per-block device copy
    # (copy-on-write) and device<->host movement (swap tiers).  Layout-aware,
    # so each family owns its own implementation.  Works unchanged on a
    # mesh-sharded slab: jit + GSPMD partition the copy per shard, and
    # read/write gather / re-split the per-shard slices of one block.
    paged_block_copy: Optional[Callable] = None   # (cache, src, dst) -> cache
    paged_block_read: Optional[Callable] = None   # (cache, idx) -> host pytree
    paged_block_write: Optional[Callable] = None  # (cache, idx, data) -> cache
    # Recurrent-state slab data plane (repro.serve.kv_store.StateSlab): same
    # three operations at *slot* granularity over the same cache pytree.
    # Presence of state_slot_copy is how the engine detects a stateful family.
    state_slot_copy: Optional[Callable] = None    # (cache, src, dst) -> cache
    state_slot_read: Optional[Callable] = None    # (cache, idx) -> host pytree
    state_slot_write: Optional[Callable] = None   # (cache, idx, data) -> cache


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_model(cfg: ModelConfig) -> ModelFns:
    dtype = jnp_dtype(cfg)
    fam = cfg.family

    if fam in ("dense", "moe", "vlm"):
        def input_specs(spec: ShapeSpec):
            b, s = spec.global_batch, spec.seq_len
            if spec.kind == "train":
                if fam == "vlm":
                    return {"embeds": _sds((b, s, cfg.d_model), dtype),
                            "positions": _sds((3, b, s), jnp.int32),
                            "labels": _sds((b, s), jnp.int32)}
                return {"tokens": _sds((b, s), jnp.int32),
                        "labels": _sds((b, s), jnp.int32)}
            if spec.kind == "prefill":
                if fam == "vlm":
                    return {"embeds": _sds((b, s, cfg.d_model), dtype),
                            "positions": _sds((3, b, s), jnp.int32)}
                return {"tokens": _sds((b, s), jnp.int32)}
            # decode: one new token against a cache of capacity s
            if fam == "vlm":
                return {"embeds": _sds((b, 1, cfg.d_model), dtype),
                        "positions": _sds((3, b, 1), jnp.int32),
                        "cur_len": _sds((), jnp.int32)}
            return {"token": _sds((b, 1), jnp.int32),
                    "cur_len": _sds((), jnp.int32)}

        return ModelFns(
            init=lambda rng: transformer.init_lm(cfg, rng),
            loss=lambda p, b, **kw: transformer.lm_loss(cfg, p, b, **kw),
            prefill=lambda p, b: transformer.lm_prefill(cfg, p, b),
            decode_step=lambda p, c, b: transformer.lm_decode_step(cfg, p, c, b),
            make_cache=lambda bs, ml: transformer.make_decode_cache(cfg, bs, ml, dtype),
            input_specs=input_specs,
            make_paged_cache=lambda nb, bsz: transformer.make_paged_cache(cfg, nb, bsz, dtype),
            decode_paged=lambda p, c, b: transformer.lm_decode_step_paged(cfg, p, c, b),
            prefill_chunk=lambda p, c, b, m_used=None: transformer.lm_prefill_chunk(
                cfg, p, c, b, m_used=m_used),
            paged_block_copy=transformer.paged_block_copy,
            paged_block_read=transformer.paged_block_read,
            paged_block_write=transformer.paged_block_write,
        )

    if fam == "ssm":
        def input_specs(spec: ShapeSpec):
            b, s = spec.global_batch, spec.seq_len
            if spec.kind == "train":
                return {"tokens": _sds((b, s), jnp.int32),
                        "labels": _sds((b, s), jnp.int32)}
            if spec.kind == "prefill":
                return {"tokens": _sds((b, s), jnp.int32)}
            return {"token": _sds((b, 1), jnp.int32)}

        return ModelFns(
            init=lambda rng: ssm_lm.init_ssm_lm(cfg, rng),
            loss=lambda p, b, **kw: ssm_lm.ssm_lm_loss(cfg, p, b, **kw),
            prefill=lambda p, b: ssm_lm.ssm_lm_prefill(cfg, p, b),
            decode_step=lambda p, c, b: ssm_lm.ssm_lm_decode_step(cfg, p, c, b),
            make_cache=lambda bs, ml: ssm_lm.make_ssm_cache(cfg, bs, dtype),
            input_specs=input_specs,
            # attention-free: the "paged" cache is all slab, no KV pages —
            # the block data plane is a no-op (the engine never grows a table)
            make_paged_cache=lambda nb, bsz, state_slots=1:
                ssm_lm.make_ssm_paged_cache(cfg, state_slots, dtype),
            decode_paged=lambda p, c, b: ssm_lm.ssm_lm_decode_step_paged(
                cfg, p, c, b),
            prefill_chunk=lambda p, c, b, m_used=None:
                ssm_lm.ssm_lm_prefill_chunk(cfg, p, c, b),
            paged_block_copy=lambda c, src, dst: c,
            paged_block_read=lambda c, idx: {},
            paged_block_write=lambda c, idx, data: c,
            state_slot_copy=ssm_lm.state_slot_copy,
            state_slot_read=ssm_lm.state_slot_read,
            state_slot_write=ssm_lm.state_slot_write,
        )

    if fam == "hybrid":
        def input_specs(spec: ShapeSpec):
            b, s = spec.global_batch, spec.seq_len
            if spec.kind == "train":
                return {"tokens": _sds((b, s), jnp.int32),
                        "labels": _sds((b, s), jnp.int32)}
            if spec.kind == "prefill":
                return {"tokens": _sds((b, s), jnp.int32)}
            return {"token": _sds((b, 1), jnp.int32),
                    "cur_len": _sds((), jnp.int32)}

        return ModelFns(
            init=lambda rng: hybrid.init_hybrid(cfg, rng),
            loss=lambda p, b, **kw: hybrid.hybrid_loss(cfg, p, b, **kw),
            prefill=lambda p, b: hybrid.hybrid_prefill(cfg, p, b),
            decode_step=lambda p, c, b: hybrid.hybrid_decode_step(cfg, p, c, b),
            make_cache=lambda bs, ml: hybrid.make_hybrid_cache(cfg, bs, ml, dtype),
            input_specs=input_specs,
            # mixed layout: KV pages for the shared-attention call sites,
            # state slab for the Mamba2 backbone — one shared cache pytree
            make_paged_cache=lambda nb, bsz, state_slots=1:
                hybrid.make_hybrid_paged_cache(cfg, nb, bsz, state_slots,
                                               dtype),
            decode_paged=lambda p, c, b: hybrid.hybrid_decode_step_paged(
                cfg, p, c, b),
            prefill_chunk=lambda p, c, b, m_used=None:
                hybrid.hybrid_prefill_chunk(cfg, p, c, b, m_used=m_used),
            paged_block_copy=hybrid.paged_block_copy,
            paged_block_read=hybrid.paged_block_read,
            paged_block_write=hybrid.paged_block_write,
            state_slot_copy=hybrid.state_slot_copy,
            state_slot_read=hybrid.state_slot_read,
            state_slot_write=hybrid.state_slot_write,
        )

    if fam == "audio":
        def input_specs(spec: ShapeSpec):
            b, s = spec.global_batch, spec.seq_len
            if spec.kind == "train":
                return {"frames": _sds((b, s, cfg.d_model), dtype),
                        "tokens": _sds((b, s), jnp.int32),
                        "labels": _sds((b, s), jnp.int32)}
            if spec.kind == "prefill":
                return {"frames": _sds((b, s, cfg.d_model), dtype),
                        "tokens": _sds((b, s), jnp.int32)}
            return {"token": _sds((b, 1), jnp.int32),
                    "cur_len": _sds((), jnp.int32)}

        return ModelFns(
            init=lambda rng: encdec.init_encdec(cfg, rng),
            loss=lambda p, b, **kw: encdec.encdec_loss(cfg, p, b, **kw),
            prefill=lambda p, b: encdec.encdec_prefill(cfg, p, b),
            decode_step=lambda p, c, b: encdec.encdec_decode_step(cfg, p, c, b),
            make_cache=lambda bs, ml: encdec.make_encdec_cache(cfg, bs, ml, dtype),
            input_specs=input_specs,
        )

    raise ValueError(f"unknown family {fam!r}")


def abstract_params(cfg: ModelConfig):
    fns = build_model(cfg)
    return jax.eval_shape(lambda: fns.init(jax.random.PRNGKey(0)))


def abstract_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    fns = build_model(cfg)
    return jax.eval_shape(lambda: fns.make_cache(batch_size, max_len))
