"""Mamba1 (selective scan) and Mamba2 (SSD) blocks.

Both use a *hierarchical* scan: an outer ``lax.scan`` over sequence chunks
carrying the SSM state, and within each chunk either an associative scan
(mamba1) or the quadratic-intra + state-passing SSD form (mamba2).  The full
(B, S, d_inner, d_state) hidden-state tensor is therefore never materialized —
live memory is bounded by one chunk — which is what makes train_4k compile at
scale and is itself a §Perf design point (chunk size trades scan depth vs
chunk memory).

Decode is the O(1) single-step recurrence on carried (conv window, ssm state).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers import rms_norm, truncated_normal


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def _d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm.expand * cfg.d_model


# ===========================================================================
# Mamba1
# ===========================================================================

def init_mamba1(cfg: ModelConfig, rng, dtype):
    d, di, n, k = cfg.d_model, _d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    dtr = _dt_rank(cfg)
    r = jax.random.split(rng, 6)
    s = 1.0 / math.sqrt(d)
    return {
        "in_proj": truncated_normal(r[0], (d, 2 * di), s, dtype),
        "conv_w": truncated_normal(r[1], (k, di), 1.0 / math.sqrt(k), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": truncated_normal(r[2], (di, dtr + 2 * n), 1.0 / math.sqrt(di), dtype),
        "dt_proj": truncated_normal(r[3], (dtr, di), 1.0 / math.sqrt(dtr), dtype),
        "dt_bias": jnp.full((di,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, n)) + 0.0),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": truncated_normal(r[4], (di, d), 1.0 / math.sqrt(di), dtype),
    }


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (K,C) -> (B,S,C)."""
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for j in range(k):  # K is 4: unrolled taps beat conv_general on TPU here
        out = out + pad[:, j:j + x.shape[1], :] * w[j][None, None, :]
    return out + b[None, None, :]


def _chunked_selective_scan(a: jax.Array, b: jax.Array, c: jax.Array,
                            h0: jax.Array, chunk: int):
    """a,b (B,S,di,N) f32, c (B,S,N) f32, h0 (B,di,N) -> (y (B,S,di), h_last).

    Outer scan over S//chunk chunks; associative scan inside each chunk.
    """
    B, S, di, N = a.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # identity steps: a=1, b=0 leave the state untouched
        a = jnp.concatenate([a, jnp.ones((B, pad, di, N), a.dtype)], axis=1)
        b = jnp.concatenate([b, jnp.zeros((B, pad, di, N), b.dtype)], axis=1)
        c = jnp.concatenate([c, jnp.zeros((B, pad, N), c.dtype)], axis=1)
    S_pad = S + pad
    nc = S_pad // chunk
    a = a.reshape(B, nc, chunk, di, N).transpose(1, 0, 2, 3, 4)
    b = b.reshape(B, nc, chunk, di, N).transpose(1, 0, 2, 3, 4)
    c = c.reshape(B, nc, chunk, N).transpose(1, 0, 2, 3)
    del S_pad

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a2 * a1, a2 * b1 + b2

    def step(h, xs):
        ac, bc, cc = xs  # (B,chunk,di,N), (B,chunk,N)
        cum_a, loc_h = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_t = cum_a * h[:, None] + loc_h                    # (B,chunk,di,N)
        y = jnp.einsum("btdn,btn->btd", h_t, cc)
        return h_t[:, -1], y

    h_last, ys = jax.lax.scan(step, h0, (a, b, c))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S + pad, di)[:, :S]
    return y, h_last


def mamba1_forward(cfg: ModelConfig, p, x: jax.Array,
                   h0: jax.Array = None) -> Tuple[jax.Array, Dict]:
    """x (B,S,d) -> (y (B,S,d), state {"h", "conv"})."""
    B, S, d = x.shape
    di, n = _d_inner(cfg), cfg.ssm.d_state
    dtr = _dt_rank(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = constrain(xs, "batch", None, "dinner")
    xs = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("bsi,ie->bse", xs, p["x_proj"])
    dt_r, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])                                        # (B,S,di) f32
    A = -jnp.exp(p["A_log"])                                    # (di,N) f32
    a = jnp.exp(dt[..., None] * A[None, None])                  # (B,S,di,N)
    a = constrain(a, "batch", None, "dinner", None)
    b = (dt[..., None] * b_ssm.astype(jnp.float32)[:, :, None, :]
         * xs.astype(jnp.float32)[..., None])
    b = constrain(b, "batch", None, "dinner", None)
    if h0 is None:
        h0 = jnp.zeros((B, di, n), jnp.float32)
    y, h_last = _chunked_selective_scan(a, b, c_ssm.astype(jnp.float32),
                                        h0, cfg.ssm.chunk)
    y = (y + p["D"][None, None] * xs.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    state = {"h": h_last, "conv": _tail_window(xz[..., :di], cfg.ssm.d_conv - 1)}
    return out, state


def _tail_window(x_pre: jax.Array, w: int) -> jax.Array:
    """Last `w` pre-activation conv inputs (left-pad with zeros if S < w)."""
    s = x_pre.shape[1]
    if s >= w:
        return x_pre[:, -w:, :]
    pad = jnp.zeros((x_pre.shape[0], w - s, x_pre.shape[2]), x_pre.dtype)
    return jnp.concatenate([pad, x_pre], axis=1)


def mamba1_decode_step(cfg: ModelConfig, p, x: jax.Array, state: Dict):
    """x (B,1,d); state {"h" (B,di,N) f32, "conv" (B,K-1,di)}."""
    B = x.shape[0]
    di, n, k = _d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    dtr = _dt_rank(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)                          # (B,1,di)
    window = jnp.concatenate([state["conv"], xs], axis=1)       # (B,K,di)
    conv = jnp.einsum("bki,ki->bi", window, p["conv_w"]) + p["conv_b"]
    xs1 = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)  # (B,di)
    proj = jnp.einsum("bi,ie->be", xs1, p["x_proj"])
    dt_r, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("br,ri->bi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])                                        # (B,di)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A[None])                        # (B,di,N)
    bterm = (dt[..., None] * b_ssm.astype(jnp.float32)[:, None, :]
             * xs1.astype(jnp.float32)[..., None])
    h = a * state["h"] + bterm
    y = jnp.einsum("bin,bn->bi", h, c_ssm.astype(jnp.float32))
    y = (y + p["D"][None] * xs1.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z[:, 0].astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    new_state = {"h": h, "conv": window[:, 1:, :]}
    return out, new_state


def _conv_with_carry(xs: jax.Array, carry: jax.Array, w: jax.Array,
                     b: jax.Array):
    """Depthwise causal conv of one chunk continuing a longer sequence.

    ``carry`` (B,K-1,C) holds the last K-1 *pre-activation* conv inputs of
    the previous chunk (zeros on the first chunk — identical to the zero
    left-pad the from-scratch conv applies).  Returns the chunk's conv
    outputs and the extended pre-activation sequence (the caller slices its
    next carry window out of it).
    """
    k = w.shape[0]
    ext = jnp.concatenate([carry, xs], axis=1)        # (B, K-1+C, C)
    return causal_conv1d(ext, w, b)[:, k - 1:], ext


def _next_conv_carry(ext: jax.Array, valid_len, k: int) -> jax.Array:
    """The carry window after a chunk whose first ``valid_len`` positions are
    real: extended index ``valid_len + K-2`` is chunk position
    ``valid_len - 1`` (the last real token), so the K-1 entries ending there
    start at ``valid_len`` — always in bounds, and degenerating to the old
    carry when ``valid_len`` is 0."""
    b, _, c = ext.shape
    return jax.lax.dynamic_slice(
        ext, (0, jnp.asarray(valid_len, jnp.int32), 0), (b, k - 1, c))


def mamba1_chunk(cfg: ModelConfig, p, x: jax.Array, state: Dict, valid_len):
    """One prompt chunk continuing from carried state (chunked prefill).

    x (B,C,d); state as in ``mamba1_decode_step``; ``valid_len`` () int32 —
    chunk positions >= it are padding, masked to identity scan steps
    (dt -> 0 gives a=exp(0)=1, b=0: exactly the pad convention of
    ``_chunked_selective_scan``), so ``h_last`` is the state after the last
    *real* token and padded outputs are garbage the engine discards.
    Bit-identical to one ``mamba1_forward`` over the concatenated chunks
    whenever chunk boundaries fall on multiples of ``cfg.ssm.chunk`` (the
    scan tree then combines the same groups in the same order).
    """
    B, C, _ = x.shape
    di, n, k = _d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    dtr = _dt_rank(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    xs, z = jnp.split(xz, 2, axis=-1)
    conv, ext = _conv_with_carry(xs, state["conv"], p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    proj = jnp.einsum("bsi,ie->bse", xs, p["x_proj"])
    dt_r, b_ssm, c_ssm = jnp.split(proj, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(
        jnp.einsum("bsr,ri->bsi", dt_r, p["dt_proj"]).astype(jnp.float32)
        + p["dt_bias"])                                        # (B,C,di) f32
    pos = jnp.arange(C, dtype=jnp.int32)
    dt = jnp.where((pos < valid_len)[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt[..., None] * A[None, None])
    b = (dt[..., None] * b_ssm.astype(jnp.float32)[:, :, None, :]
         * xs.astype(jnp.float32)[..., None])
    y, h_last = _chunked_selective_scan(a, b, c_ssm.astype(jnp.float32),
                                        state["h"], cfg.ssm.chunk)
    y = (y + p["D"][None, None] * xs.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h_last, "conv": _next_conv_carry(ext, valid_len, k)}


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

def _ssd_heads(cfg: ModelConfig) -> int:
    return _d_inner(cfg) // cfg.ssm.head_dim


def init_mamba2(cfg: ModelConfig, rng, dtype):
    d, di, n = cfg.d_model, _d_inner(cfg), cfg.ssm.d_state
    h = _ssd_heads(cfg)
    k = cfg.ssm.d_conv
    r = jax.random.split(rng, 4)
    s = 1.0 / math.sqrt(d)
    # Projections are split into a TP-shardable [z(di), x(di)] matrix and a
    # small replicated [B(n), C(n), dt(h)] matrix so the "model" axis shards
    # cleanly (stream boundaries align with shard boundaries).
    return {
        "in_proj_zx": truncated_normal(r[0], (d, 2 * di), s, dtype),
        "in_proj_bcdt": truncated_normal(r[3], (d, 2 * n + h), s, dtype),
        "conv_w": truncated_normal(r[1], (k, di), 1.0 / math.sqrt(k), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "out_proj": truncated_normal(r[2], (di, d), 1.0 / math.sqrt(di), dtype),
    }


def _segsum(la: jax.Array) -> jax.Array:
    """la (..., cs): log-decay per step -> L (..., cs, cs) with
    L[i,j] = sum_{j<k<=i} la_k for i>=j, -inf otherwise."""
    cs = la.shape[-1]
    cum = jnp.cumsum(la, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, chunk: int, h0: jax.Array = None):
    """Chunked SSD (mamba2).  x (B,S,H,P), dt (B,S,H) f32 (post-softplus),
    A (H,) f32 negative, B/C (B,S,N).  Returns (y (B,S,H,P), h_last (B,H,P,N)).
    """
    Bsz, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # dt=0 pad steps: decay exp(0)=1 and zero input leave state untouched
        x = jnp.concatenate([x, jnp.zeros((Bsz, pad, H, P), x.dtype)], axis=1)
        dt = jnp.concatenate([dt, jnp.zeros((Bsz, pad, H), dt.dtype)], axis=1)
        B = jnp.concatenate([B, jnp.zeros((Bsz, pad, N), B.dtype)], axis=1)
        C = jnp.concatenate([C, jnp.zeros((Bsz, pad, N), C.dtype)], axis=1)
    S_pad = S + pad
    nc = S_pad // chunk
    xf = x.astype(jnp.float32).reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = B.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    Cc = C.astype(jnp.float32).reshape(Bsz, nc, chunk, N)
    la = dtc * A[None, None, None, :]                    # (B,nc,cs,H) log-decay
    la_h = la.transpose(0, 1, 3, 2)                       # (B,nc,H,cs)
    Lmat = jnp.exp(_segsum(la_h))                         # (B,nc,H,cs,cs)

    # intra-chunk (quadratic within chunk)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (B,nc,cs,cs)
    w = scores[:, :, None] * Lmat                         # (B,nc,H,cs,cs)
    xw = xf * dtc[..., None]                              # dt-weighted inputs
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xw)

    # chunk states: S_c = sum_j exp(la_last - cum_j) dt_j B_j x_j
    cum = jnp.cumsum(la_h, axis=-1)                       # (B,nc,H,cs)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)           # (B,nc,H,cs)
    sc = jnp.einsum("bchj,bcjn,bcjhp->bchpn", decay_to_end, Bc, xw)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(cum[..., -1])                   # (B,nc,H)
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, xs):
        s_c, dec = xs                                     # (B,H,P,N), (B,H)
        h_next = dec[..., None, None] * h + s_c
        return h_next, h                                  # emit state *before* chunk

    scs = sc.transpose(1, 0, 2, 3, 4)
    decs = chunk_decay.transpose(1, 0, 2)
    h_last, h_in = jax.lax.scan(step, h0, (scs, decs))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                  # (B,nc,H,P,N)

    # inter-chunk contribution: y[i] = (C_i . h_in) * exp(cum_i)
    decay_in = jnp.exp(cum)                               # (B,nc,H,cs)
    y_inter = jnp.einsum("bcin,bchpn,bchi->bcihp", Cc, h_in, decay_in)

    y = (y_intra + y_inter).reshape(Bsz, S_pad, H, P)[:, :S]
    return y, h_last


def mamba2_forward(cfg: ModelConfig, p, x: jax.Array, h0=None):
    B, S, d = x.shape
    di, n = _d_inner(cfg), cfg.ssm.d_state
    H, P = _ssd_heads(cfg), cfg.ssm.head_dim
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj_zx"])
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
    z, xs = jnp.split(zx, 2, axis=-1)
    xs = constrain(xs, "batch", None, "dinner")
    b_ssm, c_ssm, dt = jnp.split(bcdt, [n, 2 * n], axis=-1)
    xs = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                       # (H,)
    xh = constrain(xs.reshape(B, S, H, P), "batch", None, "heads", None)
    y, h_last = ssd_forward(xh, dt, A, b_ssm, c_ssm, cfg.ssm.chunk, h0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h_last, "conv": _tail_window(zx[..., di:],
                                                   cfg.ssm.d_conv - 1)}


def mamba2_decode_step(cfg: ModelConfig, p, x: jax.Array, state: Dict):
    """x (B,1,d); state {"h" (B,H,P,N), "conv" (B,K-1,di)}."""
    B = x.shape[0]
    di, n = _d_inner(cfg), cfg.ssm.d_state
    H, P = _ssd_heads(cfg), cfg.ssm.head_dim
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj_zx"])[:, 0]
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])[:, 0]
    z, xs = jnp.split(zx, 2, axis=-1)
    b_ssm, c_ssm, dt = jnp.split(bcdt, [n, 2 * n], axis=-1)
    window = jnp.concatenate([state["conv"], xs[:, None, :]], axis=1)
    conv = jnp.einsum("bki,ki->bi", window, p["conv_w"]) + p["conv_b"]
    xs1 = jax.nn.silu(conv.astype(jnp.float32))                    # (B,di) f32
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,H)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A[None])                                      # (B,H)
    xh = xs1.reshape(B, H, P)
    binc = jnp.einsum("bh,bn,bhp->bhpn", dt, b_ssm.astype(jnp.float32), xh)
    h = a[..., None, None] * state["h"] + binc
    y = jnp.einsum("bhpn,bn->bhp", h, c_ssm.astype(jnp.float32))
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bi,id->bd", y, p["out_proj"])[:, None, :]
    return out, {"h": h, "conv": window[:, 1:, :]}


def mamba2_chunk(cfg: ModelConfig, p, x: jax.Array, state: Dict, valid_len):
    """One prompt chunk continuing from carried state (chunked prefill).

    x (B,C,d); state as in ``mamba2_decode_step``; ``valid_len`` () int32 —
    padded tail positions are masked via dt -> 0 (SSD's own pad convention:
    decay exp(0)=1 and zero dt-weighted input leave the state untouched), so
    ``h_last`` is the state after the last real token.  The conv carry is
    the last K-1 *pre-activation* inputs (``zx[..., di:]`` — note mamba2
    splits z first), mirroring ``mamba2_forward``'s ``_tail_window``.
    """
    B, C, _ = x.shape
    di, n, k = _d_inner(cfg), cfg.ssm.d_state, cfg.ssm.d_conv
    H, P = _ssd_heads(cfg), cfg.ssm.head_dim
    zx = jnp.einsum("bsd,de->bse", x, p["in_proj_zx"])
    bcdt = jnp.einsum("bsd,de->bse", x, p["in_proj_bcdt"])
    z, xs = jnp.split(zx, 2, axis=-1)
    b_ssm, c_ssm, dt = jnp.split(bcdt, [n, 2 * n], axis=-1)
    conv, ext = _conv_with_carry(xs, state["conv"], p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])    # (B,C,H)
    pos = jnp.arange(C, dtype=jnp.int32)
    dt = jnp.where((pos < valid_len)[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, C, H, P)
    y, h_last = ssd_forward(xh, dt, A, b_ssm, c_ssm, cfg.ssm.chunk,
                            state["h"])
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, C, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype),
                 p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"h": h_last, "conv": _next_conv_carry(ext, valid_len, k)}
