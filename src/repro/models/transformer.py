"""Decoder-only transformer covering the dense, moe, and vlm families.

Layers are *scanned* (weights stacked on a leading axis) so the lowered HLO is
depth-independent — essential for compiling 80-layer models in the multi-pod
dry-run.  MoE-every-2 archs scan over "super-layers" of (dense layer, MoE
layer) so the scan body stays homogeneous.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import lora as lora_mod
from repro.models import moe as moe_lib
from repro.models.layers import (
    apply_mlp, embed_tokens, init_embed, init_mlp, logits_from_hidden,
    rms_norm, softmax_cross_entropy,
)


def _layer_kind(cfg: ModelConfig, layer_idx_in_super: int) -> str:
    if cfg.moe is None:
        return "dense"
    if cfg.moe.every == 1:
        return "moe"
    # every=k: last layer of the super-layer is MoE, the rest dense
    return "moe" if layer_idx_in_super == cfg.moe.every - 1 else "dense"


def init_layer(cfg: ModelConfig, rng, kind: str, dtype):
    r = jax.random.split(rng, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.init_attention(cfg, r[0], dtype),
    }
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(cfg, r[1], dtype)
    else:
        d_ff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        p["mlp"] = init_mlp(cfg, r[1], d_ff, dtype)
    return p


def init_lm(cfg: ModelConfig, rng) -> Dict:
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    every = cfg.moe.every if cfg.moe else 1
    n_super = cfg.n_layers // every
    r = jax.random.split(rng, 2 + n_super * every)

    def stack_layers(kind_idx):
        keys = [r[2 + i * every + kind_idx] for i in range(n_super)]
        kind = _layer_kind(cfg, kind_idx)
        return jax.tree.map(lambda *xs: jnp.stack(xs),
                            *[init_layer(cfg, k, kind, dtype) for k in keys])

    params = {
        "embed": init_embed(cfg, r[0], dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": tuple(stack_layers(j) for j in range(every)),
    }
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _ffn(cfg: ModelConfig, lp, h: jax.Array, decode: bool,
         lora: Optional[dict] = None) -> Tuple[jax.Array, jax.Array]:
    if "moe" in lp:
        # MoE experts are per-token routed; per-tenant deltas there would
        # need per-(token, expert) gathers — MoE archs get attention-only
        # LoRA (adapters.adapted_projections omits the MLP for them)
        if decode:
            from repro.perf import perf
            if perf().moe_decode == "dispatch":
                return moe_lib.apply_moe_decode_dispatch(cfg, lp["moe"], h), \
                    jnp.float32(0)
            return moe_lib.apply_moe_decode(cfg, lp["moe"], h), jnp.float32(0)
        return moe_lib.apply_moe(cfg, lp["moe"], h)
    return apply_mlp(cfg, lp["mlp"], h, lora=lora), jnp.float32(0)


def _layer_fwd(cfg: ModelConfig, lp, x: jax.Array, positions: jax.Array,
               impl: Optional[str]) -> Tuple[jax.Array, jax.Array]:
    from repro.perf import perf
    seq_axis = "seq_mp" if perf().seq_parallel else None
    h = x + attn.attention_block(cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                                 positions, causal=True, impl=impl)
    h = constrain(h, "batch", seq_axis, None)
    y, aux = _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps), decode=False)
    return constrain(h + y, "batch", seq_axis, None), aux


def forward_hidden(cfg: ModelConfig, params, embeds: jax.Array,
                   positions: jax.Array, remat: bool = False,
                   impl: Optional[str] = None) -> Tuple[jax.Array, jax.Array]:
    """embeds (B,S,d) -> (hidden (B,S,d), moe_aux scalar)."""
    sub_stacks = params["layers"]

    def body(x, lps):
        aux_total = jnp.float32(0)
        for lp in lps:
            x, aux = _layer_fwd(cfg, lp, x, positions, impl)
            aux_total = aux_total + aux
        return x, aux_total

    if remat:
        from repro.perf import remat_policy_fn
        body = jax.checkpoint(body, policy=remat_policy_fn())
    x, auxs = jax.lax.scan(body, embeds, sub_stacks)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), jnp.sum(auxs)


def lm_loss(cfg: ModelConfig, params, batch: Dict, remat: bool = True) -> jax.Array:
    if "embeds" in batch:  # vlm stub frontend
        embeds, positions = batch["embeds"], batch["positions"]
    else:
        embeds = embed_tokens(params["embed"], batch["tokens"])
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    h, aux = forward_hidden(cfg, params, embeds, positions, remat=remat)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return softmax_cross_entropy(logits, batch["labels"]) + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with stacked KV caches
# ---------------------------------------------------------------------------

def _collect_kv_layer(cfg, lp, x, positions, impl):
    """Layer fwd that also returns this layer's (k, v) for the cache."""
    xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(cfg, lp["attn"], xn, positions)
    o = attn.multi_head_attention(q, k, v, causal=True, impl=impl)
    b, s = x.shape[:2]
    from repro.distributed.sharding import weight_use
    h = x + jnp.einsum("bse,ed->bsd", o.reshape(b, s, cfg.q_dim),
                       weight_use(lp["attn"]["wo"], "heads", None))
    y, _ = _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps), decode=False)
    return constrain(h + y, "batch", None, None), (k, v)


def lm_prefill(cfg: ModelConfig, params, batch: Dict,
               impl: Optional[str] = None) -> Tuple[Dict, jax.Array]:
    """Returns (cache, last-position logits (B,V)). Cache capacity == S."""
    if "embeds" in batch:
        embeds, positions = batch["embeds"], batch["positions"]
    else:
        b, s = batch["tokens"].shape
        embeds = embed_tokens(params["embed"], batch["tokens"])
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def body(x, lps):
        kvs = []
        for lp in lps:
            x, kv = _collect_kv_layer(cfg, lp, x, positions, impl)
            kvs.append(kv)
        return x, tuple(kvs)

    x, kvs = jax.lax.scan(body, embeds, params["layers"])
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h[:, -1:, :])[:, 0, :]
    # Cache is stored SLOT-MAJOR: [slot0 layers..., slot1 layers...]; decode
    # slices it the same way, so ordering is consistent end-to-end.
    ks = jnp.concatenate([kv[0] for kv in kvs], axis=0) if len(kvs) > 1 else kvs[0][0]
    vs = jnp.concatenate([kv[1] for kv in kvs], axis=0) if len(kvs) > 1 else kvs[0][1]
    cache = {"k": ks, "v": vs}
    return cache, logits


def make_decode_cache(cfg: ModelConfig, batch_size: int, max_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def make_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int, dtype):
    """Block-pool KV cache shared by all in-flight requests: live memory
    scales with tokens actually written, not max_batch x max_len.  Block 0 is
    the null block (see repro.serve.paged_cache)."""
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, num_blocks, block_size, cfg.n_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def lm_decode_step(cfg: ModelConfig, params, cache: Dict, batch: Dict,
                   impl: Optional[str] = None) -> Tuple[Dict, jax.Array]:
    """One decode step.  batch: {"token" (B,1) | "embeds" (B,1,d), "cur_len" ()}.

    cache: {"k": (L,B,Smax,KV,hd), "v": ...}; the new token's K/V are written
    at cur_len; logits for the new token are returned.
    """
    cur_len = batch["cur_len"]
    if "embeds" in batch:
        x, positions = batch["embeds"], batch["positions"]
    else:
        x = embed_tokens(params["embed"], batch["token"])
        b = batch["token"].shape[0]
        positions = jnp.broadcast_to(cur_len[None, None], (b, 1)).astype(jnp.int32)

    def body(x, xs):
        lps, kcs, vcs = xs
        new_kc, new_vc = [], []
        for i, lp in enumerate(lps):
            kc, vc = kcs[i], vcs[i]
            xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, kc, vc = attn.attention_decode_block(cfg, lp["attn"], xn, kc, vc,
                                                    cur_len, positions)
            h = x + o
            y, _ = _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps), decode=True)
            x = h + y
            new_kc.append(kc)
            new_vc.append(vc)
        return x, (tuple(new_kc), tuple(new_vc))

    every, k_slots, v_slots = _slot_major_split(cfg, cache)
    x, (new_k, new_v) = jax.lax.scan(body, x, (params["layers"], k_slots, v_slots))
    cache = _slot_major_merge(new_k, new_v, every)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return cache, logits


def _slot_major_split(cfg: ModelConfig, cache: Dict):
    """Slice a cache's leading (L, ...) slabs into per-super-layer stacks —
    the slot-major convention lm_prefill established, shared by the dense and
    paged cache layouts."""
    every = cfg.moe.every if cfg.moe else 1
    n_super = cfg.n_layers // every
    k_slots = tuple(cache["k"][i * n_super:(i + 1) * n_super] for i in range(every))
    v_slots = tuple(cache["v"][i * n_super:(i + 1) * n_super] for i in range(every))
    return every, k_slots, v_slots


def _slot_major_merge(new_k, new_v, every: int) -> Dict:
    return {"k": jnp.concatenate(new_k, axis=0) if every > 1 else new_k[0],
            "v": jnp.concatenate(new_v, axis=0) if every > 1 else new_v[0]}


# ---------------------------------------------------------------------------
# Paged serving: block-table-aware chunked prefill + decode
# ---------------------------------------------------------------------------


# Block indices are TRACED scalars: baking them in as constants would
# recompile the scatter for every distinct (src, dst) pair — one jit per
# cache shape instead, shared across all blocks and all engines.  On a
# mesh-sharded slab the same jits compile a second, partitioned executable
# (jit caches per input sharding): the copy runs shard-local, the read
# gathers one block's head-slices to host, the write re-splits them —
# DeviceTier._pin re-asserts the slab sharding after each update.
_paged_copy_jit = jax.jit(
    lambda c, src, dst: {k: v.at[:, dst].set(v[:, src]) for k, v in c.items()})
_paged_read_jit = jax.jit(lambda c, idx: {k: v[:, idx] for k, v in c.items()})
_paged_write_jit = jax.jit(
    lambda c, idx, data: {k: v.at[:, idx].set(data[k].astype(v.dtype))
                          for k, v in c.items()})


def paged_block_copy(cache: Dict, src, dst) -> Dict:
    """Device-side copy of one KV block (all layers): the copy-on-write data
    plane for ``repro.serve.kv_store`` — a shared block is duplicated on
    device before a holder writes into it, so sharers never see each other's
    tokens."""
    return _paged_copy_jit(cache, jnp.int32(src), jnp.int32(dst))


def paged_block_read(cache: Dict, idx) -> Dict:
    """Block ``idx`` -> host numpy {(k|v): (L, bs, KV, hd)} — the device->host
    half of a swap_out (bf16 round-trips bit-exactly through ml_dtypes)."""
    import numpy as np
    return {k: np.asarray(v)
            for k, v in _paged_read_jit(cache, jnp.int32(idx)).items()}


def paged_block_write(cache: Dict, idx, data: Dict) -> Dict:
    """Host numpy block -> device block ``idx`` (the swap_in half)."""
    return _paged_write_jit(cache, jnp.int32(idx),
                            {k: jnp.asarray(v) for k, v in data.items()})


def lm_decode_step_paged(cfg: ModelConfig, params, cache: Dict, batch: Dict):
    """One decode step over a paged cache.

    batch: {"token" (B,1) int32, "block_tables" (B,M) int32,
    "seq_lens" (B,) int32}.  Every row sits at its own position — no shared
    ``cur_len`` — which is what makes continuous batching (rows at wildly
    different depths) exact instead of aligned-and-masked.
    """
    seq_lens = batch["seq_lens"].astype(jnp.int32)
    tables = batch["block_tables"].astype(jnp.int32)
    x = embed_tokens(params["embed"], batch["token"])
    every, k_slots, v_slots = _slot_major_split(cfg, cache)
    # multi-LoRA: per-row adapter slot ids + stacked slabs ride the batch
    # only when the engine has adapters loaded — absent, not even a zero-add
    # is traced (the adapter_id=None bitwise-identity contract)
    lora = batch.get("lora")
    lora_ids = None if lora is None else lora["ids"].astype(jnp.int32)
    lora_slots = lora_mod.split_layers(lora, every)

    def body(x, xs):
        lps, kcs, vcs, lsl = xs if lora is not None else (*xs, None)
        new_kc, new_vc = [], []
        for i, lp in enumerate(lps):
            kc, vc = kcs[i], vcs[i]
            ll = None if lsl is None else {"ids": lora_ids,
                                           "slabs": lsl[i]}
            xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, kc, vc = attn.attention_decode_block_paged(
                cfg, lp["attn"], xn, kc, vc, tables, seq_lens, lora=ll)
            h = x + o
            y, _ = _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps),
                        decode=True, lora=ll)
            x = h + y
            new_kc.append(kc)
            new_vc.append(vc)
        return x, (tuple(new_kc), tuple(new_vc))

    xs = (params["layers"], k_slots, v_slots)
    if lora is not None:
        xs = xs + (lora_slots,)
    x, (new_k, new_v) = jax.lax.scan(body, x, xs)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0, :]
    return _slot_major_merge(new_k, new_v, every), logits


def lm_prefill_chunk(cfg: ModelConfig, params, cache: Dict, batch: Dict,
                     m_used: Optional[int] = None):
    """Process one prompt chunk for a single request into the paged cache.

    batch: {"tokens" (1,C) int32 (null-padded past the prompt),
    "block_table" (1,M) int32, "start" () int32 — absolute position of the
    chunk's first token, "prompt_len" () int32 — the chunk's write limit:
    positions >= it are padding whose KV goes to the null block (the engine
    passes the chunk's end, which on the final chunk is the true prompt
    length)}.  Returns (cache, logits (1,C,V)) — the engine reads the logit
    row of the prompt's last token from the final chunk.

    ``m_used`` (static int) restricts attention to the table's first blocks
    — the engine passes ceil((start+C)/block_size), so early chunks don't
    gather/stream the request's full table capacity.  One retrace per
    distinct value, bounded by max_blocks_per_seq.

    Note for MoE archs: expert capacity is computed per forward call, so a
    chunked prefill can route/drop tokens slightly differently than one full
    prefill of the same prompt.  Dense archs are bit-identical to lm_prefill.
    """
    start = batch["start"].astype(jnp.int32)
    prompt_len = batch["prompt_len"].astype(jnp.int32)
    table = batch["block_table"].astype(jnp.int32)
    c = batch["tokens"].shape[1]
    chunk_pos = start + jnp.arange(c, dtype=jnp.int32)
    x = embed_tokens(params["embed"], batch["tokens"])
    every, k_slots, v_slots = _slot_major_split(cfg, cache)
    # prefill runs one request per call: "lora" carries a single-element ids
    # row broadcast over the chunk (see lm_decode_step_paged for the shape)
    lora = batch.get("lora")
    lora_ids = None if lora is None else lora["ids"].astype(jnp.int32)
    lora_slots = lora_mod.split_layers(lora, every)

    def body(x, xs):
        lps, kcs, vcs, lsl = xs if lora is not None else (*xs, None)
        new_kc, new_vc = [], []
        for i, lp in enumerate(lps):
            kc, vc = kcs[i], vcs[i]
            ll = None if lsl is None else {"ids": lora_ids,
                                           "slabs": lsl[i]}
            xn = rms_norm(x, lp["ln1"], cfg.norm_eps)
            o, kc, vc = attn.attention_prefill_chunk_block(
                cfg, lp["attn"], xn, kc, vc, table, chunk_pos, prompt_len,
                m_used=m_used, lora=ll)
            h = x + o
            y, _ = _ffn(cfg, lp, rms_norm(h, lp["ln2"], cfg.norm_eps),
                        decode=False, lora=ll)
            x = h + y
            new_kc.append(kc)
            new_vc.append(vc)
        return x, (tuple(new_kc), tuple(new_vc))

    xs = (params["layers"], k_slots, v_slots)
    if lora is not None:
        xs = xs + (lora_slots,)
    x, (new_k, new_v) = jax.lax.scan(body, x, xs)
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return _slot_major_merge(new_k, new_v, every), logits
