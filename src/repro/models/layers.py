"""Shared layer primitives: norms, RoPE/M-RoPE, MLPs, embeddings, losses.

All functions are pure; params are plain dicts of jnp arrays.  Initializers
take an explicit rng and return stacked weights when used under scan.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def truncated_normal(rng, shape, scale, dtype=jnp.float32):
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    from repro.perf import perf
    dt = x.dtype
    acc = jnp.float32 if perf().norm_f32 else dt
    xf = x.astype(acc)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * jax.lax.rsqrt(var + jnp.asarray(eps, acc))
    return (xf * w.astype(acc)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------

def _rope_angles(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """positions (...,) -> angles (..., dim//2)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions.astype(jnp.float32)[..., None] * inv


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0,
               mrope_sections: Optional[tuple] = None) -> jax.Array:
    """Rotate-half RoPE.

    x: (B, S, H, hd).  positions: (B, S) for standard RoPE, or (3, B, S) for
    M-RoPE (temporal / height / width streams, qwen2-vl style): the head dim is
    partitioned into ``mrope_sections`` (in half-dim units), each section keyed
    by its own position stream.
    """
    hd = x.shape[-1]
    half = hd // 2
    if positions.ndim == 3 and mrope_sections is not None:
        # angles per stream: (3, B, S, half)
        ang = _rope_angles(positions, hd, theta)
        parts = []
        start = 0
        for i, sec in enumerate(mrope_sections):
            parts.append(ang[i, ..., start:start + sec])
            start += sec
        angles = jnp.concatenate(parts, axis=-1)           # (B, S, half)
    else:
        angles = _rope_angles(positions, hd, theta)        # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def default_mrope_sections(head_dim: int) -> tuple:
    """qwen2-vl uses sections (t, h, w) = (16, 24, 24) of the 64 half-dims for
    hd=128; generalize proportionally."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    w = half - t - h
    return (t, h, w)


def sinusoidal_positions(seq: int, dim: int) -> jax.Array:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-math.log(10000.0) * jnp.arange(0, dim, 2, dtype=jnp.float32) / dim)
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(cfg: ModelConfig, rng, d_ff: int, dtype):
    d = cfg.d_model
    r = jax.random.split(rng, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(d_ff)
    if cfg.act == "swiglu":
        return {
            "wi_gate": truncated_normal(r[0], (d, d_ff), s_in, dtype),
            "wi_up": truncated_normal(r[1], (d, d_ff), s_in, dtype),
            "wo": truncated_normal(r[2], (d_ff, d), s_out, dtype),
        }
    return {
        "wi": truncated_normal(r[0], (d, d_ff), s_in, dtype),
        "wo": truncated_normal(r[1], (d_ff, d), s_out, dtype),
    }


def apply_mlp(cfg: ModelConfig, p, x: jax.Array,
              lora: Optional[dict] = None) -> jax.Array:
    from repro.distributed.sharding import weight_use
    from repro.models import lora as lora_mod
    if cfg.act == "swiglu":
        g = lora_mod.add_delta("gate", jnp.einsum(
            "bsd,df->bsf", x, weight_use(p["wi_gate"], None, "ff")), x, lora)
        u = lora_mod.add_delta("up", jnp.einsum(
            "bsd,df->bsf", x, weight_use(p["wi_up"], None, "ff")), x, lora)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = lora_mod.add_delta("wi", jnp.einsum(
            "bsd,df->bsf", x, weight_use(p["wi"], None, "ff")), x, lora)
        if cfg.act == "squared_relu":
            h = jnp.square(jax.nn.relu(h.astype(jnp.float32))).astype(x.dtype)
        else:  # gelu
            h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    from repro.perf import perf
    if perf().seq_parallel:
        # SP: the MLP is pointwise over seq — stay sequence-sharded end to
        # end instead of resharding seq->ff per layer (§Perf iter5 lesson:
        # the conflicting "ff" constraint forced 2GB/layer seq all-gathers)
        h = constrain(h, "batch", "seq_mp", None)
    else:
        h = constrain(h, "batch", None, "ff")
    from repro.distributed.param_sharding import tp_hidden
    h = tp_hidden(h)
    return lora_mod.add_delta("down", jnp.einsum(
        "bsf,fd->bsd", h, weight_use(p["wo"], "ff", None)), h, lora)


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------

def init_embed(cfg: ModelConfig, rng, dtype):
    r = jax.random.split(rng, 2)
    p = {"embed": truncated_normal(r[0], (cfg.vocab, cfg.d_model), 1.0, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = truncated_normal(
            r[1], (cfg.d_model, cfg.vocab), 1.0 / math.sqrt(cfg.d_model), dtype)
    return p


def embed_tokens(p, tokens: jax.Array) -> jax.Array:
    from repro.distributed.param_sharding import tp_use
    out = jnp.take(tp_use(p["embed"]), tokens, axis=0)
    return constrain(out, "batch", None, None)


def logits_from_hidden(cfg: ModelConfig, p, h: jax.Array) -> jax.Array:
    from repro.distributed.sharding import weight_use
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h, weight_use(p["embed"], "vocab", None))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h, weight_use(p["unembed"], None, "vocab"))
    return constrain(logits, "batch", None, "vocab")


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token loss; logits (B,S,V) any dtype, labels (B,S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)
