"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

Dispatch avoids the (T, E, C) one-hot tensor of classic Switch
implementations: tokens are scattered into a per-sequence (E, C, d) buffer
(dest index = expert*C + rank-within-expert), experts run as a single batched
einsum over expert-stacked weights, and results are gathered back and scaled
by the router gate.  Cumulative ranks are computed *within each sequence* so
no cross-device cumsum is required under batch sharding.

Decode path (S=1) gathers the selected experts' weights per token instead —
for single-token batches that is the memory-optimal execution (reading k
experts' weights per token) rather than densely running all E experts.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.param_sharding import tp_use
from repro.distributed.sharding import constrain
from repro.models.layers import init_mlp, apply_mlp, truncated_normal


def init_moe(cfg: ModelConfig, rng, dtype):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    r = jax.random.split(rng, 5)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": truncated_normal(r[0], (d, e), s_in, jnp.float32),
        "wi_gate": truncated_normal(r[1], (e, d, f), s_in, dtype),
        "wi_up": truncated_normal(r[2], (e, d, f), s_in, dtype),
        "wo": truncated_normal(r[3], (e, f, d), s_out, dtype),
    }
    if m.n_shared_experts:
        p["shared"] = init_mlp(cfg, r[4], m.n_shared_experts * f, dtype)
    return p


def _route(cfg: ModelConfig, p, x: jax.Array):
    """x (B,S,d) -> (gates (B,S,k), idx (B,S,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    if m.top_k > 1:
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    # load-balance auxiliary loss (Switch): E * mean_e(frac_e * prob_e)
    assign = jax.nn.one_hot(idx[..., 0], m.n_experts, dtype=jnp.float32)
    frac = jnp.mean(assign, axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = m.n_experts * jnp.sum(frac * mean_prob)
    return gates, idx, aux


def _dispatch_one(x: jax.Array, idx: jax.Array, n_experts: int, capacity: int):
    """x (B,S,d), idx (B,S) -> buf (B,E,C,d), dest (B,S), keep (B,S)."""
    b, s, d = x.shape
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)          # (B,S,E)
    pos = jnp.cumsum(onehot, axis=1) - 1                               # (B,S,E)
    rank = jnp.take_along_axis(pos, idx[..., None], axis=-1)[..., 0]   # (B,S)
    keep = rank < capacity
    dest = jnp.where(keep, idx * capacity + rank, n_experts * capacity)

    def scatter(xb, db):
        return jnp.zeros((n_experts * capacity + 1, d), xb.dtype).at[db].add(xb)

    buf = jax.vmap(scatter)(x, dest)[:, :-1, :]
    return buf.reshape(b, n_experts, capacity, d), dest, keep


def _expert_ffn(cfg: ModelConfig, p, buf: jax.Array) -> jax.Array:
    """buf (B,E,C,d) -> (B,E,C,d) through expert-stacked SwiGLU.

    The (batch-sharded) -> (expert-sharded) constraint transition is where
    GSPMD inserts the expert-parallel all-to-all.
    """
    buf = constrain(buf, "batch", "experts", None, None)
    g = jnp.einsum("becd,edf->becf", buf, tp_use(p["wi_gate"]))
    u = jnp.einsum("becd,edf->becf", buf, tp_use(p["wi_up"]))
    h = jax.nn.silu(g.astype(jnp.float32)).astype(buf.dtype) * u
    h = constrain(h, "batch", "experts", None, None)
    out = jnp.einsum("becf,efd->becd", h, tp_use(p["wo"]))
    return constrain(out, "batch", "experts", None, None)


def apply_moe(cfg: ModelConfig, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Train/prefill MoE: x (B,S,d) -> (y (B,S,d), aux_loss)."""
    m = cfg.moe
    b, s, d = x.shape
    gates, idx, aux = _route(cfg, p, x)
    capacity = max(1, int(math.ceil(s / m.n_experts * m.capacity_factor)))
    y = jnp.zeros_like(x)
    for k in range(m.top_k):
        buf, dest, keep = _dispatch_one(x, idx[..., k], m.n_experts, capacity)
        out = _expert_ffn(cfg, p, buf).reshape(b, m.n_experts * capacity, d)
        out = jnp.concatenate([out, jnp.zeros((b, 1, d), out.dtype)], axis=1)
        gathered = jnp.take_along_axis(out, dest[..., None], axis=1)
        w = (gates[..., k] * keep.astype(gates.dtype))[..., None]
        y = y + gathered * w.astype(x.dtype)
    if "shared" in p:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y, aux


def apply_moe_decode_dispatch(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Decode MoE via capacity-based token dispatch (all-to-all) instead of
    per-token expert-weight gathers.

    All decode tokens across the batch form ONE dispatch group: the (E, C, d)
    buffer is expert-sharded, so getting tokens to their experts moves
    ~B*d*2 bytes of activations over ICI rather than B * (3*d*f*2) bytes of
    expert weights — the §Perf fix for the collective-bound llama4 decode
    cell (napkin: 128 tokens x 5120 x 2B = 1.3 MB vs 128 x 250 MB gathered).
    """
    m = cfg.moe
    b, s, d = x.shape
    gates, idx, _ = _route(cfg, p, x)
    xt = x.reshape(b * s, d)
    idx = idx.reshape(b * s, m.top_k)
    gates = gates.reshape(b * s, m.top_k)
    capacity = max(1, int(math.ceil(b * s * m.capacity_factor / m.n_experts)))
    y = jnp.zeros_like(xt)
    for k in range(m.top_k):
        buf, dest, keep = _dispatch_one(xt[None], idx[None, :, k],
                                        m.n_experts, capacity)
        out = _expert_ffn(cfg, p, buf).reshape(1, m.n_experts * capacity, d)
        out = jnp.concatenate([out, jnp.zeros((1, 1, d), out.dtype)], axis=1)
        gathered = jnp.take_along_axis(out, dest[..., None], axis=1)[0]
        w = (gates[:, k] * keep[0].astype(gates.dtype))[:, None]
        y = y + gathered * w.astype(xt.dtype)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y


def apply_moe_decode(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """Decode MoE (S=1): gather each token's expert weights and run locally."""
    m = cfg.moe
    b, s, d = x.shape
    gates, idx, _ = _route(cfg, p, x)
    xt = x.reshape(b * s, d)
    idx = idx.reshape(b * s, m.top_k)
    gates = gates.reshape(b * s, m.top_k)
    y = jnp.zeros_like(xt)
    for k in range(m.top_k):
        wi_g = jnp.take(tp_use(p["wi_gate"]), idx[:, k], axis=0)   # (T,d,f)
        wi_u = jnp.take(tp_use(p["wi_up"]), idx[:, k], axis=0)
        wo = jnp.take(tp_use(p["wo"]), idx[:, k], axis=0)          # (T,f,d)
        g = jnp.einsum("td,tdf->tf", xt, wi_g)
        u = jnp.einsum("td,tdf->tf", xt, wi_u)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
        y = y + jnp.einsum("tf,tfd->td", h, wo) * gates[:, k, None].astype(xt.dtype)
    y = y.reshape(b, s, d)
    if "shared" in p:
        y = y + apply_mlp(cfg, p["shared"], x)
    return y
